"""FileStore: the POSIX-directory backend (current on-disk layout).

Bit-compatible with pre-backend datasets: keys map 1:1 onto the relative
paths CZDataset has always written (``p/t000000.cz``), member bytes are
written streaming through a real file handle, and ``put_atomic`` is the
store's historical manifest commit (tmp + fsync + rename + directory
fsync).  Existing datasets on disk open unchanged.
"""
from __future__ import annotations

import contextlib
import fcntl
import os

from .base import Store, StoreKeyError, check_key, check_range

__all__ = ["FileStore"]


class FileStore(Store):
    """Byte store over a local directory tree."""

    scheme = "file"

    def __init__(self, root: str):
        super().__init__()
        self.root = os.path.abspath(os.fspath(root))

    @classmethod
    def from_url(cls, rest: str) -> "FileStore":
        # file:///abs/path -> "/abs/path"; file://rel/path -> "rel/path"
        return cls(rest or ".")

    def path_for(self, key: str) -> str:
        """Local filesystem path for ``key`` (validated)."""
        return os.path.join(self.root, *check_key(key).split("/"))

    def _ensure_parent(self, path: str) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)

    # -- primitives ----------------------------------------------------------

    def get(self, key, byte_range=None):
        try:
            f = open(self.path_for(key), "rb")
        except (FileNotFoundError, IsADirectoryError, NotADirectoryError):
            raise StoreKeyError(key) from None
        with f:
            if byte_range is None:
                return f.read()
            start, end = byte_range
            start = check_range(key, start, os.fstat(f.fileno()).st_size)
            f.seek(start)
            return f.read(None if end is None else max(0, int(end) - start))

    def put(self, key, data):
        path = self.path_for(key)
        self._ensure_parent(path)
        with open(path, "wb") as f:
            f.write(data)

    def put_atomic(self, key, data):
        """tmp write + fsync + rename over the target, then fsync the parent
        directory so the rename itself is durable — the dataset's manifest
        commit protocol, unchanged from the pre-backend store."""
        path = self.path_for(key)
        self._ensure_parent(path)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        dfd = os.open(os.path.dirname(path), os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def list(self, prefix=""):
        out = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            parts = [] if rel == "." else rel.split(os.sep)
            for fn in filenames:
                key = "/".join(parts + [fn])
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key):
        path = self.path_for(key)
        try:
            os.unlink(path)
        except FileNotFoundError:
            raise StoreKeyError(key) from None
        # prune now-empty parent directories back up to the root, so a
        # delete-driven gc leaves no husk quantity dirs behind
        d = os.path.dirname(path)
        while len(d) > len(self.root):
            try:
                os.rmdir(d)
            except OSError:
                break
            d = os.path.dirname(d)

    def exists(self, key):
        return os.path.isfile(self.path_for(key))

    # -- derived -------------------------------------------------------------

    def open_write(self, key):
        """A real file handle: the CZ2 writer streams chunks (one in memory)
        and seeks back to patch the footer pointer — byte-identical to the
        pre-backend direct-path writer."""
        path = self.path_for(key)
        self._ensure_parent(path)
        return open(path, "wb")

    @contextlib.contextmanager
    def lock(self, name: str):
        """``flock`` on a file inside the root: exclusive across processes
        (the sidecar commit/merge serialization needs more than in-process
        locks on a shared filesystem)."""
        path = self.path_for(name)
        self._ensure_parent(path)
        fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield
        finally:
            fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    @property
    def url(self) -> str:
        return f"file://{self.root}"
