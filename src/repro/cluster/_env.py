"""Worker-process environment setup for the cluster engine.

N rank processes each spinning up a multi-threaded BLAS/XLA runtime
oversubscribes the node and can make the parallel path *slower* than serial
— one compute thread per rank is the paper's model anyway.  The caps must be
in the environment **before** the worker process loads numpy (OpenBLAS/OMP
size their pools at library load) — too early for any in-worker initializer,
since unpickling one already imports the package.  So the parent exports the
caps around spawn-pool creation (:func:`worker_env`); the children inherit
them at exec.
"""
from __future__ import annotations

import contextlib
import os

_THREAD_VARS = ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                "MKL_NUM_THREADS", "NUMEXPR_NUM_THREADS")


@contextlib.contextmanager
def worker_env():
    """Temporarily export per-worker thread caps (explicit settings win);
    restores the parent's environment on exit."""
    saved: dict[str, str | None] = {}

    def _set(var: str, val: str) -> None:
        saved[var] = os.environ.get(var)
        os.environ[var] = val

    for var in _THREAD_VARS:
        if var not in os.environ:
            _set(var, "1")
    flags = os.environ.get("XLA_FLAGS", "")
    add = [f for f in ("--xla_cpu_multi_thread_eigen=false",
                       "intra_op_parallelism_threads=1")
           if f.split("=")[0].lstrip("-") not in flags]
    if add:
        _set("XLA_FLAGS", " ".join([flags] + add).strip())
    try:
        yield
    finally:
        for var, old in saved.items():
            if old is None:
                os.environ.pop(var, None)
            else:
                os.environ[var] = old
