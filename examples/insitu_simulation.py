"""In-situ compression during a running simulation (paper Fig. 12 analogue):
the mini Euler solver advances a bubble collapse; every N steps the I/O hook
compresses pressure snapshots in place.

Run:  PYTHONPATH=src python examples/insitu_simulation.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import CompressionSpec, Pipeline
from repro.fields import EulerConfig, init_bubble_cloud
from repro.fields.euler3d import cfl_dt, primitives, run

cfg = EulerConfig(n=48, n_bubbles=5)
U = init_bubble_cloud(cfg)
dt = cfl_dt(U)
sim_t = io_t = 0.0
for snap in range(5):
    t0 = time.time()
    U = run(U, 10, dt=dt)
    jnp.asarray(U).block_until_ready()
    sim_t += time.time() - t0

    _, _, p = primitives(U)
    p = np.asarray(p, np.float32)
    t0 = time.time()
    eps = 1e-4 * float(p.max() - p.min())
    comp = Pipeline(CompressionSpec(scheme="wavelet", eps=eps,
                                    block_size=16)).compress(p)
    io_t += time.time() - t0
    print(f"snapshot {snap}: p in [{p.min():.2f},{p.max():.2f}] "
          f"CR {comp.header['raw_bytes']/comp.nbytes:6.1f}x")
print(f"in-situ I/O overhead: {io_t/(sim_t+io_t)*100:.1f}% of wall time")
