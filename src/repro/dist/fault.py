"""Fault tolerance for long-running compression/training fleets.

Three mechanisms, matching what ``repro.launch.train`` wires up:

* :class:`PreemptionHandler` — turns SIGTERM/SIGINT into a cooperative
  "finish the step, checkpoint, exit 0" instead of a hard kill.
* :class:`StragglerWatchdog` — flags steps whose wall time exceeds a
  multiple of the rolling median; persistent outliers get a ``redispatch``
  verdict (the scheduler should move that shard's work elsewhere).
* :func:`elastic_plan` — re-plans the device mesh and per-device batch when
  the fleet comes back smaller (or larger) than requested; checkpoints are
  resharded on load, so training resumes on whatever is available.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import signal

import numpy as np

__all__ = ["PreemptionHandler", "StragglerReport", "StragglerWatchdog",
           "elastic_plan"]


class PreemptionHandler:
    """Latch SIGTERM/SIGINT; the train loop polls ``.preempted`` each step."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT), install: bool = True):
        self.preempted = False
        self._prev = {}
        if install:
            for sig in signals:
                try:
                    self._prev[sig] = signal.signal(sig, self._handle)
                except (ValueError, OSError):  # non-main thread / exotic platform
                    pass

    def _handle(self, signum, frame):
        self.preempted = True

    def restore(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    ratio: float      # step_time / rolling median of healthy steps
    action: str       # "ok" | "flag" | "redispatch"


class StragglerWatchdog:
    """Rolling-median step timer; only healthy steps feed the baseline so a
    slow shard cannot drag the median up and mask itself."""

    def __init__(self, window: int = 8, flag_ratio: float = 1.5,
                 redispatch_ratio: float = 3.0):
        self.flag_ratio = flag_ratio
        self.redispatch_ratio = redispatch_ratio
        self._times: collections.deque[float] = collections.deque(maxlen=window)
        self.reports: list[StragglerReport] = []

    def observe(self, step: int, step_time: float) -> StragglerReport:
        ratio = step_time / float(np.median(self._times)) if self._times else 1.0
        if ratio >= self.redispatch_ratio:
            action = "redispatch"
        elif ratio >= self.flag_ratio:
            action = "flag"
        else:
            action = "ok"
        rep = StragglerReport(step, step_time, ratio, action)
        if action == "ok":
            self._times.append(step_time)
        else:
            self.reports.append(rep)
        return rep


def elastic_plan(requested: int, available: int, *, global_batch: int) -> dict:
    """Mesh + batch plan for a fleet of ``available`` devices.

    Factors ``available`` into the squarest (data, model) mesh and keeps the
    global batch by padding the per-device batch up when data parallelism
    does not divide it evenly.
    """
    if available < 1:
        raise ValueError("no devices available")
    model = max(d for d in range(1, math.isqrt(available) + 1)
                if available % d == 0)
    data = available // model
    per_device = math.ceil(global_batch / data)
    return {
        "requested": requested,
        "n_devices": available,
        "mesh_shape": (data, model),
        "per_device_batch": per_device,
        "batch_pad": per_device * data - global_batch,
        "degraded": available < requested,
    }
