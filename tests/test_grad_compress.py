"""Gradient compression: roundtrip, error feedback convergence, and the
compressed-pod train step lowering on the multi-pod mesh."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.train.grad_compress import topk_compress, topk_decompress


def test_topk_roundtrip_keeps_largest():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    vals, idx = topk_compress(g, ratio=8)
    assert vals.shape == (1, 128)  # one 2^20 block covers the whole leaf
    dense = topk_decompress(vals, idx, (1024,))
    kept = np.asarray(dense)[np.asarray(idx)[0]]
    np.testing.assert_allclose(kept, np.asarray(vals)[0], rtol=1e-6)
    # kept magnitudes dominate dropped ones
    thresh = np.abs(np.asarray(vals)).min()
    dropped = np.delete(np.asarray(g), np.asarray(idx)[0])
    assert np.abs(dropped).max() <= thresh + 1e-6


def test_topk_multiblock_roundtrip():
    """Leaves larger than one block: block-local selection + exact scatter."""
    import repro.train.grad_compress as gc

    old = gc._BLOCK
    gc._BLOCK = 256
    try:
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)  # pad path
        vals, idx = topk_compress(g, ratio=4)
        assert vals.shape == (4, 64)
        dense = np.asarray(topk_decompress(vals, idx, (1000,)))
        # every kept entry matches the original exactly
        nz = dense != 0
        np.testing.assert_allclose(dense[nz], np.asarray(g)[nz], rtol=1e-6)
    finally:
        gc._BLOCK = old


def test_error_feedback_converges_quadratic():
    """EF-compressed SGD must converge on a quadratic like dense SGD does."""
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
    A = A @ A.T + 0.5 * jnp.eye(64)
    b = jnp.asarray(rng.standard_normal(64), jnp.float32)

    def grad(x):
        return A @ x - b

    x_star = jnp.linalg.solve(A, b)

    def run(compressed):
        x = jnp.zeros(64)
        r = jnp.zeros(64)
        for _ in range(400):
            g = grad(x)
            if compressed:
                corrected = g + r
                vals, idx = topk_compress(corrected, ratio=8)
                sent = topk_decompress(vals, idx, (64,))
                r = corrected - sent
                g = sent
            x = x - 0.1 * g
        return float(jnp.linalg.norm(x - x_star))

    dense_err = run(False)
    comp_err = run(True)
    assert comp_err < 1e-2, comp_err
    assert comp_err < max(dense_err * 50, 1e-2)


def test_compressed_pod_step_lowers_on_multi_mesh():
    """The grad-compressed train step must lower+compile on a (pod,data,model)
    mesh — small mesh here; the production 2x16x16 is exercised by dryrun."""
    if jax.device_count() < 4:
        import pytest

        pytest.skip("needs >=4 devices (run under XLA_FLAGS host device count)")
    from repro.configs import ARCHS, reduced
    from repro.models import ModelSettings, input_batch_specs
    from repro.train.step import build_train_step, train_state_specs
    from repro.configs.base import ShapeConfig

    cfg = reduced(ARCHS["smollm-135m"])
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 1), ("pod", "data", "model"))
    st = ModelSettings(q_chunk=16, kv_chunk=16, ce_chunk=32, remat="none")
    shape = ShapeConfig("tiny", 64, 8, "train")
    batch_specs = input_batch_specs(cfg, shape)
    state_specs = train_state_specs(cfg, grad_compress="topk32")
    _, jit_for, _ = build_train_step(cfg, mesh, settings=st,
                                     grad_compress="topk32", donate=False)
    with mesh:
        lowered = jit_for(batch_specs).lower(state_specs, batch_specs)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
