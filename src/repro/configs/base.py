"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeConfig``s.  ``reduced()`` produces the CPU-smoke-test
variant of an architecture (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False         # qwen2.5
    qk_norm: bool = False          # qwen3
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False    # llama4-style always-on expert
    moe_period: int = 1            # MoE every `moe_period` layers (jamba: 2)
    capacity_factor: float = 1.25
    moe_group: int = 512           # GShard dispatch group size (tokens)
    # SSM / hybrid
    ssm_kind: str = ""             # "rwkv6" | "mamba"
    attn_period: int = 0           # hybrid: 1 attention layer per `attn_period`
    d_state: int = 16
    ssm_expand: int = 2
    conv_kernel: int = 4
    # encoder-decoder
    encoder_layers: int = 0
    enc_frames: int = 1500         # stub audio frontend output length
    # misc
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without O(S^2) attention state?

        True for SSM and hybrid archs (decode state is O(1) per Mamba/RWKV
        layer; jamba's few attention layers keep a cache but decode is O(S)
        per token, not O(S^2))."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameters (analytic)."""
        from repro.models.registry import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason) for an (arch x shape) dry-run cell."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention (DESIGN.md §4)"
    return True, ""


def reduced(arch: ArchConfig) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    return dataclasses.replace(
        arch,
        n_layers=max(2, min(4, arch.attn_period or 2) * (2 if arch.family == "hybrid" else 1)),
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if arch.n_kv_heads < arch.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        n_experts=min(arch.n_experts, 4),
        top_k=min(arch.top_k, 2),
        moe_group=32,
        encoder_layers=2 if arch.encoder_layers else 0,
        enc_frames=24 if arch.encoder_layers else 1500,
        d_state=8,
        attn_period=min(arch.attn_period, 4) if arch.attn_period else 0,
    )
