"""Make ``pytest -q`` work from a clean checkout: put ``src`` on sys.path
(equivalent to ``PYTHONPATH=src`` or an editable install), and register the
tier markers CI splits on (``-m "not slow and not device"`` is the fast
tier-1 job; the kernels job runs the marker-gated remainder)."""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "device: exercises the Pallas kernel (device='jax') paths — slower "
        "to trace/compile; run via the marker-gated CI job")
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end tests (deselect with -m 'not slow')")
