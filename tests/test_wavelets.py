"""Wavelet transform correctness: perfect reconstruction, polynomial
exactness, energy compaction, boundary handling — incl. hypothesis sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip cleanly on a bare interpreter
    from _hypothesis_compat import given, settings, st

from repro.core import wavelets as wv


@pytest.mark.parametrize("kind", wv.WAVELETS)
@pytest.mark.parametrize("n", [8, 16, 32, 64])
def test_roundtrip_3d(kind, n):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, n, n, n)) * 100, jnp.float32)
    y = wv.forward3d(x, kind)
    xr = wv.inverse3d(y, kind)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=2e-2, rtol=1e-5)


@pytest.mark.parametrize("kind", wv.WAVELETS)
def test_roundtrip_1d_all_axes(kind):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 16, 16, 16)), jnp.float32)
    for axis in (-3, -2, -1):
        y = wv.forward1d(x, kind, axis=axis)
        xr = wv.inverse1d(y, kind, axis=axis)
        np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=1e-5)


def test_w4i_reproduces_cubics():
    """Cubic signals have (near-)zero interior details under W4 interpolation."""
    t = np.arange(32, dtype=np.float32)
    sig = 1e-3 * t**3 - 0.02 * t**2 + t
    x = jnp.asarray(np.broadcast_to(sig, (1, 32, 32, 32)))
    d = wv.forward1d(x, "w4i", axis=-1)[..., 16:]
    assert float(jnp.max(jnp.abs(d))) < 1e-4


def test_w3ai_reproduces_quadratics():
    t = np.arange(32, dtype=np.float32)
    sig = 0.01 * t**2 + t
    x = jnp.asarray(np.broadcast_to(sig, (1, 32, 32, 32)))
    d = wv.forward1d(x, "w3ai", axis=-1)[..., 16:]
    assert float(jnp.max(jnp.abs(d))) < 1e-4


def test_w3ai_preserves_mean():
    """Average-interpolating coarse signal is the exact pairwise mean."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 32)), jnp.float32)
    y = wv.forward1d(x, "w3ai", axis=-1)
    s = np.asarray(y[..., :16])
    expect = (np.asarray(x)[..., 0::2] + np.asarray(x)[..., 1::2]) / 2
    np.testing.assert_allclose(s, expect, atol=1e-6)


def test_energy_compaction_smooth_field():
    g = np.exp(-((np.mgrid[0:32, 0:32, 0:32] - 16) ** 2).sum(0) / 60.0)
    for kind in wv.WAVELETS:
        y = wv.forward3d(jnp.asarray(g[None], jnp.float32), kind)
        det = np.asarray(y[0])[wv.detail_mask(32)]
        assert (np.abs(det) < 1e-3).mean() > 0.9, kind


def test_levels_and_coarse_side():
    assert wv.max_levels(32) == 3
    assert wv.max_levels(8) == 1
    assert wv.coarse_side(32) == 4
    assert wv.coarse_side(32, 1) == 16
    with pytest.raises(ValueError):
        wv.default_levels(32, 9)


@settings(max_examples=20, deadline=None)
@given(
    kind=st.sampled_from(wv.WAVELETS),
    n=st.sampled_from([8, 16, 32]),
    levels=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_roundtrip_property(kind, n, levels, seed, scale):
    levels = min(levels, wv.max_levels(n))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, n, n, n)) * scale, jnp.float32)
    y = wv.forward3d(x, kind, levels)
    xr = wv.inverse3d(y, kind, levels)
    tol = max(1e-5, 3e-6 * scale * 30)
    assert float(jnp.max(jnp.abs(xr - x))) < tol
