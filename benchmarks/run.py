"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness convention) and writes
detailed JSON to artifacts/bench/.  ``--full`` runs the publication-size
sweeps; default is the quick variant (CI-friendly).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated bench names (e.g. methods,speed)")
    args = ap.parse_args(argv)

    from . import (
        bench_backends,
        bench_blocksize,
        bench_ckpt,
        bench_coeff,
        bench_device,
        bench_gradcomp,
        bench_insitu,
        bench_methods,
        bench_parallel,
        bench_scaling,
        bench_serve,
        bench_shuffle,
        bench_speed,
        bench_store,
        bench_tolerance,
        bench_wavelet_time,
        bench_wavelet_types,
    )

    benches = {
        "wavelet_time": bench_wavelet_time,
        "wavelet_types": bench_wavelet_types,
        "shuffle": bench_shuffle,
        "blocksize": bench_blocksize,
        "methods": bench_methods,
        "coeff": bench_coeff,
        "speed": bench_speed,
        "tolerance": bench_tolerance,
        "scaling": bench_scaling,
        "insitu": bench_insitu,
        "ckpt": bench_ckpt,
        "gradcomp": bench_gradcomp,
        "store": bench_store,
        "backends": bench_backends,
        "parallel": bench_parallel,
        "device": bench_device,
        "serve": bench_serve,
    }
    only = [s for s in args.only.split(",") if s]
    unknown = sorted(set(only) - set(benches))
    if unknown:
        # a typo must fail loudly, not let the CI smoke job pass while
        # silently running zero benchmarks
        print(f"# unknown bench name(s): {', '.join(unknown)}; "
              f"known: {', '.join(sorted(benches))}", file=sys.stderr)
        raise SystemExit(2)
    from . import common

    failures = []
    for name, mod in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", file=sys.stderr)
        try:
            metrics = mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        else:
            # one machine-readable record per bench: params + run() return
            # + the full cz_* registry snapshot (perf trajectory across PRs)
            rec = common.write_bench_record(
                name, {"quick": not args.full,
                       "duration_s": round(time.time() - t0, 3)}, metrics)
            print(f"# wrote {rec}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
