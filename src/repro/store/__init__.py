"""``repro.store`` — sharded multi-quantity dataset store over CZ2 members.

A petascale run is a *dataset* — many quantities x many timesteps — not a
pile of loose files.  :class:`CZDataset` makes the paper's per-quantity,
per-snapshot output layout first-class (Zarr-style manifest-driven store;
WaveRange-style per-field, per-snapshot records):

On-disk layout
--------------

::

    dataset/
      manifest.json            # the ONLY mutable file; atomic tmp+rename
      p/
        t000000.cz             # CZ2 container: quantity "p", timestep 0
        t000001.cz
      rho/
        t000000.cz
        t000001.cz

* Every member is an ordinary CZ2 container (``repro.core.container``):
  independently decompressible chunks, per-chunk CRC32, self-describing
  JSON footer (scheme name + params + dtype tag) — each member also reads
  standalone with ``read_field``/``FieldReader``.
* ``manifest.json`` is the commit point.  Schema (format 1)::

      {"magic": "CZDS", "format": 1,
       "version": <int, +1 per commit>, "next_t": <int>,
       "spec": {<dataset-default CompressionSpec>},
       "quantities": {
         "p": {"shape": [nx, ny, nz], "dtype": "float32",
               "timesteps": [{"t": 0, "time": 9.4, "file": "p/t000000.cz",
                              "bytes": ..., "raw_bytes": ...}, ...]}}}

  A timestep exists iff the manifest references it; members are written
  first and the manifest is replaced atomically, so a crash mid-append
  leaves at most orphaned member files, never a torn dataset.
* **Append mode** (``mode="a"``): an in-situ simulation opens the dataset
  once and appends timesteps as they are produced; chunk encoding for all
  quantities of a snapshot runs on one shared thread pool
  (:class:`ShardWriter` — the paper's per-thread writers) with a single
  ordered drain per file, byte-identical to a serial write.
* **Region reads**: ``read_box(quantity, t, lo, hi)`` decodes only the
  chunks covering the sub-box through per-member LRU chunk caches
  (``FieldReader``) — never the whole field.
* **Multi-writer runs** (``repro.cluster.multiwriter``): per-rank
  ``manifest.rank{r}.json`` sidecars commit independently during in-situ
  append and are folded into ``manifest.json`` by one atomic merge;
  ``CZDataset.gc()`` reclaims orphans from torn appends or aborted merges
  without ever touching sidecar-referenced (still pending) members.
"""
from .dataset import CZDataset  # noqa: F401
from .manifest import MANIFEST_NAME, ManifestError  # noqa: F401
from .writer import DtypeCoercionWarning, ShardWriter  # noqa: F401

__all__ = ["CZDataset", "ShardWriter", "DtypeCoercionWarning",
           "ManifestError", "MANIFEST_NAME"]
