"""End-to-end training driver with fault tolerance.

Runs for real on this CPU container with ``--reduced`` (tiny same-family
config) and is the same code path a fleet launcher would invoke per host.
Features: deterministic resumable data, compressed checkpoints (CubismZ
fpzipx) with atomic commit + retention, auto-resume from latest, preemption
(SIGTERM) checkpointing, straggler watchdog, fault injection for tests
(``--fail-at-step``), optional cross-pod gradient compression.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --ckpt-dir /tmp/ck --ckpt-every 50
  # kill it mid-run, re-run the same command -> resumes from latest step
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.core import CompressionSpec
from repro.ckpt import Checkpointer
from repro.data.tokens import DataConfig, batch_at
from repro.dist.fault import PreemptionHandler, StragglerWatchdog
from repro.launch.mesh import make_mesh
from repro.models import ModelSettings
from repro.train.optim import OptConfig
from repro.train.step import build_train_step, init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-scheme", default="fpzipx",
                    help="checkpoint codec: fpzipx|wavelet|szx|raw")
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--fail-at-step", type=int, default=0,
                    help="fault injection: hard-exit at this step (tests)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    ap.add_argument("--data-branching", type=int, default=8)
    ap.add_argument("--data-regimes", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    st = ModelSettings(q_chunk=32, kv_chunk=64, ce_chunk=64, remat="none",
                       compute_dtype=jnp.float32)
    opt = OptConfig(lr=args.lr, warmup_steps=20, total_steps=max(args.steps, 100))
    mesh = make_mesh((1, 1), ("data", "model"))

    data_cfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                          seed=args.seed, branching=args.data_branching,
                          n_regimes=args.data_regimes)

    train_fn, jit_for, _ = build_train_step(cfg, mesh, settings=st, opt=opt,
                                            donate=True)
    batch0 = {k: jnp.asarray(v) for k, v in batch_at(data_cfg, 0).items()}
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        batch0["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_frames, cfg.d_model)),
            jnp.float32)
    jitted = jit_for(jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch0))

    # --- state init or resume -------------------------------------------
    ckpt = None
    start_step = 0
    state = None
    if args.ckpt_dir:
        spec = (CompressionSpec(scheme=args.ckpt_scheme, precision=32,
                                block_size=16, shuffle="byte")
                if args.ckpt_scheme != "raw" else CompressionSpec(scheme="raw"))
        ckpt = Checkpointer(args.ckpt_dir, every=args.ckpt_every, spec=spec)
        template = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        restored, rstep = ckpt.resume(template) if args.resume else (None, None)
        if restored is not None:
            state = jax.tree.map(jnp.asarray, restored)
            start_step = int(rstep)
            print(f"[resume] from step {start_step}")
        else:
            state = template
    else:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))

    preempt = PreemptionHandler()
    watchdog = StragglerWatchdog()
    losses = []

    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in batch_at(data_cfg, step).items()}
            if cfg.family == "encdec":
                batch["frames"] = batch0["frames"]
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            rep = watchdog.observe(step, time.time() - t0)
            if rep.action != "ok":
                print(f"[straggler] step {step}: {rep.step_time:.2f}s "
                      f"({rep.ratio:.1f}x median) -> {rep.action}")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.2f}s)")
            if ckpt:
                m = ckpt.maybe_save(state, step + 1)
                if m:
                    print(f"[ckpt] step {step+1} CR={m['cr']:.2f}")
            if args.fail_at_step and step + 1 == args.fail_at_step:
                print(f"[fault-injection] hard exit at step {step+1}")
                sys.exit(17)
            if preempt.preempted:
                if ckpt:
                    ckpt.maybe_save(state, step + 1, force=True)
                    print(f"[preempt] checkpointed step {step+1}, exiting")
                sys.exit(0)

    if ckpt:
        ckpt.maybe_save(state, args.steps, force=True)
    first = float(np.mean(losses[:5])) if len(losses) >= 5 else losses[0]
    last = float(np.mean(losses[-5:]))
    print(f"done: loss {first:.4f} -> {last:.4f} over {len(losses)} steps")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": losses, "first": first, "last": last,
                       "steps": len(losses)}, f)
    return first, last


if __name__ == "__main__":
    main()
