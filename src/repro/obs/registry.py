"""Thread-safe metrics registry with Prometheus text exposition.

The one instrumentation substrate every tier reports through: the codec
pipeline (per-chunk encode/decode time, bytes, achieved ratio), the
container reader (fetch vs decode split), the byte-store layer (ops/bytes/
latency per backend), the cluster engine (per-rank phase timing), and the
serve tier's ``/metrics`` endpoint all register here, so one snapshot — or
one scrape — answers where time and bytes went.

Three metric kinds, all label-aware and safe under concurrent updates:

* :class:`Counter` — monotonically increasing totals (``_total`` names);
* :class:`Gauge` — point-in-time values that go both ways;
* :class:`Histogram` — fixed-bucket distributions in the Prometheus shape
  (cumulative ``le`` buckets plus ``_sum``/``_count``).

A :class:`Registry` owns an ordered set of uniquely-named metrics and
renders them as Prometheus text format 0.0.4 (:meth:`Registry.render`),
as an OpenMetrics 1.0 document (``render(openmetrics=True)`` — the only
format in which histogram exemplars are emitted, since the legacy 0.0.4
parser rejects exemplar syntax), or a JSON-able snapshot
(:meth:`Registry.snapshot`).  ``REGISTRY`` is the
process-wide default — module-level :func:`counter` / :func:`gauge` /
:func:`histogram` are get-or-create against it, so instrumented modules
can register at import time and re-imports are idempotent.

Namespace hygiene is enforced at registration: every metric name must
match ``cz_[a-z0-9_]+`` and carry a non-empty help string, so third-party
schemes/backends cannot pollute the exposition (the naming lint in
``tests/test_obs.py`` asserts the same invariant over everything that
actually registered).

Stdlib only — this module must stay importable before numpy/jax.
"""
from __future__ import annotations

import bisect
import re
import threading
import time
from typing import Iterator

__all__ = ["Counter", "Gauge", "Histogram", "Metric", "Registry", "REGISTRY",
           "DEFAULT_BUCKETS", "FAST_BUCKETS", "counter", "gauge", "histogram",
           "render", "snapshot", "parse_prometheus"]

#: required shape of every metric name (the ``cz_`` namespace is the lint).
NAME_RE = re.compile(r"cz_[a-z0-9_]+")
_LABEL_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*")

#: request-latency bucket bounds, seconds (+Inf is implicit) — the serve
#: tier's histogram shape, also the default for new histograms.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5)

#: finer low end for micro-ops (in-memory store gets, chunk fetches).
FAST_BUCKETS = (1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3,
                5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 1.0)


def _check_name(name: str) -> str:
    if not isinstance(name, str) or not NAME_RE.fullmatch(name):
        raise ValueError(
            f"metric name {name!r} must match '{NAME_RE.pattern}' "
            "(cz_ namespace, lowercase, underscores)")
    return name


def _escape(value) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labelstr(names: tuple, values: tuple, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Metric:
    """Base: name/help/labelnames validation plus the labelled-series map.

    Series are keyed by the tuple of label *values* in ``labelnames`` order;
    an unlabelled metric has exactly one series keyed ``()`` (created
    eagerly, so exposition always shows it).
    """

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple = ()):
        self.name = _check_name(name)
        if not isinstance(help, str) or not help.strip():
            raise ValueError(f"metric {name!r} needs a non-empty help string")
        self.help = help.strip()
        self.labelnames = tuple(labelnames)
        for ln in self.labelnames:
            if not _LABEL_RE.fullmatch(ln) or ln == "le":
                raise ValueError(f"invalid label name {ln!r} on {name!r}")
        self._lock = threading.Lock()
        self._series: dict[tuple, object] = {}
        if not self.labelnames:
            self._series[()] = self._zero()

    def _zero(self):
        return 0

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name} takes labels {list(self.labelnames)}, "
                f"got {sorted(labels)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def _get(self, labels: dict):
        """Current series value under the lock (creates the series)."""
        key = self._key(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = self._zero()
            return self._series[key]

    def samples(self) -> list[tuple[dict, object]]:
        """``[(labels_dict, value), ...]`` in series-creation order."""
        with self._lock:
            items = list(self._series.items())
        return [(dict(zip(self.labelnames, k)), v) for k, v in items]

    def value(self, **labels):
        """One series' current value (0 / empty if never touched)."""
        return self._get(labels)

    def __repr__(self) -> str:
        return (f"{type(self).__name__}({self.name!r}, "
                f"series={len(self._series)})")


class Counter(Metric):
    """Monotonically increasing total."""

    kind = "counter"

    def inc(self, amount=1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    def set_total(self, value, **labels) -> None:
        """Overwrite the running total — for exposition synced from an
        external monotonic snapshot (the serve tier mirrors its request
        counters here at render time), never for live accounting."""
        key = self._key(labels)
        with self._lock:
            self._series[key] = value


class Gauge(Metric):
    """Point-in-time value."""

    kind = "gauge"

    def set(self, value, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = value

    def inc(self, amount=1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    def dec(self, amount=1, **labels) -> None:
        self.inc(-amount, **labels)


class _HistSeries:
    __slots__ = ("counts", "sum", "exemplars")

    def __init__(self, nbuckets: int):
        self.counts = [0] * nbuckets   # per-bucket (not cumulative); last=+Inf
        self.sum = 0.0
        self.exemplars: dict | None = None  # lazily {bucket_i: exemplar}


class Histogram(Metric):
    """Fixed-bucket distribution (cumulative ``le`` exposition)."""

    kind = "histogram"

    def __init__(self, name: str, help: str, buckets=DEFAULT_BUCKETS,
                 labelnames: tuple = ()):
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError(f"histogram {name!r} needs at least one bucket")
        super().__init__(name, help, labelnames)

    def _zero(self):
        return _HistSeries(len(self.bounds) + 1)

    def observe(self, value: float, **labels) -> None:
        i = bisect.bisect_left(self.bounds, value)
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = self._zero()
            s.counts[i] += 1
            s.sum += value

    def snapshot(self, **labels) -> dict:
        """``{"buckets": [(le, cumulative), ...], "sum": s, "count": n}``
        with the +Inf bucket last (the shape ``/metrics`` consumers and
        ``FieldRegionServer.stats`` read)."""
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key) or self._zero()
            counts, total = list(s.counts), s.sum
        cum, rows = 0, []
        for bound, c in zip(self.bounds + (float("inf"),), counts):
            cum += c
            rows.append((bound, cum))
        return {"buckets": rows, "sum": total, "count": cum}

    def exemplar(self, value: float, trace_id: str, **labels) -> None:
        """Attach an exemplar to the bucket ``value`` falls in — the
        OpenMetrics link from a ``/metrics`` bucket to a kept trace ID (the
        tail sampler calls this for every retained trace).  One exemplar
        per bucket is kept (latest wins)."""
        i = bisect.bisect_left(self.bounds, value)
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = self._zero()
            if s.exemplars is None:
                s.exemplars = {}
            s.exemplars[i] = {"trace_id": str(trace_id),
                              "value": float(value),
                              "ts": round(time.time(), 3)}

    def exemplars(self, **labels) -> dict:
        """``{le_bound: {"trace_id", "value", "ts"}}`` for one series
        (empty if none attached)."""
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            ex = dict(s.exemplars) if s is not None and s.exemplars else {}
        bounds = self.bounds + (float("inf"),)
        return {bounds[i]: dict(v) for i, v in ex.items()}

    def load(self, snap: dict, **labels) -> None:
        """Overwrite one series from a :meth:`snapshot`-shaped dict (the
        exposition-sync analog of :meth:`Counter.set_total`)."""
        rows = list(snap["buckets"])
        if len(rows) != len(self.bounds) + 1:
            raise ValueError(
                f"snapshot has {len(rows)} buckets, {self.name} has "
                f"{len(self.bounds) + 1}")
        key = self._key(labels)
        s = self._zero()
        prev = 0
        for i, (_bound, cum) in enumerate(rows):
            s.counts[i] = cum - prev
            prev = cum
        s.sum = snap["sum"]
        with self._lock:
            self._series[key] = s


class Registry:
    """Ordered collection of uniquely-named metrics.

    ``counter``/``gauge``/``histogram`` are get-or-create: re-registering
    the same name returns the existing metric when kind and labelnames
    agree and raises otherwise — so instrumented modules register at import
    and nothing double-counts on re-import.  Exposition (:meth:`render`)
    walks metrics in registration order, which keeps the serve tier's
    migrated ``/metrics`` output name-ordered exactly like the PR 5
    hand-rolled formatter it replaced.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Metric] = {}

    # -- registration --------------------------------------------------------

    def register(self, metric: Metric) -> Metric:
        """Add an already-constructed metric (e.g. a histogram shared with
        in-process accounting).  Idempotent for the same object; a *name*
        collision with a different object is an error."""
        with self._lock:
            have = self._metrics.get(metric.name)
            if have is None:
                self._metrics[metric.name] = metric
            elif have is not metric:
                raise ValueError(
                    f"metric {metric.name!r} already registered")
        return metric

    def _get_or_create(self, cls, name, help, labelnames, **kw) -> Metric:
        with self._lock:
            have = self._metrics.get(name)
            if have is not None:
                if type(have) is not cls or \
                        have.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{have.kind} with labels {list(have.labelnames)}")
                return have
            m = cls(name, help, labelnames=labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name, help, labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name, help, labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name, help, buckets=DEFAULT_BUCKETS,
                  labelnames=()) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    # -- introspection -------------------------------------------------------

    def get(self, name: str) -> Metric:
        with self._lock:
            return self._metrics[name]

    def names(self) -> list[str]:
        with self._lock:
            return list(self._metrics)

    def __iter__(self) -> Iterator[Metric]:
        with self._lock:
            return iter(list(self._metrics.values()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._metrics

    def unregister(self, name: str) -> None:
        """Remove one metric (tests cleaning up after themselves)."""
        with self._lock:
            self._metrics.pop(name, None)

    # -- exposition ----------------------------------------------------------

    def render(self, openmetrics: bool = False) -> str:
        """Text exposition, metrics in registration order.

        The default is Prometheus text format 0.0.4 with **no** exemplars —
        the legacy parser (selected by ``text/plain; version=0.0.4``) errors
        on exemplar syntax, which would fail the whole scrape.  With
        ``openmetrics=True`` the output is an OpenMetrics 1.0 document
        instead: counter families drop their ``_total`` suffix in
        HELP/TYPE, histogram buckets carry their exemplars, and the
        document ends with ``# EOF`` — serve it only to scrapers that
        negotiated ``application/openmetrics-text``.
        """
        lines: list[str] = []
        for m in self:
            family, kind = m.name, m.kind
            if openmetrics and kind == "counter":
                if family.endswith("_total"):
                    # OpenMetrics counters: family name is suffix-free, the
                    # sample keeps the _total suffix
                    family = family[:-len("_total")]
                else:
                    kind = "unknown"  # _total-less counter: stay parseable
            lines.append(f"# HELP {family} {m.help}")
            lines.append(f"# TYPE {family} {kind}")
            if isinstance(m, Histogram):
                for labels, _ in m.samples():
                    snap = m.snapshot(**labels)
                    ex = m.exemplars(**labels) if openmetrics else {}
                    values = tuple(labels[k] for k in m.labelnames)
                    for bound, cum in snap["buckets"]:
                        le = "+Inf" if bound == float("inf") else repr(bound)
                        ls = _labelstr(m.labelnames, values, f'le="{le}"')
                        line = f"{m.name}_bucket{ls} {cum}"
                        e = ex.get(bound)
                        if e is not None:
                            # OpenMetrics exemplar syntax: links this bucket
                            # to a kept tail-trace ID in /debug/traces
                            line += (f' # {{trace_id="{_escape(e["trace_id"])}"'
                                     f'}} {e["value"]} {e["ts"]}')
                        lines.append(line)
                    ls = _labelstr(m.labelnames, values)
                    lines.append(f"{m.name}_sum{ls} {snap['sum']}")
                    lines.append(f"{m.name}_count{ls} {snap['count']}")
            else:
                for labels, value in m.samples():
                    values = tuple(labels[k] for k in m.labelnames)
                    lines.append(
                        f"{m.name}{_labelstr(m.labelnames, values)} {value}")
        if openmetrics:
            lines.append("# EOF")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-able dump: ``{name: {kind, help, labelnames, samples}}``.
        Histogram samples carry ``buckets``/``sum``/``count`` instead of a
        scalar ``value``."""
        out: dict[str, dict] = {}
        for m in self:
            rows = []
            for labels, value in m.samples():
                if isinstance(m, Histogram):
                    snap = m.snapshot(**labels)
                    rows.append({"labels": labels,
                                 "buckets": [[b, c] for b, c in snap["buckets"]],
                                 "sum": snap["sum"], "count": snap["count"]})
                else:
                    rows.append({"labels": labels, "value": value})
            out[m.name] = {"kind": m.kind, "help": m.help,
                           "labelnames": list(m.labelnames), "samples": rows}
        return out


#: the process-wide default registry (module-level helpers target it).
REGISTRY = Registry()


def counter(name, help, labelnames=()) -> Counter:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name, help, labelnames=()) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(name, help, buckets=DEFAULT_BUCKETS, labelnames=()) -> Histogram:
    return REGISTRY.histogram(name, help, buckets, labelnames)


def render(openmetrics: bool = False) -> str:
    return REGISTRY.render(openmetrics=openmetrics)


def snapshot() -> dict:
    return REGISTRY.snapshot()


# -- exposition parsing ------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)\s*$")
_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _strip_exemplar(line: str) -> str:
    """Drop an OpenMetrics exemplar suffix (``... # {labels} value ts``).

    The ``#`` that starts an exemplar is the first one *outside* quoted
    label values — a ``#`` inside a quoted value (an escaped error message,
    say) is sample content and must survive."""
    in_quotes = False
    i, n = 0, len(line)
    while i < n:
        ch = line[i]
        if in_quotes:
            if ch == "\\":
                i += 1  # escaped char: skip it
            elif ch == '"':
                in_quotes = False
        elif ch == '"':
            in_quotes = True
        elif ch == "#":
            return line[:i].rstrip()
        i += 1
    return line


def parse_prometheus(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Parse text exposition into ``{name: [(labels, value), ...]}``.

    Histogram sub-series appear under their exposed names
    (``..._bucket``/``..._sum``/``..._count``).  The structured inverse of
    :meth:`Registry.render` — tests and benchmarks use it (via
    ``serve.Client.metrics_dict``) instead of string-grepping exposition
    text.  Both output formats parse: OpenMetrics exemplars are dropped
    (they link buckets to trace IDs for humans/Perfetto; parse keeps the
    sample shape stable) and ``# EOF`` is skipped as a comment.
    """
    out: dict[str, list[tuple[dict, float]]] = {}
    for line in text.splitlines():
        line = _strip_exemplar(line.strip())
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line: {line!r}")
        labels = {k: v.replace(r'\"', '"').replace(r"\n", "\n")
                   .replace(r"\\", "\\")
                  for k, v in _PAIR_RE.findall(m.group("labels") or "")}
        out.setdefault(m.group("name"), []).append(
            (labels, float(m.group("value"))))
    return out
