"""Self-contained terminal dashboard for a running ``cz-compress serve``.

No Grafana required: polls ``/metrics`` and ``/debug/traces`` and redraws a
compact panel — request rate, latency percentiles from the histogram
buckets, cache hit rates, tail-sampling status, and the most recent kept
traces with their request IDs (fetch one in full with
``curl $URL/debug/traces/<id>``).

Usage::

    PYTHONPATH=src python examples/dashboard/serve_dashboard.py \
        http://127.0.0.1:8423 [--interval 2]
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.serve.http import Client


def _quantile(buckets: list[tuple[dict, float]], q: float) -> float:
    """Percentile estimate from cumulative ``_bucket`` samples (upper bound
    of the first bucket whose cumulative count reaches the target)."""
    rows = sorted(((float(lbl["le"]), val) for lbl, val in buckets
                   if lbl.get("le") not in (None, "+Inf")),
                  key=lambda r: r[0])
    inf = next((val for lbl, val in buckets if lbl.get("le") == "+Inf"), 0.0)
    total = max(inf, rows[-1][1] if rows else 0.0)
    if total <= 0:
        return 0.0
    target = q * total
    for bound, cum in rows:
        if cum >= target:
            return bound
    return rows[-1][0] if rows else 0.0


def _rate(cur: float, prev: float | None, dt: float) -> str:
    if prev is None or dt <= 0:
        return "-"
    return f"{(cur - prev) / dt:,.1f}/s"


def draw(client: Client, prev: dict | None, dt: float) -> dict:
    md = client.metrics_dict()

    def scalar(name, default=0.0):
        rows = md.get(name)
        return rows[0][1] if rows else default

    queries = scalar("cz_serve_queries_total")
    decoded = scalar("cz_serve_bytes_decoded_total")
    served = scalar("cz_serve_bytes_served_total")
    rhits = scalar("cz_serve_region_cache_hits_total")
    rmiss = scalar("cz_serve_region_cache_misses_total")
    buckets = md.get("cz_serve_request_seconds_bucket", [])
    p50 = _quantile(buckets, 0.50)
    p99 = _quantile(buckets, 0.99)

    lines = [
        f"cz-serve dashboard  {time.strftime('%H:%M:%S')}",
        "",
        f"  queries   {int(queries):>12,}   "
        f"rate {_rate(queries, (prev or {}).get('queries'), dt):>12}",
        f"  latency   p50 {p50 * 1e3:>8.2f} ms   p99 {p99 * 1e3:>8.2f} ms",
        f"  region$   {rhits / max(1.0, rhits + rmiss):>11.1%} hit   "
        f"coalesced {int(scalar('cz_serve_coalesced_requests_total')):,}",
        f"  bytes     decoded {decoded / 2**20:>10.1f} MiB   "
        f"served {served / 2**20:>10.1f} MiB",
    ]
    try:
        tr = client.traces()
    except IOError:
        lines.append("  sampling  disabled (--no-sample)")
    else:
        st = tr["stats"]
        lines.append(
            f"  sampling  kept {st['kept_error'] + st['kept_slow']:>4} "
            f"({st['kept_error']} err / {st['kept_slow']} slow)   "
            f"{st['bytes'] / 2**10:,.0f}/{st['budget_bytes'] / 2**10:,.0f} "
            f"KiB   thresh {st['threshold_s'] * 1e3:.1f} ms")
        if tr["traces"]:
            lines.append("")
            lines.append("  recent kept traces (newest last):")
            for rec in tr["traces"][-5:]:
                err = f"  {rec['error']}" if rec["error"] else ""
                lines.append(
                    f"    {rec['request_id']:<18} {rec['reason']:<5} "
                    f"{rec['duration_ms']:>9.2f} ms  "
                    f"{rec['events']:>4} spans{err}")
    sys.stdout.write("\x1b[2J\x1b[H" + "\n".join(lines) + "\n")
    sys.stdout.flush()
    return {"queries": queries}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("url", help="base URL of a running cz-compress serve")
    ap.add_argument("--interval", type=float, default=2.0)
    args = ap.parse_args(argv)
    prev: dict | None = None
    last = time.perf_counter()
    with Client(args.url) as client:
        try:
            while True:
                now = time.perf_counter()
                prev = draw(client, prev, now - last)
                last = now
                time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
