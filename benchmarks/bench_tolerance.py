"""Table 4 — eps sweep with ZLIB default vs best level (CR, PSNR, time).

Expected reproduction: Z/BEST costs far more time for negligible CR gain;
compression time grows as eps shrinks (more coefficients reach stage 2)."""
from __future__ import annotations

import time

from repro.core import CompressionSpec, compress_field, decompress_field
from repro.core.metrics import psnr

from .common import dataset, emit, save_json


def run(quick: bool = True):
    field = dataset("10k")["p"]
    rows = []
    t_all = time.time()
    for eps in (1e-4, 1e-3, 1e-2):
        for lvl, stage2 in (("default", "zlib"), ("best", "zlib9")):
            spec = CompressionSpec(scheme="wavelet", wavelet="w3ai",
                                   eps=eps, stage2=stage2)
            t0 = time.time()
            comp = compress_field(field, spec)
            t1 = time.time() - t0
            dec = decompress_field(comp)
            rows.append({"eps": eps, "zlib": lvl,
                         "cr": comp.header["raw_bytes"] / comp.nbytes,
                         "psnr": psnr(field, dec), "t1_s": t1})
    dt = time.time() - t_all
    save_json("table4_tolerance", rows)
    d = {(r["eps"], r["zlib"]): r for r in rows}
    slowdown = d[(1e-4, "best")]["t1_s"] / max(d[(1e-4, "default")]["t1_s"], 1e-9)
    cr_gain = d[(1e-4, "best")]["cr"] / d[(1e-4, "default")]["cr"]
    emit("table4_zbest_slowdown", dt * 1e6 / max(len(rows), 1), f"{slowdown:.2f}")
    emit("table4_zbest_cr_gain", dt * 1e6 / max(len(rows), 1), f"{cr_gain:.3f}")
    emit("table4_cr_eps1e-3", dt * 1e6 / max(len(rows), 1),
         f"{d[(1e-3, 'default')]['cr']:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
