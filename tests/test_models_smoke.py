"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import (
    ModelSettings,
    cache_spec,
    count_params,
    decode_step,
    init_params,
    lm_loss,
    prefill,
)

ST = ModelSettings(q_chunk=16, kv_chunk=16, ce_chunk=32, remat="none",
                   compute_dtype=jnp.float32)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_loss_finite(name):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, ST)
    )(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    assert float(loss) > 0
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_grads_finite(name):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, seed=1)

    def loss_fn(p):
        return lm_loss(p, batch, cfg, ST)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat, _ = jax.tree_util.tree_flatten(grads)
    for g in flat:
        assert np.isfinite(np.asarray(g)).all()
    # at least some gradient signal reaches the embedding
    assert float(jnp.max(jnp.abs(grads["embed"]))) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 32
    cache = cache_spec(cfg, B, S, dtype=jnp.float32, mode="zeros")
    token = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(p, c, t, jnp.int32(3), cfg, ST)
    )(params, cache, token)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)
    for a, b in zip(jax.tree_util.tree_leaves(new_cache), jax.tree_util.tree_leaves(cache)):
        assert a.shape == b.shape


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill(name):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(3))
    batch = make_batch(cfg)
    logits = jax.jit(
        lambda p, b: prefill(p, b["tokens"], cfg, ST, enc_inputs=b.get("frames"))
    )(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_counts_match_assignment():
    """Analytic parameter counts are in the advertised ballpark."""
    expect = {
        "smollm-135m": (0.10e9, 0.2e9),
        "granite-8b": (6e9, 9e9),
        "qwen2.5-32b": (28e9, 36e9),
        "qwen3-32b": (28e9, 36e9),
        "chameleon-34b": (30e9, 38e9),
        "rwkv6-7b": (6e9, 9e9),
        "olmoe-1b-7b": (5e9, 8.5e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),
        "whisper-small": (0.15e9, 0.4e9),
    }
    for name, (lo, hi) in expect.items():
        n = count_params(ARCHS[name])
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_active_params_moe():
    total = count_params(ARCHS["olmoe-1b-7b"])
    active = count_params(ARCHS["olmoe-1b-7b"], active_only=True)
    assert active < total * 0.35  # 64 experts, top-8 + attention
