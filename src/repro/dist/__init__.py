"""Distributed-systems utilities: parallel-write offsets and fault tolerance.

``offsets``  — exclusive prefix-sum (MPI_Exscan analogue) over compressed
shard sizes, the paper's collective that lets every writer seek to its slot
in the shared per-quantity file without coordination.
``fault``    — preemption handling, straggler detection and elastic
re-planning for a fleet that loses or regains devices mid-run.
"""
from .offsets import exclusive_offsets_np, exclusive_offsets_sharded  # noqa: F401
from .fault import (  # noqa: F401
    PreemptionHandler,
    StragglerReport,
    StragglerWatchdog,
    elastic_plan,
)
