"""``szx`` — SZ-style error-bounded predictive quantization, TPU-adapted.

SZ (Di & Cappello 2016) predicts each value from *reconstructed* neighbours
(Lorenzo predictor) and quantizes the residual — a serial data dependence.
We adopt the dual-quantization reformulation (the same one cuSZ uses on
GPUs): quantize first onto the 2*eps grid, then take the exact integer 3D
Lorenzo difference:

    q = round(x / (2 eps))           (int32)
    r = (I - Sx)(I - Sy)(I - Sz) q   (three axis-wise finite differences)

Encoding is three parallel diffs; decoding is three parallel inclusive
prefix sums (cumsum — TPU native).  The error bound |x - xhat| <= eps holds
*exactly*, like SZ's.  Residuals concentrate near zero and are entropy-coded
by the host stage 2 (int8 stream with escape marker + outlier list + ZLIB).

Prediction is block-local (CubismZ block independence): each (bs,bs,bs)
block is differenced independently, so blocks decompress in isolation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["encode", "decode", "lorenzo_fwd", "lorenzo_inv", "max_eps_ratio"]

# |q| must fit int32 with headroom for the 3D diff (factor <= 8).
_Q_LIMIT = 2 ** 27


def max_eps_ratio() -> float:
    """Smallest allowed eps relative to max|x|: eps >= max|x| / (2*_Q_LIMIT)."""
    return 1.0 / (2.0 * _Q_LIMIT)


def lorenzo_fwd(q):
    """3D Lorenzo residual over trailing three axes (exact int arithmetic)."""
    for ax in (-3, -2, -1):
        q = jnp.diff(q, axis=ax, prepend=jnp.zeros_like(jnp.take(q, jnp.asarray([0]), axis=ax)))
    return q


def lorenzo_inv(r):
    """Inverse: inclusive cumsum along each axis (wrapping int arithmetic)."""
    for ax in (-1, -2, -3):
        r = jnp.cumsum(r, axis=ax, dtype=r.dtype)
    return r


@functools.partial(jax.jit, static_argnames=("eps",))
def encode(blocks, eps: float = 1e-3):
    """blocks (B, n, n, n) float32 -> int32 Lorenzo residuals (B, n, n, n).

    Quantization uses a compensated two-step refinement: fp32 rounding of
    ``x / 2eps`` can shift the rounding decision when |q| is large, so after
    the first round we re-quantize the reconstruction residual.  This keeps
    |x - q*2eps| <= eps up to one ulp of x (tested with hypothesis).
    """
    x = jnp.asarray(blocks, jnp.float32)
    inv = 1.0 / (2.0 * eps)
    q = jnp.round(x * inv)
    err = x - q * (2.0 * eps)
    q = (q + jnp.round(err * inv)).astype(jnp.int32)
    return lorenzo_fwd(q)


@functools.partial(jax.jit, static_argnames=("eps",))
def decode(residuals, eps: float = 1e-3):
    q = lorenzo_inv(residuals)
    return q.astype(jnp.float32) * (2.0 * eps)


def check_eps(fields_absmax: float, eps: float) -> None:
    if eps <= 0:
        raise ValueError("szx requires eps > 0 (error-bounded lossy codec)")
    if fields_absmax / (2.0 * eps) >= _Q_LIMIT:
        raise ValueError(
            f"eps={eps} too small for data with max|x|={fields_absmax}: "
            f"quantized values would overflow int32 (need eps >= "
            f"{fields_absmax * max_eps_ratio():.3e})"
        )
