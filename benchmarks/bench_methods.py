"""Fig. 7/8 — PSNR vs CR for wavelets / zfpx / szx / fpzipx across QoIs,
timesteps and resolutions — plus the ratio-at-bound frontier for the
``auto`` meta-scheme.

Expected reproductions: no single method dominates; zfpx strongest on a2;
wavelets competitive in the visualization band; higher resolution improves
the wavelet CR more than the others.  The frontier turns "no single method
dominates" into a feature: on a heterogeneous field, ``auto`` (per-chunk
winner selection under an explicit abs/rel/psnr target) must achieve a
compression ratio at least as good as the best *fixed* scheme held to the
same per-chunk bound contract — asserted hard, for all three target modes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CompressionSpec, Pipeline
from repro.core import blocks as blk
from repro.fields import CloudConfig, cavitation_fields

from .common import dataset, emit, eps_sweep, save_json, sweep


def _specs_for(scheme: str, eps_list):
    if scheme == "wavelet":
        return [CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=e)
                for e in eps_list]
    if scheme in ("zfpx", "szx"):
        return [CompressionSpec(scheme=scheme, eps=e) for e in eps_list]
    # fpzipx sweeps bits of precision instead of eps
    return [CompressionSpec(scheme="fpzipx", precision=p)
            for p in (28, 24, 20, 16, 12, 8)[: len(eps_list)]]


_FRONTIER_BS = 8
_FRONTIER_BUF = 1 << 13  # 4 blocks per chunk at 8^3 float32


def _hetero_field(n: int = 48) -> np.ndarray:
    """Multi-regime field, regimes aligned to 8-deep x-slabs: near-constant,
    oscillatory, incompressible hash-noise, and a *high-magnitude* band
    (values ~4e5 — beyond szx/lorenzo's quantizer range at tight eps, so a
    fixed error-bounded scheme cannot hold the target everywhere) over a
    smooth base — the setting where per-chunk winner selection beats any
    fixed scheme.  Analytic + hashed-index noise: reproducible without an
    RNG."""
    g = np.mgrid[0:n, 0:n, 0:n].astype(np.float32) / n
    f = 1.0 + 0.5 * np.sin(4 * g[0]) * np.cos(3 * g[1]) + g[2]
    idx = np.arange(n ** 3, dtype=np.uint32).reshape(n, n, n)
    h = ((idx * np.uint32(2654435761)) >> np.uint32(20)).astype(np.float32)
    f[:8] = 0.25 + g[2][:8] * 1e-3                           # near-constant
    f[8:16] += 0.3 * np.sin(40 * g[0][8:16]) * np.sin(37 * g[1][8:16])
    f[16:24] = h[16:24] / 2048.0 - 0.5                        # hash noise
    f[24:32] = 3e5 + 1e5 * np.sin(3 * g[0][24:32]) * np.cos(2 * g[1][24:32])
    return f.astype(np.float32)


def _strictest_chunk_bound(field: np.ndarray, target) -> float:
    """The tightest per-chunk abs bound the target implies on this field —
    the bound a *fixed* scheme with one global eps must be held to so the
    comparison against ``auto`` is bound-for-bound fair."""
    blocks = np.asarray(blk.blockify(field, _FRONTIER_BS))
    bpc = max(1, _FRONTIER_BUF // (4 * _FRONTIER_BS ** 3))
    bounds = []
    for lo in range(0, blocks.shape[0], bpc):
        c = blocks[lo:lo + bpc]
        bounds.append(target.abs_bound(float(c.min()), float(c.max())))
    return min(bounds)


def _frontier(quick: bool) -> list[dict]:
    """Ratio-at-bound frontier: for each target mode, auto vs every fixed
    scheme that can honour the same per-chunk bound contract."""
    from repro.core.schemes import SCHEMES
    from repro.tune import Target, candidate_spec

    field = _hetero_field(32 if quick else 48)
    base = CompressionSpec(scheme="auto", block_size=_FRONTIER_BS,
                           buffer_bytes=_FRONTIER_BUF)
    rows = []
    for tgt in ("abs=1e-3", "rel=1e-4", "psnr=80"):
        target = Target.parse(tgt)
        strict = _strictest_chunk_bound(field, target)
        arms = {}
        for name in sorted(SCHEMES):
            if name == "auto":
                continue
            cand = candidate_spec(name, base, strict)
            if cand is None:
                continue  # cannot meet the bound (or rejects the dtype)
            try:
                r = Pipeline(cand).analyze(field)
            except ValueError:
                # the scheme's declared bound fits but its encoder rejects
                # the field at this eps (szx/lorenzo quantizer range on the
                # high-magnitude band): a fixed arm that cannot encode
                # everywhere is out of the frontier — auto routes around it
                rows.append({"target": tgt, "scheme": name, "eps": cand.eps,
                             "cr": None, "psnr": None, "max_err": None})
                continue
            arms[name] = r["cr"]
            rows.append({"target": tgt, "scheme": name, "eps": cand.eps,
                         "cr": r["cr"], "psnr": r["psnr"],
                         "max_err": r["max_err"]})
        aspec = CompressionSpec(scheme="auto", block_size=_FRONTIER_BS,
                                buffer_bytes=_FRONTIER_BUF,
                                extra={"target": tgt})
        r = Pipeline(aspec).analyze(field)
        rows.append({"target": tgt, "scheme": "auto", "eps": None,
                     "cr": r["cr"], "psnr": r["psnr"],
                     "max_err": r["max_err"]})
        best_fixed = max(arms, key=arms.get)
        # the acceptance bar: self-driving selection dominates every fixed
        # scheme held to the same bound, in every target mode
        assert r["cr"] >= arms[best_fixed], (
            f"auto CR {r['cr']:.2f} < best fixed {best_fixed} "
            f"{arms[best_fixed]:.2f} at target {tgt}")
        emit(f"frontier_{target.mode}_auto_vs_{best_fixed}",
             0.0, round(r["cr"] / arms[best_fixed], 3))
    return rows


def run(quick: bool = True):
    eps_list = eps_sweep(n=4 if quick else 7)
    qois = ["p", "a2"] if quick else ["p", "rho", "E", "a2"]
    t_labels = ["10k"] if quick else ["5k", "10k"]
    rows = []
    t0 = time.time()
    for tl in t_labels:
        fields = dataset(tl)
        for q in qois:
            for scheme in ("wavelet", "zfpx", "szx", "fpzipx"):
                for spec, r in zip(_specs_for(scheme, eps_list),
                                   sweep(fields[q], _specs_for(scheme, eps_list))):
                    rows.append({"t": tl, "qoi": q, "scheme": scheme,
                                 "eps": spec.eps, "precision": spec.precision,
                                 "cr": r["cr"], "psnr": r["psnr"]})
    # Fig. 8: resolution effect (wavelets gain with resolution)
    res_rows = []
    if not quick:
        for n in (64, 128, 192):
            f = cavitation_fields(CloudConfig(n=n), 9.4)["p"]
            for scheme in ("wavelet", "zfpx", "szx"):
                spec = _specs_for(scheme, [1e-3])[0]
                r = sweep(f, [spec])[0]
                res_rows.append({"n": n, "scheme": scheme, "cr": r["cr"],
                                 "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig7_methods", rows)
    if res_rows:
        save_json("fig8_resolution", res_rows)

    # no-single-winner check + zfpx wins a2
    winners = set()
    for q in qois:
        sub = [r for r in rows if r["qoi"] == q and r["t"] == t_labels[-1]]
        best = max(sub, key=lambda r: r["cr"] if r["psnr"] > 40 else -1)
        winners.add(best["scheme"])
    emit("fig7_distinct_winners", dt * 1e6 / max(len(rows), 1), len(winners))
    a2 = [r for r in rows if r["qoi"] == "a2" and r["t"] == t_labels[-1]]
    besta2 = max(a2, key=lambda r: r["cr"] if r["psnr"] > 40 else -1)
    emit("fig7_best_on_a2", dt * 1e6 / max(len(rows), 1), besta2["scheme"])

    frontier = _frontier(quick)
    save_json("methods_frontier", frontier)
    return {"frontier": frontier}


if __name__ == "__main__":
    import argparse

    from .common import write_bench_record

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized sweep (the harness default)")
    args = ap.parse_args()
    metrics = run(quick=args.quick)
    write_bench_record("methods", {"quick": args.quick}, metrics)
