"""HTTP front for :class:`FieldRegionServer` — stdlib only, no new deps.

The post-hoc region-access pattern, network-facing: analysts pull arbitrary
subdomains out of a compressed CZDataset over plain ``GET``, the way Zarr
grew an HTTP fetch path over its chunk store.

Endpoints
---------

``GET /v1/region/{quantity}/{t}?lo=x,y,z&hi=x,y,z[&format=raw|npy]``
    The decoded box ``[lo, hi)``.  ``raw`` (default) streams C-order bytes
    with ``X-CZ-Shape`` / ``X-CZ-Dtype`` headers; ``npy`` (also selected by
    ``Accept: application/x-npy``) wraps the same bytes in the self-
    describing ``.npy`` container.
``GET /v1/manifest``
    Dataset summary JSON — the same serializer as
    ``cz-compress inspect --json``.
``GET /healthz``
    Liveness probe (``200 ok``).
``GET /metrics``
    Metrics exposition: query count, request-latency histogram, region-
    and chunk-cache hits/misses, bytes decoded vs bytes served, coalesced
    flights, tail-sampling counters, responses by status code.  Content
    negotiated: scrapers whose ``Accept`` header names
    ``application/openmetrics-text`` (Prometheus does by default) get an
    OpenMetrics 1.0 document whose latency buckets carry exemplars
    pointing at kept tail traces; everyone else gets plain text format
    0.0.4, exemplar-free — the legacy parser rejects exemplar syntax.
``GET /debug/traces``
    Tail-sampled trace retention: summaries of every kept trace (errored
    or slow-tail requests only) plus sampler stats.
``GET /debug/traces/{request_id}[?format=chrome]``
    One kept trace in full — ``format=chrome`` re-shapes it as a Chrome
    trace-event document loadable in Perfetto.
``GET /debug/events[?n=50]``
    The tail of the in-process structured event ring.

Request correlation: every response carries an ``X-CZ-Request-Id`` header
— minted per request, or echoed from the client's own header when it sends
a well-formed one — and the same ID is stamped on every span and event the
request touches, kept tail traces included.

Concurrency: one thread per connection (``ThreadingHTTPServer``) with a
bounded decode-admission semaphore (``max_inflight``), and all duplicate
work coalesced by the region server's tiered cache + single-flight
scheduler — N clients hammering one hot region cost one decode.
"""
from __future__ import annotations

import collections
import io
import json
import socket
import threading
import time
from http.client import HTTPConnection, HTTPException
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro import obs
from repro.obs import context as _context
from repro.obs import events as _events
from repro.obs.sampling import chrome_trace
from repro.store.backends import open_store

from .region import FieldRegionServer

__all__ = ["RegionHTTPServer", "Client", "render_metrics", "main"]


def render_metrics(region: FieldRegionServer,
                   responses: dict[int, int] | None = None,
                   openmetrics: bool = False) -> str:
    """Text-exposition rendering of one region server's counters, through
    :class:`repro.obs.Registry` — Prometheus 0.0.4 by default, OpenMetrics
    1.0 (with latency-bucket exemplars) when ``openmetrics`` is set.

    A fresh registry is assembled per scrape from the server's counter
    snapshot — registration order reproduces the historical hand-rolled
    exposition name-for-name (pinned by the parity test in
    ``tests/test_obs.py``) — and the server's live ``LatencyHistogram`` is
    registered directly, so the latency buckets are exposed without a copy.
    """
    s = region.stats()
    reg = obs.Registry()

    def counter(name, help_, value):
        reg.counter(name, help_).set_total(value)

    counter("cz_serve_queries_total",
            "Region queries answered.", s["queries"])
    counter("cz_serve_bytes_served_total",
            "Decoded bytes returned to clients.", s["bytes_served"])
    counter("cz_serve_bytes_decoded_total",
            "Bytes inflated from compressed chunks (cache misses only).",
            s["bytes_decoded"])
    counter("cz_serve_region_cache_hits_total",
            "Queries answered from the decoded-region LRU.",
            s["region_cache_hits"])
    counter("cz_serve_region_cache_misses_total",
            "Queries that had to assemble their box.",
            s["region_cache_misses"])
    counter("cz_serve_region_cache_evictions_total",
            "Regions evicted from the decoded-region LRU.",
            s["region_cache_evictions"])
    reg.gauge("cz_serve_region_cache_bytes",
              "Bytes resident in the decoded-region LRU."
              ).set(s["region_cache_bytes"])
    counter("cz_serve_chunk_cache_hits_total",
            "Chunk fetches served by the store's chunk LRUs.",
            s["cache_hits"])
    counter("cz_serve_chunk_cache_misses_total",
            "Chunk fetches that decoded (== chunks decoded).",
            s["cache_misses"])
    counter("cz_serve_chunks_decoded_total",
            "Chunks inflated since the server started.", s["chunks_decoded"])
    counter("cz_serve_coalesced_requests_total",
            "Chunk fetches that joined another request's in-flight decode.",
            s["flights_joined"])
    reg.register(region.latency)  # live cz_serve_request_seconds histogram
    if getattr(region, "sampler", None) is not None:
        counter("cz_serve_traces_sampled_total",
                "Requests whose tail-sampling keep/drop decision ran.",
                s["trace_sampled"])
        kept = reg.counter("cz_serve_traces_kept_total",
                           "Tail traces kept, by reason.",
                           labelnames=("reason",))
        kept.set_total(s["trace_kept_error"], reason="error")
        kept.set_total(s["trace_kept_slow"], reason="slow")
        counter("cz_serve_traces_evicted_total",
                "Kept traces evicted by the byte budget.",
                s["trace_evicted"])
        reg.gauge("cz_serve_trace_bytes",
                  "Bytes of tail traces currently retained."
                  ).set(s["trace_bytes"])
    if responses is not None:
        resp = reg.counter("cz_serve_http_responses_total",
                           "HTTP responses by status code.",
                           labelnames=("code",))
        for code in sorted(responses):
            resp.set_total(responses[code], code=code)
    return reg.render(openmetrics=openmetrics)


class _RegionHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "cz-serve/1.0"

    # -- plumbing ----------------------------------------------------------

    def log_message(self, fmt, *args):  # quiet by default; opt-in via server
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def handle(self):
        try:
            super().handle()
        except (BrokenPipeError, ConnectionResetError):
            # a dropped client is routine, not a server error worth a
            # socketserver traceback (e.g. RST between keep-alive requests)
            self.close_connection = True

    def _send(self, code: int, body: bytes, ctype: str,
              headers: dict | None = None) -> None:
        self._responded = True
        self._status = code
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        rid = getattr(self, "_rid", None)
        if rid is not None:
            self.send_header("X-CZ-Request-Id", rid)
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)
        self.server._count_response(code)

    def _json(self, code: int, obj) -> None:
        self._send(code, json.dumps(obj).encode(),
                   "application/json; charset=utf-8")

    def _error(self, code: int, msg: str) -> None:
        if getattr(self, "_responded", False):
            # a response already started (e.g. the write itself failed):
            # a second status line would corrupt the stream — just hang up
            self.close_connection = True
            return
        try:
            self._json(code, {"error": msg})
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True

    # -- routes ------------------------------------------------------------

    def do_GET(self):  # noqa: N802 (http.server API)
        self._responded = False
        self._status = 0
        url = urlparse(self.path)
        sampler = getattr(self.server.region, "sampler", None)
        rid = _context.clean_id(self.headers.get("X-CZ-Request-Id"))
        t0 = time.perf_counter()
        with _context.request(rid, collect=sampler is not None) as ctx:
            self._rid = ctx.rid
            try:
                if url.path == "/healthz":
                    self._send(200, b"ok\n", "text/plain; charset=utf-8")
                elif url.path == "/metrics":
                    om = ("application/openmetrics-text"
                          in self.headers.get("Accept", ""))
                    body = render_metrics(
                        self.server.region,
                        self.server.response_counts(),
                        openmetrics=om).encode()
                    self._send(200, body,
                               "application/openmetrics-text; "
                               "version=1.0.0; charset=utf-8" if om else
                               "text/plain; version=0.0.4; charset=utf-8")
                elif url.path == "/v1/manifest":
                    self._json(200, self.server.region.manifest())
                elif url.path.startswith("/v1/region/"):
                    self._region(url)
                elif url.path.startswith("/debug/"):
                    self._debug(url)
                else:
                    self._error(404, f"no route {url.path}")
            except (BrokenPipeError, ConnectionResetError):
                self.close_connection = True  # client went away mid-response
            except KeyError as e:
                self._error(404, str(e.args[0]) if e.args else str(e))
            except ValueError as e:
                self._error(400, str(e))
            except Exception as e:  # a bug must not kill the thread pool
                self._error(500, f"{type(e).__name__}: {e}")
            dt = time.perf_counter() - t0
            code = self._status
            _events.event("http.request",
                          level=("error" if code >= 500
                                 else "warn" if code >= 400 else "info"),
                          method="GET", path=url.path, code=code,
                          dur_ms=round(dt * 1e3, 3))
            if sampler is not None and code >= 400:
                # HTTP-layer failures (bad params, unknown routes) never
                # reach query(); finalize them here — the per-context latch
                # keeps this a no-op when query() already decided
                sampler.finish(ctx, dt, error=f"http {code}")

    def do_POST(self):  # noqa: N802
        self._responded = False
        # drain the request body first, or the unread bytes desynchronize
        # this keep-alive connection (they'd parse as the next request line)
        if self.headers.get("Transfer-Encoding"):
            self.close_connection = True
        else:
            length = int(self.headers.get("Content-Length") or 0)
            while length > 0:
                got = self.rfile.read(min(length, 1 << 16))
                if not got:
                    break
                length -= len(got)
        self._error(405, "read-only service: GET only")

    do_PUT = do_DELETE = do_PATCH = do_POST

    def _region(self, url) -> None:
        parts = url.path.split("/")  # ['', 'v1', 'region', quantity, t]
        if len(parts) != 5 or not parts[3] or not parts[4]:
            raise ValueError("expected /v1/region/{quantity}/{t}")
        quantity = parts[3]
        try:
            t = int(parts[4])
        except ValueError:
            raise ValueError(f"timestep must be an integer, got {parts[4]!r}")
        q = parse_qs(url.query)

        def vec(name):
            if name not in q:
                raise ValueError(f"missing query parameter {name}=x,y,z")
            try:
                v = tuple(int(x) for x in q[name][-1].split(","))
            except ValueError:
                raise ValueError(f"{name} must be comma-separated integers")
            if len(v) != 3:
                raise ValueError(f"{name} must have 3 components")
            return v

        lo, hi = vec("lo"), vec("hi")
        fmt = q.get("format", ["raw"])[-1]
        if fmt not in ("raw", "npy"):
            raise ValueError(f"unknown format {fmt!r} (raw or npy)")
        if "application/x-npy" in self.headers.get("Accept", ""):
            fmt = "npy"

        arr = self.server.region.query(quantity, t, lo, hi, copy=False)
        if fmt == "npy":
            buf = io.BytesIO()
            np.lib.format.write_array(buf, np.asarray(arr),
                                      allow_pickle=False)
            self._send(200, buf.getvalue(), "application/x-npy")
        else:
            self._send(200, arr.tobytes(), "application/octet-stream",
                       headers={
                           "X-CZ-Shape": ",".join(map(str, arr.shape)),
                           "X-CZ-Dtype": str(arr.dtype),
                       })

    def _debug(self, url) -> None:
        q = parse_qs(url.query)
        if url.path == "/debug/events":
            try:
                n = int(q.get("n", ["50"])[-1])
            except ValueError:
                raise ValueError("n must be an integer")
            self._json(200, {"events": _events.tail(n)})
            return
        sampler = getattr(self.server.region, "sampler", None)
        if sampler is None:
            raise KeyError("tail sampling is disabled on this server")
        if url.path == "/debug/traces":
            self._json(200, {"traces": sampler.traces(),
                             "stats": sampler.stats()})
            return
        parts = url.path.split("/")  # ['', 'debug', 'traces', request_id]
        if len(parts) != 4 or parts[2] != "traces" or not parts[3]:
            raise ValueError("expected /debug/traces[/{request_id}]")
        rec = sampler.get(parts[3])  # KeyError -> 404
        fmt = q.get("format", ["json"])[-1]
        if fmt == "chrome":
            self._json(200, chrome_trace(rec))
        elif fmt == "json":
            self._json(200, rec)
        else:
            raise ValueError(f"unknown format {fmt!r} (json or chrome)")


class RegionHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP server over one :class:`FieldRegionServer`.

    ``dataset`` is a path (opened and owned), a ``CZDataset`` (borrowed), or
    an existing ``FieldRegionServer`` (borrowed — its caches, counters, and
    admission policy are shared with in-process callers, so ``cache_*`` and
    ``max_inflight`` are ignored).  ``port=0`` binds an ephemeral loopback
    port (tests, benchmarks).  ``max_inflight`` bounds concurrent region
    *decodes* (cache hits never queue behind them) — the admission-control
    knob surfaced as ``--workers`` on the CLI.
    """

    daemon_threads = True

    def __init__(self, dataset, host: str = "127.0.0.1", port: int = 8423,
                 cache_bytes: int = 64 << 20, cache_readers: int = 16,
                 cache_chunks: int = 32, max_inflight: int = 8,
                 verbose: bool = False, sample: bool = True,
                 trace_budget_bytes: int = 4 << 20,
                 trace_slow_ms: float | None = None,
                 prefetch: int = 0):
        self._owns_region = not isinstance(dataset, FieldRegionServer)
        self.region = (FieldRegionServer(dataset, cache_readers=cache_readers,
                                         cache_chunks=cache_chunks,
                                         cache_bytes=cache_bytes,
                                         max_inflight=max(1, int(max_inflight)),
                                         sample=sample,
                                         trace_budget_bytes=trace_budget_bytes,
                                         trace_slow_ms=trace_slow_ms,
                                         prefetch=prefetch)
                       if self._owns_region else dataset)
        self.verbose = verbose
        self._responses = collections.Counter()
        self._resp_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._conns: set = set()
        self._conn_lock = threading.Lock()
        self.closed = False
        try:
            super().__init__((host, port), _RegionHandler)
        except Exception:
            if self._owns_region:
                self.region.close()  # don't leak the dataset on a bind error
            raise

    # -- introspection -----------------------------------------------------

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def _count_response(self, code: int) -> None:
        with self._resp_lock:
            self._responses[int(code)] += 1

    def response_counts(self) -> dict[int, int]:
        with self._resp_lock:
            return dict(self._responses)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "RegionHTTPServer":
        """Serve on a daemon thread; returns self (``with`` friendly)."""
        self._thread = threading.Thread(target=self.serve_forever,
                                        name="cz-serve", daemon=True)
        self._thread.start()
        return self

    def get_request(self):
        request, addr = super().get_request()
        with self._conn_lock:
            self._conns.add(request)
        return request, addr

    def shutdown_request(self, request):
        with self._conn_lock:
            self._conns.discard(request)
        super().shutdown_request(request)

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        if self._thread is not None:
            self.shutdown()
            self._thread.join(timeout=5)
        self.server_close()
        # Sever lingering keep-alive connections so their handler threads
        # exit now — otherwise a client's pooled socket stays "alive" and
        # gets answered by a zombie handler over a closed dataset.
        with self._conn_lock:
            stale = list(self._conns)
            self._conns.clear()
        for request in stale:
            try:
                request.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                request.close()
            except OSError:
                pass
        if self._owns_region:
            self.region.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class Client:
    """Minimal stdlib client for the region service (tests, examples,
    benchmarks).  One persistent connection per instance — use one Client
    per thread."""

    def __init__(self, url: str, timeout: float = 30.0):
        u = urlparse(url if "//" in url else f"http://{url}")
        self.host, self.port = u.hostname, u.port or 80
        self.timeout = timeout
        self._conn: HTTPConnection | None = None

    def _request(self, path: str,
                 headers: dict | None = None) -> tuple[int, dict, bytes]:
        """The single retry-once helper **every** client GET goes through
        (`/v1/region`, `/v1/manifest`, `/metrics`, `/debug/*`, `/healthz`):
        a request that trips over a stale keep-alive connection — the server
        restarted or idle-timed the socket since the last call — is replayed
        once on a fresh connection.  Safe because the API surface is
        idempotent GETs.  ``http.client`` faults (``CannotSendRequest``
        after a half-drained response, ``BadStatusLine`` on a torn reply)
        get the same treatment as socket-level ``OSError``s: both mean
        "this connection is dead", not "this request failed"."""
        for attempt in (0, 1):
            if self._conn is None:
                self._conn = HTTPConnection(self.host, self.port,
                                            timeout=self.timeout)
            try:
                self._conn.request("GET", path, headers=headers or {})
                r = self._conn.getresponse()
                return r.status, dict(r.getheaders()), r.read()
            except (HTTPException, ConnectionError, OSError):
                self.close()
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def _ok(self, path: str, headers: dict | None = None
            ) -> tuple[dict, bytes]:
        status, headers, body = self._request(path, headers)
        if status != 200:
            try:
                msg = json.loads(body)["error"]
            except Exception:
                msg = body.decode(errors="replace")
            raise IOError(f"GET {path} -> {status}: {msg}")
        return headers, body

    def region(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        """Fetch one region as a numpy array (``.npy`` wire format)."""
        path = (f"/v1/region/{quantity}/{int(t)}"
                f"?lo={','.join(str(int(v)) for v in lo)}"
                f"&hi={','.join(str(int(v)) for v in hi)}&format=npy")
        _, body = self._ok(path)
        return np.lib.format.read_array(io.BytesIO(body), allow_pickle=False)

    def region_raw(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        """Fetch one region over the raw-bytes wire format (shape/dtype from
        the ``X-CZ-*`` headers)."""
        path = (f"/v1/region/{quantity}/{int(t)}"
                f"?lo={','.join(str(int(v)) for v in lo)}"
                f"&hi={','.join(str(int(v)) for v in hi)}")
        headers, body = self._ok(path)
        shape = tuple(int(v) for v in headers["X-CZ-Shape"].split(","))
        return np.frombuffer(body, dtype=headers["X-CZ-Dtype"]).reshape(shape)

    def manifest(self) -> dict:
        return json.loads(self._ok("/v1/manifest")[1])

    def metrics(self, openmetrics: bool = False) -> str:
        """The ``/metrics`` exposition — 0.0.4 text by default;
        ``openmetrics=True`` negotiates the OpenMetrics document (the one
        carrying latency-bucket exemplars)."""
        hdrs = ({"Accept": "application/openmetrics-text; version=1.0.0"}
                if openmetrics else None)
        return self._ok("/metrics", hdrs)[1].decode()

    def metrics_dict(self) -> dict[str, list[tuple[dict, float]]]:
        """Parsed ``/metrics``: ``{name: [(labels, value), ...]}`` (histogram
        sub-series under their exposed ``_bucket``/``_sum``/``_count``
        names) — the structured alternative to grepping exposition text."""
        return obs.parse_prometheus(self.metrics())

    def metric(self, name: str, labels: dict | None = None) -> float:
        """One sample out of :meth:`metrics` (convenience for tests and
        benchmarks).  Without ``labels`` the metric's un-labelled sample is
        returned; with a label dict, the unique sample whose labels contain
        every given pair (``KeyError`` if none match, ``ValueError`` if the
        match is ambiguous)."""
        samples = self.metrics_dict().get(name)
        if not samples:
            raise KeyError(name)
        if labels is None:
            for lbl, val in samples:
                if not lbl:
                    return val
            raise KeyError(f"{name} has no un-labelled sample "
                           f"(labelled: {[lbl for lbl, _ in samples]})")
        want = {k: str(v) for k, v in labels.items()}
        hits = [val for lbl, val in samples
                if all(lbl.get(k) == v for k, v in want.items())]
        if not hits:
            raise KeyError(f"{name} has no sample matching {labels}")
        if len(hits) > 1:
            raise ValueError(f"{name}: labels {labels} match "
                             f"{len(hits)} samples — add more labels")
        return hits[0]

    def traces(self) -> dict:
        """The ``/debug/traces`` listing: kept tail traces + sampler
        stats."""
        return json.loads(self._ok("/debug/traces")[1])

    def trace(self, request_id: str, chrome: bool = False) -> dict:
        """One kept tail trace in full (``chrome=True`` fetches the
        Perfetto-loadable reshaping)."""
        path = f"/debug/traces/{request_id}"
        if chrome:
            path += "?format=chrome"
        return json.loads(self._ok(path)[1])

    def events(self, n: int = 50) -> list[dict]:
        """The tail of the server's structured event ring."""
        return json.loads(self._ok(f"/debug/events?n={int(n)}")[1])["events"]

    def healthz(self) -> bool:
        return self._request("/healthz")[0] == 200

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def main(argv=None) -> int:
    """``cz-compress serve`` — serve a CZDataset over HTTP."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="cz-compress serve",
        description="HTTP region-query service over a CZDataset: "
                    "/v1/region, /v1/manifest, /healthz, /metrics.")
    ap.add_argument("dataset", help="CZDataset directory or store URL "
                    "(file://, mem://, http://, any registered backend)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8423,
                    help="0 picks an ephemeral port (printed on start)")
    ap.add_argument("--cache-mb", type=float, default=64.0,
                    help="decoded-region LRU budget in MiB (0 disables)")
    ap.add_argument("--workers", type=int, default=8,
                    help="max concurrent region decodes (admission control)")
    ap.add_argument("--cache-readers", type=int, default=16,
                    help="pooled FieldReaders kept open")
    ap.add_argument("--cache-chunks", type=int, default=32,
                    help="LRU chunk slots per reader")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per request")
    ap.add_argument("--trace", metavar="OUT.json",
                    help="collect spans while serving and write a Chrome "
                         "trace (view in Perfetto) on shutdown")
    ap.add_argument("--no-sample", action="store_true",
                    help="disable always-on tail-based trace sampling")
    ap.add_argument("--trace-budget-mb", type=float, default=4.0,
                    help="byte budget for kept tail traces (MiB)")
    ap.add_argument("--trace-slow-ms", type=float, default=None,
                    help="fixed slow-trace threshold in ms (default: track "
                         "the live p99 of request latency)")
    ap.add_argument("--events", metavar="OUT.jsonl",
                    help="append structured events as JSON lines to a file")
    ap.add_argument("--prefetch", type=int, default=0, metavar="N",
                    help="chunks each reader fetches ahead of decode during "
                         "a region query (0 = off; worth 2-8 over remote "
                         "stores)")
    ap.add_argument("--retries", type=int, default=None, metavar="N",
                    help="store-level retries on transient faults (default: "
                         "2 for remote backends like http://, 0 otherwise; "
                         "0 disables)")
    ap.add_argument("--timeout", type=float, default=None, metavar="SEC",
                    help="per-request store socket timeout and retry "
                         "deadline (default: backend's own)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.trace.enable()
    if args.events:
        _events.configure(path=args.events)
    # resolve the root here (rather than inside CZDataset) when a policy
    # knob is set, so the retry/timeout wrapping is applied exactly once
    dataset = args.dataset
    if args.retries is not None or args.timeout is not None:
        dataset = open_store(dataset, retries=args.retries,
                             timeout=args.timeout)
    srv = RegionHTTPServer(dataset, host=args.host, port=args.port,
                           cache_bytes=int(args.cache_mb * 2**20),
                           cache_readers=args.cache_readers,
                           cache_chunks=args.cache_chunks,
                           max_inflight=args.workers, verbose=args.verbose,
                           sample=not args.no_sample,
                           trace_budget_bytes=int(args.trace_budget_mb
                                                  * 2**20),
                           trace_slow_ms=args.trace_slow_ms,
                           prefetch=args.prefetch)
    qs = ", ".join(srv.region.ds.quantities) or "(empty)"
    print(f"serving {args.dataset} [{qs}] at {srv.url}")
    print(f"  GET {srv.url}/v1/region/{{quantity}}/{{t}}?lo=x,y,z&hi=x,y,z")
    print(f"  GET {srv.url}/v1/manifest | /healthz | /metrics")
    if not args.no_sample:
        print(f"  GET {srv.url}/debug/traces | /debug/traces/{{id}} "
              f"| /debug/events")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        print("shutting down")
    finally:
        srv.close()
        if args.events:
            _events.LOG.close()
        if args.trace:
            obs.trace.disable()
            print(f"trace written to {obs.trace.save(args.trace)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
