"""repro.tune coverage: target parsing and bound math, candidate eps
inversion, deterministic sampling, the ``auto`` meta-scheme's per-chunk
bound contract in every target mode, the decision cache, and the CLI /
dataset surfaces that expose the per-chunk scheme mix."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import CODEC_FORMAT, CompressionSpec, container
from repro.core import blocks as blk
from repro.core.schemes import get_scheme
from repro.launch import compress as cli
from repro.store import CZDataset
from repro.tune import (DecisionPolicy, Target, candidate_spec,
                        chunk_signature, policy_for, sample_blocks,
                        target_from_spec)
from repro.tune import policy as policy_mod

N, BS = 16, 8
# 2 KiB buffer -> one 8^3 float32 block per chunk: every block gets its own
# tuning decision, so block-aligned regimes force a genuinely mixed container
AUTO_SPEC = CompressionSpec(scheme="auto", eps=1e-3, block_size=BS,
                            buffer_bytes=1 << 11)


def hetero_field() -> np.ndarray:
    """Block-raster-aligned regimes: constant, hash-noise, smooth."""
    g = np.mgrid[0:N, 0:N, 0:N].astype(np.float32) / N
    f = 2.0 + np.sin(5 * g[0]) * np.cos(4 * g[1]) + g[2]
    idx = np.arange(N ** 3, dtype=np.uint32).reshape(N, N, N)
    h = ((idx * np.uint32(2654435761)) >> np.uint32(20)).astype(np.float32)
    f[:BS, :BS, :] = 0.5
    f[BS:, BS:, :] = h[BS:, BS:, :] / 2048.0 - 1.0
    return f.astype(np.float32)


def chunks_of(field: np.ndarray, spec: CompressionSpec):
    blocks = np.asarray(blk.blockify(field, spec.block_size))
    bpc = max(1, spec.buffer_bytes // (4 * spec.block_size ** 3))
    return [blocks[lo:lo + bpc] for lo in range(0, blocks.shape[0], bpc)]


# ---------------------------------------------------------------------------
# Target: parsing, rendering, bound math
# ---------------------------------------------------------------------------

def test_target_parse_render_roundtrip():
    for text, mode, value in (("abs=1e-3", "abs", 1e-3),
                              ("rel=1e-4", "rel", 1e-4),
                              ("psnr=80", "psnr", 80.0),
                              (" psnr =80", "psnr", 80.0)):
        t = Target.parse(text)
        assert (t.mode, t.value) == (mode, value)
        assert Target.parse(str(t)) == t


@pytest.mark.parametrize("bad", ["", "abs", "abs=", "abs=nope", "snr=40",
                                 "abs=-1", "abs=0", "psnr=inf", "abs=nan"])
def test_target_rejects_malformed(bad):
    with pytest.raises(ValueError):
        Target.parse(bad)


def test_target_abs_bound_math():
    assert Target("abs", 2e-3).abs_bound(-5.0, 17.0) == 2e-3
    assert Target("rel", 1e-4).abs_bound(1.0, 3.0) == pytest.approx(2e-4)
    # psnr (paper Eq. 1) via the uniform-error model: a = rng*sqrt(3)/(2*10^(dB/20))
    got = Target("psnr", 80.0).abs_bound(0.0, 2.0)
    assert got == pytest.approx(2.0 * math.sqrt(3.0) / (2.0 * 1e4))
    # constant data: rel/psnr collapse to 0 -> only lossless stays admissible
    assert Target("rel", 1e-4).abs_bound(1.5, 1.5) == 0.0
    assert Target("psnr", 80.0).abs_bound(1.5, 1.5) == 0.0


def test_target_from_spec_default_is_abs_eps():
    spec = CompressionSpec(scheme="auto", eps=5e-4)
    assert target_from_spec(spec) == Target("abs", 5e-4)
    spec = CompressionSpec(scheme="auto", extra={"target": "psnr=60"})
    assert target_from_spec(spec) == Target("psnr", 60.0)


# ---------------------------------------------------------------------------
# candidate_spec: inverting each scheme's declared error_bound contract
# ---------------------------------------------------------------------------

def test_candidate_spec_inverts_declared_bounds():
    base = CompressionSpec(scheme="auto", block_size=BS)
    bound = 1e-3
    for name in ("wavelet", "zfpx", "szx", "lorenzo"):
        cand = candidate_spec(name, base, bound)
        assert cand is not None and cand.scheme == name
        got = get_scheme(name).error_bound(cand)
        assert got == pytest.approx(bound), (name, got)
        # the eps actually differs per scheme (szx eps=bound, wavelet 100x
        # tighter): the inversion is per-contract, not a copy
        assert cand.eps == pytest.approx(
            bound / get_scheme(name).error_bound(
                CompressionSpec(scheme=name, eps=1.0, block_size=BS)))


def test_candidate_spec_lossless_and_impossible():
    base = CompressionSpec(scheme="auto", block_size=BS)
    raw = candidate_spec("raw", base, 1e-3)
    assert raw is not None and get_scheme("raw").error_bound(raw) is None
    # a zero bound is unmeetable by any lossy scheme but fine for lossless
    assert candidate_spec("szx", base, 0.0) is None
    assert candidate_spec("raw", base, 0.0) is not None


# ---------------------------------------------------------------------------
# sampling: deterministic, content-independent stride
# ---------------------------------------------------------------------------

def test_sample_blocks_even_stride_includes_block_zero():
    blocks = np.arange(10 * 2, dtype=np.float32).reshape(10, 2)
    s = sample_blocks(blocks, max_blocks=4)
    np.testing.assert_array_equal(s, blocks[[0, 3, 6, 9]])
    # small chunks pass through whole
    np.testing.assert_array_equal(sample_blocks(blocks[:3], 4), blocks[:3])


# ---------------------------------------------------------------------------
# the auto scheme: per-chunk bound contract in every target mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tgt", ["abs=1e-3", "rel=1e-4", "psnr=80"])
def test_auto_roundtrip_holds_per_chunk_bound(tmp_path, tgt):
    field = hetero_field()
    spec = CompressionSpec(scheme="auto", block_size=BS,
                           buffer_bytes=1 << 11, extra={"target": tgt})
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, field, spec)
    dec = container.read_field(path)
    target = Target.parse(tgt)
    for orig, got in zip(chunks_of(field, spec), chunks_of(dec, spec)):
        bound = target.abs_bound(float(orig.min()), float(orig.max()))
        err = float(np.max(np.abs(orig.astype(np.float64)
                                  - got.astype(np.float64))))
        ulp = float(np.spacing(np.float32(np.abs(orig).max() or 1.0)))
        assert err <= bound * (1 + 1e-6) + ulp, (tgt, err, bound)


def test_auto_container_is_mixed_and_self_describing(tmp_path):
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, hetero_field(), AUTO_SPEC)
    d = container.describe(path, verify=True)
    assert d["crc_ok"] and d["format"] == CODEC_FORMAT
    assert len(d["schemes"]) >= 2, d["schemes"]
    assert sum(d["schemes"].values()) == len(d["chunks"])
    for row in d["chunks"]:
        assert row["scheme"] in d["schemes"] and row["eps"] > 0
    assert d["scheme_params"]["target"] == "abs=0.001"


def test_auto_mixed_container_region_read(tmp_path):
    """FieldReader must dispatch each chunk's own decoder on a partial read
    of a mixed-scheme container."""
    field = hetero_field()
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, field, AUTO_SPEC)
    lo, hi = (2, 1, 3), (14, 7, 12)  # x spans both halves, y only the first
    with container.FieldReader(path) as r:
        box = r.read_box(lo, hi)
        assert 0 < r.chunks_decoded < r.nchunks
    ref = field[lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]]
    assert np.max(np.abs(box - ref)) <= 1e-3 * (1 + 1e-6)


def test_auto_validate_rejects_bad_knobs():
    with pytest.raises(ValueError):
        CompressionSpec(scheme="auto", extra={"target": "snr=40"}).validate()
    with pytest.raises(ValueError):
        CompressionSpec(scheme="auto", extra={"tune_cache": -1}).validate()
    with pytest.raises(ValueError):
        CompressionSpec(scheme="auto", extra={"tune_cache": True}).validate()
    CompressionSpec(scheme="auto", extra={"target": "psnr=80",
                                          "tune_cache": 3}).validate()


def test_auto_error_bound_declaration():
    sch = get_scheme("auto")
    assert sch.error_bound(
        CompressionSpec(scheme="auto", eps=2e-3)) == 2e-3
    assert sch.error_bound(CompressionSpec(
        scheme="auto", extra={"target": "psnr=80"})) == float("inf")


# ---------------------------------------------------------------------------
# decision policy: trial-every-chunk default, opt-in signature cache
# ---------------------------------------------------------------------------

def test_chunk_signature_separates_regimes():
    rng = np.random.default_rng(7)
    a = rng.normal(0, 1.0, (4, BS ** 3)).astype(np.float32)
    assert chunk_signature(a) == chunk_signature(a.copy())
    assert chunk_signature(a) != chunk_signature(a * 4.0)  # 2 octaves apart
    assert chunk_signature(np.full((4, BS ** 3), 1.5, np.float32)) \
        != chunk_signature(a)


def test_policy_cache_hits_and_periodic_retrial(monkeypatch):
    calls = []
    real = policy_mod.run_trials
    monkeypatch.setattr(policy_mod, "run_trials",
                        lambda b, s, t: calls.append(1) or real(b, s, t))
    spec = CompressionSpec(scheme="auto", block_size=BS)
    chunk = np.linspace(0, 1, 2 * BS ** 3,
                        dtype=np.float32).reshape(2, BS, BS, BS)
    hits0 = policy_mod._CACHE_HITS.value()

    pol = DecisionPolicy(retrial_every=2)
    decisions = [pol.decide(chunk, spec, Target("abs", 1e-3))
                 for _ in range(4)]
    # occurrences 0 and 2 trial (first + periodic re-trial), 1 and 3 hit
    assert len(calls) == 2
    assert policy_mod._CACHE_HITS.value() - hits0 == 2
    assert all(d.winner == decisions[0].winner for d in decisions)

    # default policy (cache off) trials every chunk
    calls.clear()
    for _ in range(3):
        DecisionPolicy(0).decide(chunk, spec, Target("abs", 1e-3))
    assert len(calls) == 3


def test_policy_for_is_per_spec_and_tracks_the_knob():
    a = CompressionSpec(scheme="auto", block_size=BS,
                        extra={"tune_cache": 4})
    assert policy_for(a) is policy_for(a)
    assert policy_for(a).retrial_every == 4
    b = CompressionSpec(scheme="auto", block_size=BS)
    assert policy_for(b).retrial_every == 0
    assert policy_for(a) is not policy_for(b)


# ---------------------------------------------------------------------------
# CLI: tuning flags, inspect's chunk-mix surfaces
# ---------------------------------------------------------------------------

def test_cli_target_rejected_for_fixed_schemes(tmp_path, capsys):
    with pytest.raises(SystemExit) as e:
        cli.main(["--scheme", "szx", "--target", "abs=1e-3",
                  "--out", str(tmp_path)])
    assert e.value.code == 2
    assert "only apply to --scheme auto" in capsys.readouterr().err


def test_cli_auto_end_to_end_npy(tmp_path, capsys):
    npy = os.path.join(tmp_path, "in.npy")
    np.save(npy, hetero_field())
    cli.main(["--source", "npy", "--npy", npy, "--scheme", "auto",
              "--target", "rel=1e-4", "--block-size", str(BS),
              "--out", str(tmp_path)])
    capsys.readouterr()
    path = os.path.join(tmp_path, "field.cz")
    assert container.describe(path)["scheme_params"]["target"] == "rel=0.0001"
    with open(os.path.join(tmp_path, "report.json")) as f:
        assert json.load(f)["spec"]["extra"]["target"] == "rel=1e-4"


def test_cli_inspect_prints_chunk_mix(tmp_path, capsys):
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, hetero_field(), AUTO_SPEC)
    assert cli.inspect_main([path]) == 0
    out = capsys.readouterr().out
    assert "chunk mix" in out
    assert "scheme" in out  # the per-chunk column header
    for name, cnt in container.describe(path)["schemes"].items():
        assert f"{name} x{cnt}" in out

    assert cli.inspect_main(["--json", path]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["schemes"] == container.describe(path)["schemes"]
    assert all("scheme" in row for row in doc["chunks"])


def test_cli_inspect_fixed_scheme_has_no_mix_column(tmp_path, capsys):
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, hetero_field(),
                          CompressionSpec(scheme="szx", eps=1e-3,
                                          block_size=BS))
    assert cli.inspect_main([path]) == 0
    out = capsys.readouterr().out
    assert "chunk mix" not in out


# ---------------------------------------------------------------------------
# dataset tier: the scheme mix travels into the manifest
# ---------------------------------------------------------------------------

def test_dataset_auto_member_records_scheme_mix(tmp_path):
    root = os.path.join(tmp_path, "ds")
    field = hetero_field()
    with CZDataset(root, "a", spec=AUTO_SPEC) as ds:
        ds.append({"p": field}, time=9.4)
    with CZDataset(root) as ds:
        rec = ds.timestep_info("p")[0]
        assert len(rec["schemes"]) >= 2
        assert rec["schemes"] == \
            container.describe(rec["file"], verify=False,
                               store=ds.store)["schemes"]
        # and through the /v1/manifest serializer
        man = ds.describe()
        assert man["quantities"]["p"]["timesteps"][0]["schemes"] \
            == rec["schemes"]
        lo, hi = (3, 2, 4), (13, 12, 15)
        box = ds.read_box("p", 0, lo, hi)
        ref = field[lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]]
        assert np.max(np.abs(box - ref)) <= 1e-3 * (1 + 1e-6)
