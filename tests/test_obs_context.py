"""PR 8 acceptance: request-correlated observability end to end.

One request ID minted (or honored) at the HTTP front must show up in four
places at once — the ``X-CZ-Request-Id`` response header, the kept tail
trace at ``/debug/traces/{id}``, the structured event lines, and the
``/metrics`` latency-bucket exemplar — including the coalesced-duplicate
case where the follower's ID is recorded on the leader's flight span.
Plus unit coverage for the three new obs modules (context, events,
sampling)."""
import json
import threading
import time
from http.client import HTTPConnection

import numpy as np
import pytest

from repro import obs
from repro.core import CompressionSpec, Pipeline
from repro.obs import context as obs_context
from repro.obs import events as obs_events
from repro.obs.sampling import TailSampler, chrome_trace
from repro.serve import Client, RegionHTTPServer
from repro.store import CZDataset

N = 16
BS = 8
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 12)


@pytest.fixture(autouse=True)
def _tracer_off():
    obs.TRACER.disable()
    obs.TRACER.reset()
    yield
    obs.TRACER.disable()
    obs.TRACER.reset()


def _make_dataset(root):
    rng = np.random.default_rng(8)
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append({"p": rng.normal(size=(N, N, N)).astype(np.float32)},
                  time=0.0)
    return root


def _slow_decode(monkeypatch, seconds):
    orig = Pipeline.decompress_chunk

    def slow(self, *a, **k):
        time.sleep(seconds)
        return orig(self, *a, **k)

    monkeypatch.setattr(Pipeline, "decompress_chunk", slow)


def _get(srv, path, rid=None):
    """One GET returning (status, headers, parsed-or-raw body)."""
    host, port = srv.server_address[:2]
    conn = HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", path,
                     headers={"X-CZ-Request-Id": rid} if rid else {})
        r = conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# context unit coverage
# ---------------------------------------------------------------------------

def test_request_context_mint_honor_and_nest():
    assert obs_context.request_id() is None
    with obs_context.request() as outer:
        assert obs_context.request_id() == outer.rid
        assert len(outer.rid) == 16
        with obs_context.request("client-chosen") as inner:
            assert obs_context.request_id() == "client-chosen"
            assert inner.rid == "client-chosen"
        assert obs_context.request_id() == outer.rid  # token reset
    assert obs_context.request_id() is None


@pytest.mark.parametrize("raw,want", [
    ("abc-123.X_z", "abc-123.X_z"),
    ("  ok  ", None),            # embedded whitespace is not a clean ID
    ("", None),
    (None, None),
    ("bad id", None),            # spaces
    ("-leading", None),          # must start alphanumeric
    ("x" * 200, None),           # too long
])
def test_clean_id(raw, want):
    assert obs_context.clean_id(raw) == want


def test_context_collection_is_bounded():
    with obs_context.request(collect=True, max_events=4) as ctx:
        for i in range(10):
            with obs.span("work", i=i):
                pass
    assert len(ctx.events) == 4
    assert ctx.dropped == 6
    assert all(ev["args"]["rid"] == ctx.rid for ev in ctx.events)


def test_span_collects_into_context_without_tracer():
    assert not obs.TRACER.enabled
    with obs_context.request(collect=True) as ctx:
        with obs.span("inner", tag=7):
            pass
        t0 = time.perf_counter_ns()
        obs.trace.record("post", t0, t0 + 1000, tag=8)
    names = [ev["name"] for ev in ctx.events]
    assert names == ["inner", "post"]
    assert ctx.events[0]["args"]["tag"] == 7
    assert obs.TRACER.events() == []  # nothing leaked into the tracer


# ---------------------------------------------------------------------------
# events unit coverage
# ---------------------------------------------------------------------------

def test_event_log_levels_ring_and_jsonl(tmp_path):
    log = obs_events.EventLog(ring=3, level="info")
    path = tmp_path / "events.jsonl"
    log.configure(path=str(path))
    assert log.event("dropped", level="debug") is None
    with obs_context.request("evt-rid"):
        rec = log.event("served", level="warn", code=404, q="p")
    assert rec["request_id"] == "evt-rid" and rec["code"] == 404
    for i in range(4):
        log.event(f"e{i}")
    log.close()
    assert [r["event"] for r in log.tail(10)] == ["e1", "e2", "e3"]  # ring=3
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["event"] for ln in lines] == ["served", "e0", "e1", "e2", "e3"]
    assert lines[0]["request_id"] == "evt-rid"
    assert log.suppressed == 1 and log.emitted == 5


def test_event_log_survives_torn_sink(tmp_path):
    log = obs_events.EventLog()
    stream = open(tmp_path / "t.jsonl", "w")
    log.configure(stream=stream)
    stream.close()
    log.event("after-close")  # must not raise; sink silently dropped
    assert log.tail(1)[0]["event"] == "after-close"


# ---------------------------------------------------------------------------
# sampler unit coverage
# ---------------------------------------------------------------------------

def _finished_ctx(rid="t-0", nev=1):
    ctx = obs_context.RequestContext(rid, collect=True)
    for i in range(nev):
        t0 = time.perf_counter_ns()
        ctx.record("ev", t0, t0 + 5000, {"i": i})
    return ctx


def test_sampler_keeps_error_and_slow_only():
    hist = obs.Histogram("cz_t_lat_seconds", "t", buckets=(0.01, 0.1))
    s = TailSampler(hist, slow_s=0.05)
    assert s.finish(_finished_ctx("fast"), 0.001) is False
    assert s.finish(_finished_ctx("slow"), 0.2) is True
    assert s.finish(_finished_ctx("err"), 0.001, error="boom") is True
    kept = {t["request_id"]: t for t in s.traces()}
    assert set(kept) == {"slow", "err"}
    assert kept["slow"]["reason"] == "slow" and kept["err"]["reason"] == "error"
    assert s.get("err")["error"] == "boom"
    with pytest.raises(KeyError):
        s.get("fast")
    st = s.stats()
    assert st["sampled"] == 3 and st["kept_error"] == 1 and st["kept_slow"] == 1


def test_sampler_finish_is_idempotent_per_context():
    hist = obs.Histogram("cz_t_lat2_seconds", "t", buckets=(0.01,))
    s = TailSampler(hist, slow_s=0.0)
    ctx = _finished_ctx("once")
    assert s.finish(ctx, 1.0) is True
    assert s.finish(ctx, 1.0) is False  # latched
    assert s.stats()["sampled"] == 1


def test_sampler_byte_budget_evicts_oldest():
    hist = obs.Histogram("cz_t_lat3_seconds", "t", buckets=(0.01,))
    probe = TailSampler(hist, slow_s=0.0)
    probe.finish(_finished_ctx("probe"), 1.0)
    one = probe.stats()["bytes"]  # bytes of a single kept trace

    # room for one trace but not two: keeping "b" must evict "a"
    s = TailSampler(hist, slow_s=0.0, budget_bytes=int(one * 1.5))
    s.finish(_finished_ctx("a"), 1.0)
    s.finish(_finished_ctx("b"), 1.0)
    assert [t["request_id"] for t in s.traces()] == ["b"]
    assert s.stats()["evicted"] == 1
    assert s.stats()["bytes"] <= s.budget_bytes

    # a budget smaller than any single trace retains nothing (hard cap)
    tiny = TailSampler(hist, slow_s=0.0, budget_bytes=1)
    tiny.finish(_finished_ctx("c"), 1.0)
    assert tiny.traces() == [] and tiny.stats()["bytes"] == 0


def test_sampler_dynamic_threshold_tracks_tail():
    hist = obs.Histogram("cz_t_lat4_seconds", "t", buckets=(0.01, 0.1, 1.0))
    s = TailSampler(hist, min_count=10, default_slow_s=9.9)
    assert s.threshold() == 9.9  # cold start: below min_count
    for _ in range(99):
        hist.observe(0.001)
    hist.observe(0.5)
    # 99% of observations are <= 0.01 -> the live p99 estimate is that
    # bucket's bound; the 0.5 s straggler sits above it and would be kept
    assert s.threshold() == 0.01
    assert 0.5 >= s.threshold()
    # traffic shifts slower: the threshold follows the new p99 upward
    for _ in range(900):
        hist.observe(0.05)
    assert s.threshold() == 0.1


def test_chrome_trace_export_shape():
    hist = obs.Histogram("cz_t_lat5_seconds", "t", buckets=(0.01,))
    s = TailSampler(hist, slow_s=0.0)
    s.finish(_finished_ctx("ct", nev=3), 1.0)
    doc = chrome_trace(s.get("ct"))
    assert doc["metadata"]["request_id"] == "ct"
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == 3 and all(e["dur"] == 5.0 for e in evs)


def test_exemplar_rendered_only_in_openmetrics_and_parse_tolerant():
    reg = obs.Registry()
    reg.counter("cz_t_ex_total", "t").inc(2)
    h = reg.histogram("cz_t_ex_seconds", "t", buckets=(0.01, 0.1))
    h.observe(0.05)
    h.exemplar(0.05, "trace-xyz")

    # default 0.0.4 exposition: exemplar-free — the legacy Prometheus
    # parser errors on exemplar syntax, failing the whole scrape
    text = reg.render()
    assert "trace-xyz" not in text and "# EOF" not in text
    assert "# TYPE cz_t_ex_total counter" in text

    # OpenMetrics document: exemplar attached, _total-stripped counter
    # family, '# EOF' terminator
    om = reg.render(openmetrics=True)
    line = next(ln for ln in om.splitlines()
                if 'le="0.1"' in ln and "cz_t_ex_seconds_bucket" in ln)
    assert '# {trace_id="trace-xyz"}' in line
    assert "# TYPE cz_t_ex counter" in om
    assert "cz_t_ex_total 2" in om.splitlines()
    assert om.endswith("# EOF\n")

    # both formats parse to the same samples
    for doc in (text, om):
        parsed = obs.parse_prometheus(doc)
        assert ({"le": "0.1"}, 1.0) in parsed["cz_t_ex_seconds_bucket"]
        assert parsed["cz_t_ex_total"] == [({}, 2.0)]


def test_parse_keeps_hash_inside_quoted_label_values():
    # a '#' inside a quoted label value is sample content, not an exemplar
    line = 'cz_t_err_total{msg="boom # not \\"an\\" exemplar"} 1\n'
    parsed = obs.parse_prometheus(line)
    assert parsed["cz_t_err_total"] == \
        [({"msg": 'boom # not "an" exemplar'}, 1.0)]
    # ...while a real exemplar after such a value is still stripped
    with_ex = ('cz_t_err_seconds_bucket{msg="a # b",le="0.1"} 3 '
               '# {trace_id="t-1"} 0.05 1.0\n')
    parsed = obs.parse_prometheus(with_ex)
    assert parsed["cz_t_err_seconds_bucket"] == \
        [({"msg": "a # b", "le": "0.1"}, 3.0)]


# ---------------------------------------------------------------------------
# e2e: one slow request correlated across header, trace, events, exemplar
# ---------------------------------------------------------------------------

def test_request_id_minted_and_echoed(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    with RegionHTTPServer(root, port=0).start() as srv:
        # minted: present and well-formed on every response, 404s included
        for path in ("/healthz", "/metrics", "/nope"):
            _, headers, _ = _get(srv, path)
            rid = headers.get("X-CZ-Request-Id")
            assert rid and obs_context.clean_id(rid) == rid
        # honored: a clean client-supplied ID is echoed verbatim...
        _, headers, _ = _get(srv, "/healthz", rid="my-req-007")
        assert headers["X-CZ-Request-Id"] == "my-req-007"
        # ...a malformed one is replaced, not reflected
        _, headers, _ = _get(srv, "/healthz", rid="bad id!")
        assert headers["X-CZ-Request-Id"] != "bad id!"


def test_slow_request_correlated_end_to_end(tmp_path, monkeypatch):
    root = _make_dataset(str(tmp_path / "ds"))
    _slow_decode(monkeypatch, 0.06)
    with RegionHTTPServer(root, port=0, trace_slow_ms=30).start() as srv:
        status, headers, _ = _get(
            srv, f"/v1/region/p/0?lo=0,0,0&hi={BS},{BS},{BS}",
            rid="e2e-slow-1")
        assert status == 200
        rid = headers["X-CZ-Request-Id"]
        assert rid == "e2e-slow-1"

        with Client(srv.url) as c:
            doc = c.traces()
            rec = c.trace(rid)
            chrome = c.trace(rid, chrome=True)
            text = c.metrics()
            om = c.metrics(openmetrics=True)
            evts = c.events(200)

        # kept tail trace, same ID, with the spans the request touched
        assert rid in [t["request_id"] for t in doc["traces"]]
        assert rec["reason"] == "slow" and rec["duration_ms"] >= 30
        names = [ev["name"] for ev in rec["events"]]
        assert "serve.query" in names and "fetch" in names
        assert all(ev["args"]["rid"] == rid for ev in rec["events"])
        assert chrome["metadata"]["request_id"] == rid

        # structured event line for the same request
        mine = [e for e in evts if e.get("request_id") == rid]
        assert any(e["event"] == "http.request" and e["code"] == 200
                   for e in mine)

        # /metrics: sampler counters; the default 0.0.4 scrape must stay
        # exemplar-free (the legacy parser rejects exemplar syntax), while
        # the negotiated OpenMetrics document carries a bucket exemplar
        # pointing at a kept trace (latest keep wins the bucket, so match
        # any retained ID)
        kept_ids = {t["request_id"] for t in doc["traces"]}
        assert "trace_id=" not in text
        assert any(f'trace_id="{k}"' in om for k in kept_ids)
        assert om.endswith("# EOF\n")
        for md in (obs.parse_prometheus(text), obs.parse_prometheus(om)):
            assert md["cz_serve_traces_kept_total"]
            assert sum(v for _, v in md["cz_serve_traces_kept_total"]) >= 1


def test_error_request_kept_with_http_status(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    with RegionHTTPServer(root, port=0, trace_slow_ms=10_000).start() as srv:
        status, headers, _ = _get(
            srv, "/v1/region/p/0?lo=0,0&hi=4,4,4", rid="e2e-bad-1")
        assert status == 400
        rec_ids = None
        with Client(srv.url) as c:
            # HTTP-layer failures finish the sampler just after the response
            # bytes hit the wire — poll briefly for the keep to land
            for _ in range(50):
                rec_ids = {t["request_id"]: t for t in c.traces()["traces"]}
                if "e2e-bad-1" in rec_ids:
                    break
                time.sleep(0.02)
        assert headers["X-CZ-Request-Id"] == "e2e-bad-1"
        assert rec_ids["e2e-bad-1"]["reason"] == "error"
        assert "http 400" in rec_ids["e2e-bad-1"]["error"]


def test_no_sample_disables_debug_traces(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    with RegionHTTPServer(root, port=0, sample=False).start() as srv:
        status, headers, _ = _get(
            srv, f"/v1/region/p/0?lo=0,0,0&hi={BS},{BS},{BS}")
        assert status == 200
        assert headers["X-CZ-Request-Id"]  # correlation survives opt-out
        assert _get(srv, "/debug/traces")[0] == 404
        assert "cz_serve_traces_sampled_total" not in Client(srv.url).metrics()


def test_coalesced_follower_recorded_on_leader_span(tmp_path, monkeypatch):
    """Two concurrent identical requests: the leader decodes, the follower
    parks on the flight.  The leader's kept trace must carry the follower's
    request ID on its ``serve.flight`` span, and the follower's trace must
    name its leader on ``serve.flight.wait``."""
    root = _make_dataset(str(tmp_path / "ds"))
    _slow_decode(monkeypatch, 0.15)
    with RegionHTTPServer(root, port=0, trace_slow_ms=1,
                          max_inflight=4).start() as srv:
        path = f"/v1/region/p/0?lo=0,0,0&hi={N},{N},{N}"
        started = threading.Event()
        results = {}

        def fetch(rid, wait_s):
            if wait_s:
                started.wait()
                time.sleep(wait_s)
            else:
                started.set()
            results[rid] = _get(srv, path, rid=rid)[0]

        t1 = threading.Thread(target=fetch, args=("e2e-lead", 0))
        t2 = threading.Thread(target=fetch, args=("e2e-follow", 0.05))
        t1.start(), t2.start()
        t1.join(), t2.join()
        assert results == {"e2e-lead": 200, "e2e-follow": 200}

        with Client(srv.url) as c:
            lead = c.trace("e2e-lead")
            follow = c.trace("e2e-follow")

    flights = [ev for ev in lead["events"] if ev["name"] == "serve.flight"]
    assert flights, "leader trace lost its flight span"
    followers = [f for ev in flights for f in ev["args"]["followers"]]
    assert "e2e-follow" in followers
    waits = [ev for ev in follow["events"]
             if ev["name"] == "serve.flight.wait"]
    assert waits and waits[0]["args"]["leader"] == "e2e-lead"


# ---------------------------------------------------------------------------
# cz-compress stats: --diff
# ---------------------------------------------------------------------------

def test_stats_diff_cli(tmp_path, capsys):
    from repro.launch.compress import stats_main

    a = {"cz_x_total": [{"labels": {}, "value": 3}],
         "cz_lat_seconds": [{"labels": {"q": "p"}, "sum": 1.0, "count": 4}]}
    b = {"schema": 1, "name": "serve", "params": {}, "metrics": {},
         "registry": {"cz_x_total": {
             "kind": "counter", "help": "x", "labelnames": [],
             "samples": [{"labels": {}, "value": 10}]},
             "cz_lat_seconds": {
             "kind": "histogram", "help": "l", "labelnames": ["q"],
             "samples": [{"labels": {"q": "p"}, "buckets": [],
                          "sum": 2.5, "count": 9}]}}}
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))

    assert stats_main(["--diff", str(pa), str(pb), "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    changed = {(r["name"], r["labels"]): r for r in out["changed"]}
    assert changed[("cz_x_total", "")]["delta"] == 7
    assert changed[("cz_lat_seconds_count", "q=p")]["delta"] == 5
    assert changed[("cz_lat_seconds_sum", "q=p")]["delta"] == 1.5

    assert stats_main(["--diff", str(pa), str(pb)]) == 0
    text = capsys.readouterr().out
    assert "cz_x_total" in text and "3 -> 10" in text and "(+7)" in text


# ---------------------------------------------------------------------------
# documentation + hygiene lints


def test_readme_documents_every_registered_metric():
    """The README metric table must name every metric the code registers —
    global-registry ones (import side effects below) plus the serve-tier
    names built per-scrape by ``render_metrics``."""
    import pathlib

    import repro.cluster.engine  # noqa: F401  (register cz_cluster_*)
    import repro.core.container  # noqa: F401  (cz_reader_*)
    import repro.core.pipeline  # noqa: F401  (cz_pipeline_*)
    import repro.core.schemes._device  # noqa: F401  (cz_kernel_fallbacks)
    import repro.kernels.ops  # noqa: F401  (cz_kernel_*)
    import repro.store.backends.instrument  # noqa: F401  (cz_store_*)
    import repro.tune.policy  # noqa: F401  (cz_tune_cache_hits)
    import repro.tune.trial  # noqa: F401  (cz_tune_trials/decision)
    from tests.test_obs import SERVE_METRIC_NAMES

    readme = (pathlib.Path(__file__).parent.parent / "README.md").read_text()
    names = {m.name for m in obs.REGISTRY} | set(SERVE_METRIC_NAMES)
    missing = sorted(n for n in names if n not in readme)
    assert not missing, f"metrics registered but not in README.md: {missing}"


def test_no_print_in_library_code():
    """``print(`` is banned inside src/repro outside the CLI surfaces
    (``launch/`` and the ``serve`` HTTP entry point) — library code reports
    through repro.obs.  Mirrors the ruff T20 config for environments
    without ruff."""
    import pathlib
    import tokenize

    src = pathlib.Path(__file__).parent.parent / "src" / "repro"
    allowed = {src / "serve" / "http.py",
               src / "store" / "backends" / "http.py"}  # static-server CLI
    offenders = []
    for path in sorted(src.rglob("*.py")):
        if path in allowed or (src / "launch") in path.parents:
            continue
        with tokenize.open(path) as fh:
            for tok in tokenize.generate_tokens(fh.readline):
                if tok.type == tokenize.NAME and tok.string == "print":
                    offenders.append(f"{path.relative_to(src)}:{tok.start[0]}")
    assert not offenders, f"print() in library code: {offenders}"
