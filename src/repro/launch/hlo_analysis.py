"""Loop-aware analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop *body once*, so any
scanned program (layer stacks, flash-attention chunk loops, microbatching,
SSM time scans) is undercounted by its trip counts — we measured a 64-layer
model reporting ~1/40 of its true FLOPs.  This module parses the HLO text,
extracts while trip counts from their condition computations, and folds the
multipliers through the call graph, yielding loop-aware:

* ``flops``        — 2 * |result| * |contracted dims| summed over every dot
                     (including dots nested in fusions);
* ``hbm_bytes``    — per materializing instruction: result bytes (write) +
                     operand bytes (reads).  Fusion internals are *not*
                     counted (they never hit HBM) — the fusion op's own
                     operands/results model the traffic;
* ``collectives``  — per collective op: output bytes and instruction count.

Everything is per-device (the HLO is the per-partition SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\]\S*)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# ops that still hit HBM on TPU even under aggressive fusion
_MOVEMENT_OPS = {"gather", "scatter", "dynamic-slice", "dynamic-update-slice",
                 "sort", "convolution", "reduce-window", "scatter-add"}

_NO_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota",
}


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)   # (name, type_str, opcode, rest)


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0          # unfused upper bound (every instruction)
    hbm_bytes_fused: float = 0.0    # TPU-fusion floor: dots + data movement
    attn_score_bytes: float = 0.0   # fused-model bytes on (B,H,G,qc,kc) score
                                    # blocks — eliminated by a Pallas flash
                                    # kernel that keeps blocks in VMEM
    transcendentals: float = 0.0
    collectives: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0

    @property
    def collective_bytes(self) -> int:
        return sum(v["bytes"] for v in self.collectives.values())


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _parse(text: str) -> tuple[dict[str, _Comp], str, dict[str, str]]:
    comps: dict[str, _Comp] = {}
    entry = None
    shapes: dict[str, str] = {}
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = _Comp(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, type_str, opcode = mi.group(1), mi.group(2), mi.group(3)
            rest = line[mi.end():]
            cur.instrs.append((name, type_str, opcode, rest))
            shapes[name] = type_str
    return comps, entry, shapes


def _trip_count(cond: _Comp) -> int | None:
    best = None
    for name, type_str, opcode, rest in cond.instrs:
        if opcode == "constant" and type_str.startswith("s32[]"):
            m = re.search(r"constant\((\-?\d+)\)", "constant(" + rest)
            if m:
                k = int(m.group(1))
                best = k if best is None else max(best, k)
    return best


def analyze_hlo(text: str) -> HloStats:
    comps, entry, shapes = _parse(text)
    stats_cache: dict[str, HloStats] = {}
    flops_cache: dict[str, float] = {}

    def dot_stats(comp: _Comp) -> tuple[float, float]:
        """(dot MACs*2, fused-model HBM bytes), recursing into fusions."""
        if comp.name in flops_cache:
            return flops_cache[comp.name]
        total = 0.0
        fused_bytes = 0.0
        score_bytes = 0.0
        for name, type_str, opcode, rest in comp.instrs:
            if opcode == "dot":
                out_elems = 1
                for d in _shape_dims(type_str):
                    out_elems *= d
                lhs = _OPERAND_RE.search(rest)  # first operand = lhs
                k = 1
                mcd = _LHS_CONTRACT_RE.search(rest)
                if lhs and mcd and lhs.group(1) in shapes:
                    ldims = _shape_dims(shapes[lhs.group(1)])
                    for ci in mcd.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            k *= ldims[int(ci)]
                total += 2.0 * out_elems * k
                fused_bytes += _shape_bytes(type_str)
                is_attn = "bhgqk" in rest        # score-space einsum metadata
                if is_attn:
                    score_bytes += _shape_bytes(type_str) if "->bhgqk" in rest else 0
                for opname in _OPERAND_RE.findall(rest):
                    if opname in shapes:
                        fused_bytes += _shape_bytes(shapes[opname])
                        if is_attn and "bhgqk," in rest and opname in shapes:
                            pass
                    else:
                        break
                if "bhgqk," in rest:             # score operand read back
                    op0 = _OPERAND_RE.search(rest)
                    if op0 and op0.group(1) in shapes:
                        score_bytes += _shape_bytes(shapes[op0.group(1)])
            elif opcode in _MOVEMENT_OPS:
                # window ops touch only the window, not the full buffer:
                #   dynamic-slice / gather: read+write |result|
                #   dynamic-update-slice:   read+write |update| (operand 1)
                #   scatter:                read+write |updates| (operand 2)
                if opcode in ("dynamic-slice", "gather"):
                    fused_bytes += 2 * _shape_bytes(type_str)
                elif opcode == "dynamic-update-slice":
                    ops_ = _OPERAND_RE.findall(rest)
                    upd = ops_[1] if len(ops_) > 1 else None
                    fused_bytes += 2 * _shape_bytes(shapes.get(upd, type_str))                         if upd in shapes else 2 * _shape_bytes(type_str)
                elif opcode == "scatter":
                    ops_ = _OPERAND_RE.findall(rest)
                    upd = ops_[2] if len(ops_) > 2 else None
                    fused_bytes += 2 * _shape_bytes(shapes.get(upd, type_str))                         if upd in shapes else 2 * _shape_bytes(type_str)
                else:
                    fused_bytes += _shape_bytes(type_str)
                    for opname in _OPERAND_RE.findall(rest):
                        if opname in shapes:
                            fused_bytes += _shape_bytes(shapes[opname])
                        else:
                            break
            elif opcode == "fusion":
                mf = _CALLS_RE.search(rest)
                if mf and mf.group(1) in comps:
                    f2, b2, s2 = dot_stats(comps[mf.group(1)])
                    total += f2
                    fused_bytes += b2
                    score_bytes += s2
        flops_cache[comp.name] = (total, fused_bytes, score_bytes)
        return total, fused_bytes, score_bytes

    def analyze(comp_name: str) -> HloStats:
        if comp_name in stats_cache:
            return stats_cache[comp_name]
        comp = comps[comp_name]
        st = HloStats(collectives={op: {"bytes": 0, "count": 0} for op in _COLL_OPS})
        st.flops, st.hbm_bytes_fused, st.attn_score_bytes = dot_stats(comp)
        for name, type_str, opcode, rest in comp.instrs:
            if opcode in _COLL_OPS or (opcode.endswith("-start") and opcode[:-6] in _COLL_OPS):
                op = opcode[:-6] if opcode.endswith("-start") else opcode
                st.collectives[op]["bytes"] += _shape_bytes(type_str)
                st.collectives[op]["count"] += 1
            if opcode == "while":
                mb, mc = _BODY_RE.search(rest), _COND_RE.search(rest)
                trip = None
                if mc and mc.group(1) in comps:
                    trip = _trip_count(comps[mc.group(1)])
                if trip is None:
                    trip = 1
                    st.unknown_trip_whiles += 1
                if mb and mb.group(1) in comps:
                    sub = analyze(mb.group(1))
                    st.flops += trip * sub.flops
                    st.hbm_bytes += trip * sub.hbm_bytes
                    st.hbm_bytes_fused += trip * sub.hbm_bytes_fused
                    st.attn_score_bytes += trip * sub.attn_score_bytes
                    st.unknown_trip_whiles += sub.unknown_trip_whiles
                    for op, v in sub.collectives.items():
                        st.collectives[op]["bytes"] += trip * v["bytes"]
                        st.collectives[op]["count"] += trip * v["count"]
                continue
            if opcode in ("call", "conditional"):
                for target in _CALLS_RE.findall(rest) + _BODY_RE.findall(rest):
                    if target in comps:
                        sub = analyze(target)
                        st.flops += sub.flops
                        st.hbm_bytes += sub.hbm_bytes
                        st.hbm_bytes_fused += sub.hbm_bytes_fused
                        st.attn_score_bytes += sub.attn_score_bytes
                        for op, v in sub.collectives.items():
                            st.collectives[op]["bytes"] += v["bytes"]
                            st.collectives[op]["count"] += v["count"]
                continue
            if opcode in _NO_BYTES_OPS:
                continue
            # HBM traffic model: write result + read operands (fusion opaque)
            wb = _shape_bytes(type_str)
            rb = 0
            for opname in _OPERAND_RE.findall(rest):
                if opname in shapes:
                    rb += _shape_bytes(shapes[opname])
                else:
                    break  # stop at metadata/computation refs
            st.hbm_bytes += wb + rb
        stats_cache[comp_name] = st
        return st

    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    return analyze(entry)
