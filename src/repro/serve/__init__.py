"""serve subsystem."""
