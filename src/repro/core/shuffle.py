"""Data shuffling and bit zeroing (paper Exp. 2 / Fig. 5).

Byte shuffling transposes the byte planes of a homogeneous value stream so
that "boring" high bytes group together, which substantially improves the
subsequent lossless stage.  Bit zeroing clears the least significant mantissa
bits of the detail coefficients (Z4/Z8 in the paper) — lossy, but below the
PSNR knee it is free CR.  Host (numpy) variants operate on byte buffers for
the I/O path; device (jnp) variants exist for in-situ use inside jit.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "byte_shuffle",
    "byte_unshuffle",
    "bit_shuffle",
    "bit_unshuffle",
    "zero_low_bits_np",
    "zero_low_bits",
]


def byte_shuffle(buf: bytes | np.ndarray, itemsize: int) -> bytes:
    a = np.frombuffer(buf, dtype=np.uint8) if isinstance(buf, (bytes, bytearray)) else np.asarray(buf, np.uint8)
    if a.size % itemsize:
        raise ValueError(f"buffer size {a.size} not divisible by itemsize {itemsize}")
    return a.reshape(-1, itemsize).T.tobytes()


def byte_unshuffle(buf: bytes | np.ndarray, itemsize: int) -> bytes:
    a = np.frombuffer(buf, dtype=np.uint8) if isinstance(buf, (bytes, bytearray)) else np.asarray(buf, np.uint8)
    if a.size % itemsize:
        raise ValueError(f"buffer size {a.size} not divisible by itemsize {itemsize}")
    return a.reshape(itemsize, -1).T.tobytes()


def bit_shuffle(buf: bytes, itemsize: int) -> bytes:
    a = np.frombuffer(buf, dtype=np.uint8)
    bits = np.unpackbits(a.reshape(-1, itemsize), axis=1, bitorder="little")
    return np.packbits(bits.T, bitorder="little").tobytes()


def bit_unshuffle(buf: bytes, itemsize: int) -> bytes:
    a = np.frombuffer(buf, dtype=np.uint8)
    nbits = itemsize * 8
    bits = np.unpackbits(a, bitorder="little").reshape(nbits, -1)
    return np.packbits(bits.T, axis=1, bitorder="little").tobytes()


def zero_low_bits_np(values: np.ndarray, nbits: int) -> np.ndarray:
    """Clear the ``nbits`` least significant bits of float32 values (host)."""
    if nbits == 0:
        return values
    u = values.astype(np.float32).view(np.uint32)
    u = u & np.uint32(~((1 << nbits) - 1) & 0xFFFFFFFF)
    return u.view(np.float32)


def zero_low_bits(values, nbits: int):
    """Device (jnp) variant of :func:`zero_low_bits_np`."""
    if nbits == 0:
        return values
    u = jnp.asarray(values, jnp.float32).view(jnp.uint32)
    u = u & jnp.uint32(~((1 << nbits) - 1) & 0xFFFFFFFF)
    return u.view(jnp.float32)
