"""repro.tune — self-driving compression (per-chunk scheme auto-tuning).

The paper frames the framework as a *testbed of comparison* between
wavelet/ZFP/SZ/FPZIP arms; this package closes that loop: the user states a
quality target (an explicit error bound — ABS, REL, or PSNR-targeted, the
vocabulary of the error-bounded-compression literature) and the framework
picks the best registered scheme **per chunk**, because the best predictor
is data-dependent *within* a single field (Tao et al. 2017).

Three layers, consumed by the ``auto`` meta-scheme
(:mod:`repro.core.schemes.auto`):

* :mod:`repro.tune.bound`  — :class:`Target`: parse ``abs=1e-3`` /
  ``rel=1e-4`` / ``psnr=80`` and map it onto each registered scheme's
  ``error_bound`` contract (candidate spec derivation by inverting the
  declared bound);
* :mod:`repro.tune.trial`  — the trial runner: encode a deterministic
  sample of the chunk under every admissible candidate on a thread pool,
  score (achieved ratio, measured max-err/PSNR, encode time), return a
  ranked :class:`Decision`;
* :mod:`repro.tune.policy` — the decision layer: by default every chunk is
  trialled (decisions are then a pure function of chunk content — the
  cluster engine's rank invariance depends on this), with an opt-in
  signature cache (``tune_cache=K`` in ``spec.extra``) that re-trials only
  every K-th chunk of a seen (range/variance/smoothness) signature.

Decisions are deterministic: candidate order, sampling, and ranking use no
randomness and no wall-clock input, so serial, threaded, and rank-parallel
encodes of the same data produce byte-identical containers.
"""
from .bound import MODES, Target, candidate_spec, target_from_spec  # noqa: F401
from .policy import DecisionPolicy, chunk_signature, policy_for  # noqa: F401
from .trial import Decision, Trial, run_trials, sample_blocks  # noqa: F401

__all__ = [
    "MODES", "Target", "candidate_spec", "target_from_spec",
    "Decision", "Trial", "run_trials", "sample_blocks",
    "DecisionPolicy", "chunk_signature", "policy_for",
]
