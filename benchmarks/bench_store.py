"""CZDataset store benchmarks (ISSUE 2 acceptance).

Measures (a) append throughput of an in-situ stream — multiple quantities
per timestep — with ``workers=1`` vs ``workers=4`` (the concurrent shard
writer), and (b) random-access region-read latency vs whole-field decode:
a box query should touch only its covering chunks, a full decode all of
them.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core import CompressionSpec
from repro.store import CZDataset

from .common import dataset, emit, save_json


def _append_run(root: str, fields: dict, n_steps: int, workers: int,
                spec: CompressionSpec) -> dict:
    shutil.rmtree(root, ignore_errors=True)
    raw = sum(f.nbytes for f in fields.values()) * n_steps
    t0 = time.time()
    with CZDataset(root, "a", spec=spec, workers=workers) as ds:
        for k in range(n_steps):
            ds.append(fields, time=float(k))
    dt = time.time() - t0
    comp = sum(ts["bytes"]
               for q in fields
               for ts in CZDataset(root).timestep_info(q))
    return {"workers": workers, "time_s": dt, "MBps": raw / 2**20 / dt,
            "cr": raw / comp, "raw_bytes": raw, "compressed_bytes": comp}


def run(quick: bool = True):
    n_steps = 3 if quick else 6
    box = 32
    reps = 20 if quick else 100
    qois = ["p", "rho"] if quick else ["p", "rho", "E", "a2"]
    fields = {q: f for q, f in dataset("10k").items() if q in qois}
    n = next(iter(fields.values())).shape[0]
    # small buffers force many chunks per member: parallel encode has work,
    # and region reads can skip most of the file
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                           block_size=16, buffer_bytes=1 << 18)

    root = os.path.join(tempfile.mkdtemp(), "bench_ds")
    results = {"n": n, "n_steps": n_steps, "quantities": qois, "append": []}

    for workers in (1, 4):
        r = _append_run(root, fields, n_steps, workers, spec)
        results["append"].append(r)
        emit(f"store_append_w{workers}", r["time_s"] * 1e6 / n_steps,
             f"{r['MBps']:.0f}MBps_cr{r['cr']:.1f}")
    results["append_speedup_w4"] = (results["append"][0]["time_s"]
                                    / results["append"][1]["time_s"])

    # -- region read vs whole-field decode (fresh reader each rep = cold) --
    with CZDataset(root) as ds:
        t0 = time.time()
        for k in range(reps):
            lo = (k * 7) % (n - box)
            ds.read_box("p", k % n_steps, (lo, lo, lo),
                        (lo + box, lo + box, lo + box))
        box_ms = (time.time() - t0) * 1e3 / reps
        stats = ds.stats()

        t0 = time.time()
        ds.read_field("p", 0)
        full_ms = (time.time() - t0) * 1e3
        r = ds.reader("p", 0)
        results["region"] = {
            "box": box, "reps": reps, "box_ms": box_ms, "full_ms": full_ms,
            "speedup": full_ms / box_ms, "chunks_total": r.nchunks,
            "store_stats": stats,
        }
    emit("store_read_box", box_ms * 1e3, f"{full_ms/box_ms:.1f}x_vs_full")
    emit("store_read_full", full_ms * 1e3, f"{results['region']['chunks_total']}chunks")

    shutil.rmtree(os.path.dirname(root), ignore_errors=True)
    path = save_json("store", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    run()
