"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init): this container has one physical CPU device; the dry run needs
512 placeholder devices so jax.make_mesh can build the production meshes.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--skip-existing] [--mesh both]
    python -m repro.launch.dryrun --all --attn-impl triangular --tag tri

Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json with
memory_analysis, cost_analysis and the per-collective byte totals parsed
from the post-SPMD compiled HLO — the inputs to benchmarks/roofline.py.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_applicable
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.costs import detailed_flops, model_flops
from repro.models import ModelSettings, count_params, input_batch_specs, param_specs
from repro.serve.step import build_decode_step, build_prefill_step
from repro.train.step import build_train_step, train_state_specs

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+(" + "|".join(_COLL_OPS) + r")\(")


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in compiled HLO."""
    out = {op: {"bytes": 0, "count": 0} for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op]["bytes"] += nbytes
        out[op]["count"] += 1
    return out


def _settings(args) -> ModelSettings:
    return ModelSettings(attn_impl=args.attn_impl, q_chunk=args.q_chunk,
                         kv_chunk=args.kv_chunk, remat=args.remat,
                         act_shard=args.act_shard, rwkv_chunk=args.rwkv_chunk,
                         attn_shard=args.attn_shard)


def lower_cell(arch_name: str, shape_name: str, mesh_kind: str, args):
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                "applicable": False, "skip_reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    st = _settings(args)
    t0 = time.time()

    if shape.kind == "train":
        batch_specs = input_batch_specs(cfg, shape)
        micro = args.micro if args.micro else (4 if cfg.d_model >= 4096 else 1)
        import jax.numpy as _jnp

        pdt = {"f32": None, "bf16": _jnp.bfloat16}[args.param_dtype]
        gc = args.grad_compress or None
        state_specs = train_state_specs(cfg, param_dtype=pdt, grad_compress=gc)
        _, jit_for, _ = build_train_step(cfg, mesh, settings=st, donate=False,
                                         micro_batches=micro,
                                         sharding_mode=args.sharding,
                                         param_dtype=pdt, grad_compress=gc)
        jitted = jit_for(batch_specs)
        with mesh:
            lowered = jitted.lower(state_specs, batch_specs)
    elif shape.kind == "prefill":
        pspecs = param_specs(cfg)
        batch_specs = input_batch_specs(cfg, shape)
        _, jit_for = build_prefill_step(cfg, mesh, settings=st)
        jitted, nargs = jit_for(pspecs, batch_specs)
        with mesh:
            if nargs == 3:
                lowered = jitted.lower(pspecs, batch_specs["tokens"],
                                       batch_specs["frames"])
            else:
                lowered = jitted.lower(pspecs, batch_specs["tokens"])
    else:  # decode
        pspecs = param_specs(cfg)
        dspecs = input_batch_specs(cfg, shape)
        _, jit_for = build_decode_step(cfg, mesh, settings=st, donate_cache=True)
        jitted = jit_for(pspecs, dspecs["cache"], dspecs["token"])
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            lowered = jitted.lower(pspecs, dspecs["cache"], dspecs["token"], pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    la = analyze_hlo(hlo_text)  # loop-aware (cost_analysis counts scan bodies once)
    af = detailed_flops(cfg, shape, attn_impl=st.attn_impl, remat=st.remat)

    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "applicable": True,
        "n_devices": int(mesh.devices.size),
        "attn_impl": st.attn_impl,
        "remat": st.remat,
        "act_shard": st.act_shard,
        "sharding_mode": args.sharding,
        "param_dtype": args.param_dtype,
        "grad_compress": args.grad_compress or None,
        "micro_batches": (args.micro if args.micro else (4 if cfg.d_model >= 4096 else 1)) if shape.kind == "train" else 1,
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        "flops_per_device": ca.get("flops"),
        "bytes_accessed_per_device": ca.get("bytes accessed"),
        "transcendentals": ca.get("transcendentals"),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "collectives": coll,
        "collective_bytes_per_device": sum(v["bytes"] for v in coll.values()),
        "loop_aware": {
            "flops_per_device": la.flops,
            "hbm_bytes_per_device": la.hbm_bytes,
            "hbm_bytes_fused_per_device": la.hbm_bytes_fused,
            "attn_score_bytes_per_device": la.attn_score_bytes,
            "collectives": la.collectives,
            "collective_bytes_per_device": la.collective_bytes,
            "unknown_trip_whiles": la.unknown_trip_whiles,
        },
        "analytic": af,
        "model_flops": model_flops(cfg, shape),
        "params_total": count_params(cfg),
        "params_active": count_params(cfg, active_only=True),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    # prove-it-fits line (assignment requirement)
    print(f"  memory_analysis: arg={ma.argument_size_in_bytes/2**30:.3f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.3f}GiB "
          f"out={ma.output_size_in_bytes/2**30:.3f}GiB per device")
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e} per device")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn-impl", default="masked",
                    choices=["masked", "triangular"])
    ap.add_argument("--q-chunk", type=int, default=256)
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--micro", type=int, default=0,
                    help="microbatch count for train cells (0 = per-arch default)")
    ap.add_argument("--act-shard", default="seq", choices=["none", "seq", "hidden"])
    ap.add_argument("--rwkv-chunk", type=int, default=0)
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--param-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--attn-shard", default="auto",
                    choices=["auto", "replicate", "heads", "cp"])
    ap.add_argument("--grad-compress", default="",
                    help="e.g. topk32 — cross-pod EF-compressed reduction (multi mesh)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {path}")
                    continue
                print(f"[cell] {arch} x {shape} x {mesh_kind}")
                try:
                    res = lower_cell(arch, shape, mesh_kind, args)
                except Exception as e:  # noqa: BLE001 - record and continue
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "applicable": True, "error": f"{type(e).__name__}: {e}"}
                    failures.append((arch, shape, mesh_kind, str(e)))
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
