"""Table 3 — compression / decompression speeds (MB/s) per scheme on this
host.  (Absolute numbers are hardware-specific; the paper's qualitative
claims checked: stage-2 choice dominates wavelet speed; zfpx decompresses
fastest; shuffling speeds up the lossless stage.)"""
from __future__ import annotations

import time

from repro.core import CompressionSpec, Pipeline

from .common import dataset, emit, save_json


def _timed(field, spec, repeats=1):
    pipe = Pipeline(spec)
    comp = None
    t0 = time.time()
    for _ in range(repeats):
        comp = pipe.compress(field)
    t_c = (time.time() - t0) / repeats
    t0 = time.time()
    for _ in range(repeats):
        pipe.decompress(comp)
    t_d = (time.time() - t0) / repeats
    mb = field.nbytes / 2**20
    return mb / t_c, mb / t_d, comp.header["raw_bytes"] / comp.nbytes


def run(quick: bool = True):
    field = dataset("10k")["p"]
    schemes = {
        "w3ai+zlib": CompressionSpec(scheme="wavelet", shuffle="none"),
        "w3ai+shuf+zlib": CompressionSpec(scheme="wavelet", shuffle="byte"),
        "w3ai+shuf+zlib1": CompressionSpec(scheme="wavelet", shuffle="byte", stage2="zlib1"),
        "w3ai+shuf+lzma": CompressionSpec(scheme="wavelet", shuffle="byte", stage2="lzma"),
        "w3ai+shuf+bz2": CompressionSpec(scheme="wavelet", shuffle="byte", stage2="bz2"),
        "zfpx": CompressionSpec(scheme="zfpx"),
        "szx": CompressionSpec(scheme="szx"),
        "fpzipx": CompressionSpec(scheme="fpzipx"),
        "lossless_shuf+zlib": CompressionSpec(scheme="raw", shuffle="byte"),
    }
    rows = []
    t0 = time.time()
    for name, spec in schemes.items():
        c, d, cr = _timed(field, spec)
        rows.append({"scheme": name, "comp_MBps": c, "decomp_MBps": d, "cr": cr})
        emit(f"table3_{name}_comp_MBps", (time.time() - t0) * 1e6, f"{c:.1f}")
    save_json("table3_speeds", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
