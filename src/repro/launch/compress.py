"""Ex-situ compression tool (the paper's standalone CubismZ CLI).

Compresses 3D fields — from the cavitation generator, the Euler solver, or
a raw .npy file — into CZ containers, reports CR/PSNR per quantity, and can
decompress/verify.

Examples:
  python -m repro.launch.compress --source cavitation --t 9.4 --n 128 \
      --scheme wavelet --wavelet w3ai --eps 1e-3 --out /tmp/fields
  python -m repro.launch.compress --decompress /tmp/fields/p.cz --verify-against /tmp/p.npy
  cz-compress inspect /tmp/fields/p.cz          # header + chunk table + CRCs
  cz-compress inspect artifacts/example_dataset # CZDataset manifest summary
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib

import numpy as np

from repro.core import SCHEMES, CompressionSpec, compression_ratio, psnr
from repro.core import container


def _inspect_container(path: str, verify: bool = True) -> bool:
    """Print a CZ container's self-description; returns CRC verdict."""
    with open(path, "rb") as f:
        magic = f.read(4)
        f.seek(0)
        header, data_start = container._read_header(f)
    sizes = header["chunk_sizes"]
    nblks = header["chunk_nblocks"]
    total = sum(sizes)
    print(f"{path}")
    print(f"  magic        {magic!r}  (container "
          f"{'CZ1 legacy' if magic == container.MAGIC_V1 else 'CZ2'}, "
          f"chunk format {header.get('format', 1)})")
    print(f"  scheme       {header.get('scheme', header['spec']['scheme'])}  "
          f"params {header.get('scheme_params', {})}")
    print(f"  dtype        {header.get('dtype', header['spec'].get('dtype', 'float32'))}")
    print(f"  field_shape  {header.get('field_shape', '(block batch)')}  "
          f"nblocks {header.get('nblocks')}  block_size {header['spec']['block_size']}")
    if header.get("raw_bytes"):
        print(f"  bytes        {total} compressed / {header['raw_bytes']} raw "
              f"(CR {header['raw_bytes']/max(1, total):.2f}x)")
    crcs = header.get("chunk_crc32", [None] * len(sizes))
    ok = True
    print(f"  {'chunk':>5} {'blocks':>7} {'bytes':>10}  crc32")
    with open(path, "rb") as f:
        f.seek(data_start)
        for i, (sz, nb, crc) in enumerate(zip(sizes, nblks, crcs)):
            buf = f.read(sz)
            if crc is None:
                verdict = "-"
            elif not verify:
                verdict = f"{crc:08x}"
            else:
                good = (zlib.crc32(buf) & 0xFFFFFFFF) == crc
                ok &= good
                verdict = f"{crc:08x} {'ok' if good else 'MISMATCH'}"
            print(f"  {i:>5} {nb:>7} {sz:>10}  {verdict}")
    print(f"  CRC verify   {'ok' if ok else 'FAILED'}")
    return ok


def _inspect_dataset(root: str, verify: bool) -> bool:
    from repro.store import CZDataset

    ok = True
    with CZDataset(root) as ds:
        print(f"{root}: CZDataset v{ds.version}, spec {ds.spec.to_json()}")
        for q in ds.quantities:
            print(f"  {q}: shape {list(ds.shape(q))} dtype {ds.dtype(q)} "
                  f"timesteps {ds.timesteps(q)}")
            for ts in ds.timestep_info(q):
                ok &= _inspect_container(os.path.join(root, ts["file"]), verify)
    return ok


def inspect_main(argv) -> int:
    ap = argparse.ArgumentParser(prog="cz-compress inspect")
    ap.add_argument("path", help="a .cz container or a CZDataset directory")
    ap.add_argument("--no-verify", action="store_true",
                    help="print CRCs without re-reading chunk data")
    args = ap.parse_args(argv)
    if os.path.isdir(args.path):
        ok = _inspect_dataset(args.path, not args.no_verify)
    else:
        ok = _inspect_container(args.path, not args.no_verify)
    return 0 if ok else 1


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "inspect":
        raise SystemExit(inspect_main(argv[1:]))

    from repro.fields import CloudConfig, cavitation_fields

    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="cavitation",
                    choices=["cavitation", "npy"])
    ap.add_argument("--npy", default="", help="input .npy for --source npy")
    ap.add_argument("--t", type=float, default=9.4, help="snapshot time (us)")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--qoi", default="p,rho,E,a2")
    ap.add_argument("--scheme", default="wavelet",
                    help=f"any registered scheme ({', '.join(sorted(SCHEMES))})")
    ap.add_argument("--list-schemes", action="store_true",
                    help="print the scheme registry and exit")
    ap.add_argument("--wavelet", default="w3ai")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--shuffle", default="byte")
    ap.add_argument("--zero-bits", type=int, default=0)
    ap.add_argument("--stage2", default="zlib")
    ap.add_argument("--precision", type=int, default=32)
    ap.add_argument("--out", default="artifacts/fields")
    ap.add_argument("--decompress", default="")
    ap.add_argument("--verify-against", default="")
    args = ap.parse_args(argv)

    if args.list_schemes:
        for name in sorted(SCHEMES):
            print(f"{name:10s} {type(SCHEMES[name]).__module__}")
        return

    if args.decompress:
        t0 = time.time()
        field = container.read_field(args.decompress)
        print(f"decompressed {field.shape} in {time.time()-t0:.2f}s")
        if args.verify_against:
            ref = np.load(args.verify_against)
            print(f"PSNR vs reference: {psnr(ref, field):.2f} dB "
                  f"maxerr {np.max(np.abs(ref-field)):.3e}")
        return

    spec = CompressionSpec(
        scheme=args.scheme, wavelet=args.wavelet, eps=args.eps,
        block_size=args.block_size, shuffle=args.shuffle,
        zero_bits=args.zero_bits, stage2=args.stage2, precision=args.precision)
    os.makedirs(args.out, exist_ok=True)

    if args.source == "npy":
        fields = {"field": np.load(args.npy).astype(np.float32)}
    else:
        fields = cavitation_fields(CloudConfig(n=args.n), args.t)
        fields = {k: v for k, v in fields.items() if k in args.qoi.split(",")}

    report = {}
    for name, f in fields.items():
        t0 = time.time()
        path = os.path.join(args.out, f"{name}.cz")
        nbytes = container.write_field(path, f, spec)
        dt = time.time() - t0
        dec = container.read_field(path)
        report[name] = {
            "cr": compression_ratio(f.nbytes, nbytes),
            "psnr_db": psnr(f, dec),
            "comp_MBps": f.nbytes / 2**20 / dt,
            "bytes": nbytes,
        }
        print(f"{name:5s} CR={report[name]['cr']:8.2f} "
              f"PSNR={report[name]['psnr_db']:7.2f} dB "
              f"{report[name]['comp_MBps']:6.1f} MB/s -> {path}")
    with open(os.path.join(args.out, "report.json"), "w") as f:
        json.dump({"spec": spec.to_json(), "fields": report}, f, indent=1)


if __name__ == "__main__":
    main()
