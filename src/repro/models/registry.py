"""Model registry facade: params/loss/prefill/decode per ArchConfig."""
from __future__ import annotations

from .transformer import (  # noqa: F401
    ModelSettings,
    cache_spec,
    count_params,
    decode_step,
    init_params,
    lm_loss,
    param_specs,
    prefill,
)


def input_batch_specs(cfg, shape, dtype_tokens="int32"):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    import jax
    import jax.numpy as jnp

    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache_spec(cfg, B, S, mode="spec"),
    }
