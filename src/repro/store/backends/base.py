"""The ``Store`` protocol: a minimal byte-store interface under CZDataset.

A store maps **keys** (relative POSIX-style paths, ``"p/t000000.cz"``) to
immutable byte objects.  The protocol is deliberately small — the shape of
an object store, not a filesystem — so any backend that can do whole-object
put and byte-range get can hold a dataset:

* :meth:`Store.get` — fetch an object, optionally a byte range of it (the
  random-access path: ``FieldReader`` pulls footers and chunks with ranged
  gets and never holds an open handle);
* :meth:`Store.get_many` — batched ranged gets (default: a sequential
  loop); remote backends override with a pipelined fetch so prefetch can
  overlap round-trips with decode;
* :meth:`Store.put` — write a whole object (members are immutable once
  written, so there is no partial update to express);
* :meth:`Store.put_atomic` — all-or-nothing overwrite, the manifest commit
  primitive.  Object stores get this for free (PUT is atomic); file
  backends implement tmp + fsync + rename;
* :meth:`Store.list` / :meth:`Store.delete` / :meth:`Store.exists` — the
  enumeration half, enough for ``CZDataset.gc``;
* :meth:`Store.open_write` — a seekable streaming sink for the CZ2 writer.
  The default buffers and commits through :meth:`put` on close (object
  stores cannot seek); :class:`FileStore` overrides it with a real file so
  the streaming writer stays one-chunk-in-memory and bit-compatible;
* :meth:`Store.lock` — a named advisory exclusive lock (sidecar commit vs.
  merge).  Default is in-process; file backends use ``flock`` so the
  guarantee spans processes.

Keys never contain ``..``, empty segments, or a leading ``/`` — a store is
a closed namespace and a key cannot escape it.
"""
from __future__ import annotations

import abc
import contextlib
import io
import threading
from concurrent.futures import ThreadPoolExecutor

__all__ = ["Store", "StoreKeyError", "StoreRangeError", "check_key",
           "check_range", "shared_io_pool"]


class StoreKeyError(KeyError):
    """The requested key is not in the store."""

    def __str__(self):  # KeyError repr()s its arg; keep messages readable
        return self.args[0] if self.args else ""


class StoreRangeError(IOError):
    """A ranged get started at or past the object's end (HTTP 416).

    Permanent like :class:`StoreKeyError` — the request can never succeed
    against the object as stored — so retry layers must not retry it.
    """

    def __init__(self, key: str, start: int, size: int):
        super().__init__(
            f"range start {start} is at/past the end of {key!r} "
            f"({size if size >= 0 else 'unknown'} bytes)")
        self.key = key
        self.start = int(start)
        self.size = int(size)


def check_range(key: str, start: int, size: int) -> int:
    """Validate a range start against an object of ``size`` bytes, per the
    :meth:`Store.get` contract: ``start == 0`` is always in range (an empty
    object reads as ``b""``), any other start must fall strictly inside the
    object.  Returns ``start`` as an int."""
    start = int(start)
    if start < 0:
        raise ValueError(f"byte_range start must be >= 0, got {start}")
    if start and start >= size:
        raise StoreRangeError(key, start, size)
    return start


_IO_POOL: ThreadPoolExecutor | None = None
_IO_POOL_GUARD = threading.Lock()


def shared_io_pool() -> ThreadPoolExecutor:
    """Process-wide daemon pool for pipelined store I/O (``get_many``
    overrides).  Deliberately separate from the reader-side prefetch pool in
    :mod:`repro.core.container` — a prefetch task fanning out through
    ``get_many`` must never wait on its own pool for the nested work."""
    global _IO_POOL
    with _IO_POOL_GUARD:
        if _IO_POOL is None:
            _IO_POOL = ThreadPoolExecutor(
                max_workers=8, thread_name_prefix="cz-store-io")
        return _IO_POOL


def check_key(key: str) -> str:
    """Validate a store key (relative POSIX path, no escape hatches)."""
    if not isinstance(key, str) or not key:
        raise ValueError(f"store key must be a non-empty str, got {key!r}")
    if key.startswith("/") or "\\" in key:
        raise ValueError(f"store key must be a relative POSIX path: {key!r}")
    if any(part in ("", ".", "..") for part in key.split("/")):
        raise ValueError(f"store key must not contain empty, '.' or '..' "
                         f"segments: {key!r}")
    return key


class _BufferedWriter(io.BytesIO):
    """Seekable write buffer that commits to ``store.put(key)`` on a clean
    close — the default ``open_write`` sink for backends that cannot seek
    inside an object.  An exception inside the ``with`` block abandons the
    buffer: object stores never expose a torn write."""

    def __init__(self, store: "Store", key: str):
        super().__init__()
        self._store: Store | None = store
        self._key = key

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._store = None  # abandon: no partial object is ever visible
        self.close()

    def close(self):
        if not self.closed:
            store, data = self._store, self.getvalue()
            super().close()
            if store is not None:
                store.put(self._key, data)


class Store(abc.ABC):
    """Abstract byte store.  See the module docstring for the contract."""

    #: URL scheme this backend answers to (``open_store`` routing), or None
    #: for backends that are only constructed programmatically.
    scheme: str | None = None

    #: True for backends that cross a network (HttpStore): ``open_store``
    #: wraps these in a RetryStore by default so transient faults are
    #: absorbed by policy, not by every caller.
    remote: bool = False

    def __init__(self):
        self._locks: dict[str, threading.Lock] = {}
        self._locks_guard = threading.Lock()

    # -- required primitives -----------------------------------------------

    @abc.abstractmethod
    def get(self, key: str, byte_range: tuple[int, int | None] | None = None
            ) -> bytes:
        """The object at ``key``, or its ``[start, end)`` slice when
        ``byte_range`` is given (``end=None`` means to the object's end).
        Raises :class:`StoreKeyError` for a missing key.

        Range semantics are pinned across all backends (the HTTP-416
        contract): a *short read is allowed only at EOF* — ``end`` past the
        object's end returns the bytes that exist from ``start`` — but a
        ``start`` at or past the object's end raises
        :class:`StoreRangeError`.  ``start == 0`` is always in range, so an
        empty object reads as ``b""`` and header probes on short objects
        still see whatever bytes exist.  Backends validate with
        :func:`check_range`."""

    @abc.abstractmethod
    def put(self, key: str, data: bytes) -> None:
        """Write the whole object at ``key`` (overwrites)."""

    @abc.abstractmethod
    def list(self, prefix: str = "") -> list[str]:
        """All keys starting with ``prefix``, sorted."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        """Remove ``key``; :class:`StoreKeyError` if absent."""

    @abc.abstractmethod
    def exists(self, key: str) -> bool:
        """Whether ``key`` holds an object."""

    # -- derived operations (override for a better native implementation) --

    def get_many(self, requests) -> list[bytes]:
        """Fetch several ``(key, byte_range)`` pairs; the async half of the
        read path (the Zarr-v3 ``async_get`` shape).  Returns the payloads
        in request order.  The default is a sequential loop — correct for
        local backends where per-request latency is negligible; remote
        backends (HttpStore, RangeStore) override with a thread-pooled
        pipelined fetch so the reader's prefetcher overlaps round-trips.

        Error semantics match N sequential :meth:`get` calls except that
        the first failure wins and the remaining results are discarded.
        """
        return [self.get(key, byte_range) for key, byte_range in requests]

    def put_atomic(self, key: str, data: bytes) -> None:
        """All-or-nothing durable overwrite — the manifest commit primitive.
        The default is :meth:`put`, correct wherever whole-object put is
        already atomic (every object store); file backends override with
        tmp + fsync + rename."""
        self.put(key, data)

    def open_write(self, key: str):
        """Context manager yielding a seekable binary sink for ``key``,
        committed on clean close.  Default: buffer + :meth:`put`."""
        check_key(key)
        return _BufferedWriter(self, key)

    def lock(self, name: str):
        """Context manager holding a named exclusive advisory lock.  The
        default is in-process (one lock object per name per store instance
        — named ``mem://`` stores share instances, so threads contend
        correctly); :class:`FileStore` uses ``flock`` to span processes."""
        with self._locks_guard:
            lk = self._locks.setdefault(name, threading.Lock())

        @contextlib.contextmanager
        def _held():
            with lk:
                yield

        return _held()

    # -- identity ----------------------------------------------------------

    @property
    def url(self) -> str:
        """Display / reopen URL for this store."""
        return f"{self.scheme or type(self).__name__.lower()}://"

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.url!r})"
