"""Optional-hypothesis shim: property tests skip cleanly on a bare interpreter.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # tier-1 runs without hypothesis
        from _hypothesis_compat import given, settings, st

When hypothesis is absent, ``@given(...)`` replaces the test with a stub
marked ``skip`` (same semantics as ``pytest.importorskip``, but scoped to the
property tests instead of the whole module, so plain tests still run).
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed")
        def _skipped_property_test():
            pass

        _skipped_property_test.__name__ = fn.__name__
        _skipped_property_test.__doc__ = fn.__doc__
        return _skipped_property_test

    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _StrategyStub:
    """Stands in for ``hypothesis.strategies``; every strategy is inert."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _StrategyStub()
