"""Per-rank manifest sidecars for multi-writer in-situ append.

A single-manifest dataset serializes every commit through one object.  In a
rank-parallel in-situ run, each rank instead owns a :class:`RankWriter`: it
writes its member objects (rank-suffixed, so ranks can never collide on a
key) and commits them to a private ``manifest.rank{r}.json`` sidecar —
atomically, with zero coordination.  A coordinator later calls
:func:`merge_manifests`, which folds every sidecar entry into the main
``manifest.json`` in one atomic commit and then retires the sidecars.

Both sides take ``root`` as a path, store URL, or
:class:`~repro.store.backends.Store` — rank-parallel append works over any
backend whose :meth:`Store.lock` is exclusive across the participating
writers (FileStore: ``flock``, so cross-process on a shared filesystem;
MemoryStore: in-process threads).

Crash safety at every point:

* rank crash mid-append  — its sidecar never references the torn member;
  the orphan is reclaimed by :meth:`CZDataset.gc`;
* crash before the merge commit — ``manifest.json`` is untouched, the
  dataset reads at its last committed state, sidecars survive and a re-run
  merges them;
* crash after the commit but before sidecar cleanup — the re-run sees every
  entry already committed (merge is idempotent) and just deletes sidecars.
"""
from __future__ import annotations

import numpy as np

from repro.core import container
from repro.core.pipeline import CompressionSpec
from repro.store.backends import open_store
from repro.store.dataset import _member_stats
from repro.store.manifest import (
    QUANTITY_RE,
    ManifestError,
    list_rank_manifests,
    new_rank_manifest,
    rank_manifest_name,
    read_manifest,
    read_rank_manifest,
    write_manifest,
    write_rank_manifest,
)
from repro.store.writer import ShardWriter

__all__ = ["RankWriter", "merge_manifests"]

#: advisory lock serializing sidecar commits against sidecar retirement.
#: Held for sidecar commit (RankWriter.append) and sidecar retirement
#: (merge_manifests): without it, an entry committed between the merge's
#: final re-read and its delete would vanish.  Member writes stay outside
#: the lock — only the tiny JSON commit is serialized, so rank contention
#: is negligible (the whole point of sidecars).
_LOCK_NAME = ".sidecar.lock"


class RankWriter:
    """One rank's append channel into a shared CZDataset.

    The dataset (and its committed default spec) must already exist — the
    coordinator creates it once with ``CZDataset(root, "a", spec=...)``
    before the ranks start.  Timestep indices are supplied by the caller
    (the simulation's step counter), not allocated from ``next_t``, since
    ranks commit independently.
    """

    def __init__(self, root, rank: int, spec: CompressionSpec | None = None,
                 workers: int = 1, stats: bool = False):
        self.store = open_store(root)
        self.root = str(root)
        self.rank = int(rank)
        if self.rank < 0:
            raise ValueError(f"rank must be >= 0, got {rank}")
        m = read_manifest(self.store)  # dataset must exist
        self.spec = (CompressionSpec.from_json(m["spec"]) if spec is None
                     else spec.validate())
        self._writer = ShardWriter(self.spec, workers=workers)
        self._stats = bool(stats)
        try:
            self._side = read_rank_manifest(self.store, self.rank)
        except FileNotFoundError:
            self._side = new_rank_manifest(self.rank)

    def member_name(self, quantity: str, t: int) -> str:
        """Rank-suffixed member key — two ranks can never collide."""
        return f"{quantity}/t{int(t):06d}.r{self.rank}.cz"

    def append(self, fields: dict[str, np.ndarray], t: int,
               time: float | None = None) -> int:
        """Write member objects, then commit them to this rank's sidecar.

        Uncommitted (merged) entries are invisible to dataset readers until
        :func:`merge_manifests` folds the sidecar into the main manifest.
        """
        if not fields:
            raise ValueError("append needs at least one quantity")
        t = int(t)
        done = {(e["quantity"], e["t"]) for e in self._side["entries"]}
        staged = []
        for q, field in fields.items():
            if not QUANTITY_RE.match(q):
                raise ValueError(f"invalid quantity name {q!r}")
            if (q, t) in done:
                raise ValueError(
                    f"rank {self.rank} already appended {q!r} at t={t}")
            field = np.asarray(field)
            rel = self.member_name(q, t)
            if self.store.exists(rel):
                # members are immutable; an existing object means this (q, t)
                # was already written — merged-and-committed (a restarted
                # rank replaying a step) or orphaned by a crash.  Rewriting
                # in place could tear a committed member; refuse.
                raise IOError(
                    f"member {rel} already exists (committed or orphaned); "
                    "refusing to overwrite — gc the dataset or use a new t")
            member_spec = self._writer.spec_for(field)
            nbytes = self._writer.write(
                rel, field, spec=member_spec,
                extra_header={"quantity": q, "t": t, "time": time,
                              "rank": self.rank},
                store=self.store)
            entry = {
                "quantity": q, "t": t, "time": time, "file": rel,
                "bytes": int(nbytes), "raw_bytes": int(field.nbytes),
                "shape": list(field.shape),
                "dtype": str(member_spec.np_dtype),
            }
            if self._stats:
                entry.update(_member_stats(
                    field, container.read_field(rel, store=self.store)))
            staged.append(entry)
        # all members durable in the store -> one atomic sidecar commit.  The
        # stored sidecar is the truth for *unmerged* entries (a concurrent
        # merge may have retired some), so reconcile under the lock first —
        # a long-lived writer must not resurrect already-merged history.
        with self.store.lock(_LOCK_NAME):
            try:
                self._side = read_rank_manifest(self.store, self.rank)
            except FileNotFoundError:
                self._side = new_rank_manifest(self.rank)
            self._side["entries"].extend(staged)
            write_rank_manifest(self.store, self._side)
        return t

    @property
    def pending(self) -> int:
        """Entries committed to this rank's sidecar but not yet merged
        (read from the store — a concurrent merge may have retired some)."""
        try:
            return len(read_rank_manifest(self.store, self.rank)["entries"])
        except FileNotFoundError:
            return 0

    def close(self) -> None:
        self._writer.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _committed(m: dict) -> dict[tuple[str, int], str]:
    return {(q, int(ts["t"])): ts["file"]
            for q, ent in m["quantities"].items()
            for ts in ent["timesteps"]}


def merge_manifests(root, remove_sidecars: bool = True) -> int:
    """Fold every rank sidecar into ``manifest.json`` in one atomic commit.

    Returns the number of newly merged entries.  Idempotent: entries already
    in the manifest are skipped, so re-running after a crash at any point
    converges.  Raises :class:`ManifestError` — *before* touching the main
    manifest — on a conflict (two different members claim one
    quantity/timestep), a sidecar referencing a missing member, or a shape
    mismatch; the dataset stays readable at its last committed state.
    """
    store = open_store(root)
    m = read_manifest(store)
    committed = _committed(m)
    ranks = list_rank_manifests(store)
    pending: list[tuple[int, dict]] = []
    for rank in ranks:
        side = read_rank_manifest(store, rank)
        for e in side["entries"]:
            key = (e["quantity"], int(e["t"]))
            if key in committed:
                if committed[key] != e["file"]:
                    raise ManifestError(
                        f"merge conflict in {store.url}: rank {rank} wrote "
                        f"{e['file']} for {key[0]!r} t={key[1]} but "
                        f"{committed[key]} is already committed")
                continue  # already merged (idempotent re-run)
            if not store.exists(e["file"]):
                raise ManifestError(
                    f"rank {rank} sidecar references missing member "
                    f"{e['file']} — refusing to commit a torn timestep")
            committed[key] = e["file"]
            pending.append((rank, e))

    if pending:
        pending.sort(key=lambda p: (int(p[1]["t"]), p[1]["quantity"], p[0]))
        touched = set()
        for rank, e in pending:
            q, t = e["quantity"], int(e["t"])
            ent = m["quantities"].setdefault(q, {
                "shape": list(e["shape"]),
                "dtype": str(e["dtype"]),
                "timesteps": [],
            })
            if tuple(ent["shape"]) != tuple(e["shape"]):
                raise ManifestError(
                    f"rank {rank} appended {q!r} with shape {e['shape']}, "
                    f"dataset has {ent['shape']}")
            if str(ent["dtype"]) != str(e["dtype"]):
                raise ManifestError(
                    f"rank {rank} appended {q!r} with dtype {e['dtype']}, "
                    f"dataset has {ent['dtype']}")
            rec = {"t": t, "time": e["time"], "file": e["file"],
                   "bytes": int(e["bytes"]), "raw_bytes": int(e["raw_bytes"])}
            for k in ("psnr", "max_err"):
                if k in e:
                    rec[k] = e[k]
            ent["timesteps"].append(rec)
            touched.add(q)
            m["next_t"] = max(int(m["next_t"]), t + 1)
        for q in touched:
            m["quantities"][q]["timesteps"].sort(key=lambda ts: ts["t"])
        m["version"] = int(m["version"]) + 1
        write_manifest(store, m)  # the single atomic commit point

    if remove_sidecars:
        # a rank may have committed new entries after we read its sidecar:
        # under the sidecar lock (which serializes us against every rank's
        # commit), re-read, keep anything not yet in the manifest, and only
        # retire a fully merged sidecar — concurrent appends are never
        # dropped
        for rank in ranks:
            with store.lock(_LOCK_NAME):
                try:
                    side = read_rank_manifest(store, rank)
                except FileNotFoundError:
                    continue
                remaining = [
                    e for e in side["entries"]
                    if committed.get((e["quantity"], int(e["t"]))) != e["file"]
                ]
                if remaining:
                    side["entries"] = remaining
                    write_rank_manifest(store, side)
                else:
                    store.delete(rank_manifest_name(rank))
    return len(pending)
