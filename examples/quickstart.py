"""Quickstart: compress a 3D scientific field with every codec in 20 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CompressionSpec, analyze_field
from repro.fields import CloudConfig, cavitation_fields

# a cloud-cavitation pressure snapshot (the paper's flagship dataset)
field = cavitation_fields(CloudConfig(n=64), t=9.4)["p"]

for spec in [
    CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3),   # paper's best
    CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-2, zero_bits=8),
    CompressionSpec(scheme="zfpx", eps=1e-3),
    CompressionSpec(scheme="szx", eps=1e-3),
    CompressionSpec(scheme="fpzipx", precision=32),                # lossless
]:
    r = analyze_field(field, spec)
    print(f"{spec.scheme:8s} eps={spec.eps:g} -> CR {r['cr']:7.2f}x  "
          f"PSNR {r['psnr']:7.2f} dB  max|err| {r['max_err']:.2e}")
