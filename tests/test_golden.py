"""Golden-file regression: committed CZ1 + CZ2 fixtures must decode
byte-exact forever.

The fixtures under ``tests/data/`` were written by the code at the time of
their commit (see ``tests/data/make_golden.py``); these tests assert the
*current* code reproduces the committed decodes bit-for-bit.  A future
``CODEC_FORMAT`` bump, a scheme byte-layout change without a ``decode_spec``
shim, or a drift in the transform math breaks here first — old containers
can't silently rot.
"""
import os

import numpy as np
import pytest

from repro.core import CODEC_FORMAT, container

DATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")

#: (fixture stem, container generation, scheme, decode is input-lossless)
GOLDENS = [
    ("cz1_raw", 1, "raw", True),
    ("cz1_szx", 1, "szx", False),
    ("cz2_wavelet", 2, "wavelet", False),
    ("cz2_lorenzo", 2, "lorenzo", False),
    ("cz2_zfpx", 2, "zfpx", False),
    ("cz2_auto", 2, "auto", False),
]


def _fixture(name: str) -> str:
    path = os.path.join(DATA, name)
    assert os.path.exists(path), \
        f"missing golden fixture {name}; regenerate via tests/data/make_golden.py"
    return path


@pytest.mark.parametrize("stem,gen,scheme,lossless", GOLDENS,
                         ids=[g[0] for g in GOLDENS])
def test_golden_decodes_byte_exact(stem, gen, scheme, lossless):
    decoded = container.read_field(_fixture(f"{stem}.cz"))
    expected = np.load(_fixture(f"{stem}.decoded.npy"))
    np.testing.assert_array_equal(decoded, expected, strict=True)
    if lossless:
        np.testing.assert_array_equal(
            decoded, np.load(_fixture("golden_input.npy")), strict=True)


@pytest.mark.parametrize("stem,gen,scheme,lossless", GOLDENS,
                         ids=[g[0] for g in GOLDENS])
def test_golden_headers_pin_their_generation(stem, gen, scheme, lossless):
    with open(_fixture(f"{stem}.cz"), "rb") as f:
        magic = f.read(4)
        f.seek(0)
        header, _ = container._read_header(f)
    assert magic == (container.MAGIC_V1 if gen == 1 else container.MAGIC)
    assert header["spec"]["scheme"] == scheme
    # CZ1 headers predate the format field (reader backfills 1); CZ2 fixtures
    # record the format they were written under — decode must keep honouring
    # it through Scheme.decode_spec even after CODEC_FORMAT moves on
    assert header.get("format", 1) <= CODEC_FORMAT
    if gen == 1:
        # seed-era specs had no dtype/device keys; both must default cleanly
        assert "device" not in header["spec"] and "dtype" not in header["spec"]


def test_golden_auto_fixture_is_genuinely_mixed():
    """The committed ``auto`` fixture pins a *mixed-scheme* container: the
    footer records at least two distinct per-chunk winners, each chunk's
    prelude dispatches its own decoder, and the whole decode honours the
    abs target relative to the committed input."""
    path = _fixture("cz2_auto.cz")
    d = container.describe(path, verify=True)
    assert d["crc_ok"]
    assert len(d["schemes"]) >= 2, d["schemes"]
    assert sum(d["schemes"].values()) == len(d["chunks"])
    assert all("scheme" in row for row in d["chunks"])
    field = np.load(_fixture("golden_auto_input.npy"))
    err = np.max(np.abs(container.read_field(path) - field))
    assert err <= 1e-3 * (1 + 1e-4), err  # default target: abs=spec.eps


def test_golden_error_bound_still_holds():
    """The lossy fixtures must stay within their schemes' declared bounds
    relative to the committed input — decode drift within byte-identity is
    impossible, but this guards the fixtures themselves against bad
    regeneration."""
    from repro.core.schemes import get_scheme
    from repro.core.pipeline import CompressionSpec

    field = np.load(_fixture("golden_input.npy"))
    for stem in ("cz1_szx", "cz2_wavelet", "cz2_lorenzo", "cz2_zfpx"):
        with open(_fixture(f"{stem}.cz"), "rb") as f:
            header, _ = container._read_header(f)
        spec = CompressionSpec.from_json(header["spec"])
        bound = get_scheme(spec.scheme).error_bound(spec)
        err = np.max(np.abs(container.read_field(_fixture(f"{stem}.cz")) - field))
        ulp = float(np.spacing(np.float32(np.abs(field).max())))
        assert err <= bound * (1 + 1e-4) + ulp, (stem, err, bound)
