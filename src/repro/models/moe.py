"""Mixture-of-Experts FFN: GShard-style grouped dispatch with capacity.

Tokens are processed in groups of ``cfg.moe_group``; per group each token's
top-k experts get a capacity slot (rank = order within the group, tokens
over capacity are dropped — combine weight 0).  Dispatch/combine are dense
einsums with static shapes, so the layer shards cleanly: the expert
dimension E lives on the "model" mesh axis (expert parallelism) and GSPMD
inserts the token<->expert all-to-alls.

Aux losses (load-balance + router z-loss) are returned for the train loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import gelu, swiglu_act

__all__ = ["moe_ffn", "dense_ffn"]


def dense_ffn(x, p, cfg):
    if cfg.act == "swiglu":
        h = swiglu_act(jnp.einsum("...d,df->...f", x, p["w1"]),
                       jnp.einsum("...d,df->...f", x, p["w3"]))
    else:
        h = gelu(jnp.einsum("...d,df->...f", x, p["w1"]))
    return jnp.einsum("...f,fd->...d", h, p["w2"])


def _expert_ffn(xin, p, cfg):
    """xin (E, N, D) -> (E, N, D), expert weights stacked on axis 0."""
    if cfg.act == "swiglu":
        h = swiglu_act(jnp.einsum("end,edf->enf", xin, p["we1"]),
                       jnp.einsum("end,edf->enf", xin, p["we3"]))
    else:
        h = gelu(jnp.einsum("end,edf->enf", xin, p["we1"]))
    return jnp.einsum("enf,efd->end", h, p["we2"])


def moe_ffn(x, p, cfg):
    """x (B, S, D) -> (out (B, S, D), aux_losses dict)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group, B * S)
    N = B * S
    G = N // g
    assert N % g == 0, f"tokens {N} not divisible by moe group {g}"
    C = max(4, -(-g * K * int(cfg.capacity_factor * 100) // 100 // E))

    xg = x.reshape(G, g, D)
    logits = jnp.einsum("gnd,de->gne", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (G,g,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)          # (G,g,K,E)
    # rank of each assignment within its expert (priority: token order, then k)
    flat = onehot.reshape(G, g * K, E)
    rank = jnp.cumsum(flat, axis=1) - flat                        # exclusive
    keep = (rank < C) * flat                                      # (G,gK,E)
    rank = jnp.where(keep > 0, rank, 0.0)
    pos_oh = jax.nn.one_hot(rank.astype(jnp.int32), C, dtype=jnp.float32) * keep[..., None]
    # (G, gK, E, C) -> fold k back onto tokens
    pos_oh = pos_oh.reshape(G, g, K, E, C)
    combine = (pos_oh * top_p[..., None, None]).sum(2)            # (G,g,E,C)
    dispatch = (pos_oh.sum(2) > 0).astype(x.dtype)                # (G,g,E,C)

    expert_in = jnp.einsum("gnec,gnd->gecd", dispatch, xg)       # (G,E,C,D)
    ein = expert_in.transpose(1, 0, 2, 3).reshape(E, G * C, D)
    eout = _expert_ffn(ein, p, cfg)
    eout = eout.reshape(E, G, C, D).transpose(1, 0, 2, 3)         # (G,E,C,D)
    out = jnp.einsum("gecd,gnec->gnd", eout, combine.astype(x.dtype))

    if cfg.shared_expert:
        out = out + dense_ffn(x, {"w1": p["ws1"], "w3": p["ws3"], "w2": p["ws2"]}, cfg).reshape(G, g, D)

    # aux losses (Switch/GShard style)
    density = flat.reshape(G, g, K, E).sum(2).mean(1)             # (G,E) token fraction
    mean_prob = probs.mean(1)                                     # (G,E)
    lb = (density * mean_prob).sum(-1).mean() * (E ** 2) / K
    z = (jax.nn.logsumexp(logits, axis=-1) ** 2).mean()
    dropped = 1.0 - (keep.sum() / jnp.maximum(flat.sum(), 1.0))
    aux = {"load_balance": lb, "router_z": z, "drop_fraction": dropped}
    return out.reshape(B, S, D), aux
