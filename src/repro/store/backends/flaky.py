"""FlakyStore: fault-injection wrapper for read-path resilience tests.

Wraps any :class:`Store` and fails the Nth ``get`` (and every ``fail_every``
afterwards, if configured) with an injected :class:`IOError`.  Everything
else delegates untouched, so a dataset written through the inner store can
be read through a flaky view of it — proving that a mid-``read_box`` fetch
failure surfaces as a clean error and that an immediate retry succeeds
against intact caches.
"""
from __future__ import annotations

import threading

from .base import Store

__all__ = ["FlakyStore", "InjectedFault"]


class InjectedFault(IOError):
    """The configured fault, raised by :class:`FlakyStore`."""


class FlakyStore(Store):
    """Delegating store that raises on the ``fail_on_get``-th get call.

    ``fail_on_get`` counts 1-based across the wrapper's lifetime and may be
    reassigned between operations (``flaky.fail_on_get = flaky.gets + 1``
    arms the *next* get).  ``fail_every`` repeats the failure periodically
    after the first; ``None`` (default) fails exactly once.
    """

    def __init__(self, inner: Store, fail_on_get: int | None = None,
                 fail_every: int | None = None):
        super().__init__()
        self.inner = inner
        self.fail_on_get = fail_on_get
        self.fail_every = fail_every
        self.gets = 0
        self.faults = 0
        self._count_guard = threading.Lock()

    def _maybe_fail(self) -> None:
        with self._count_guard:
            self.gets += 1
            n, first = self.gets, self.fail_on_get
            if first is None or n < first:
                return
            if n == first or (self.fail_every
                              and (n - first) % self.fail_every == 0):
                self.faults += 1
                raise InjectedFault(
                    f"injected fault on get #{n} (fail_on_get={first})")

    def get(self, key, byte_range=None):
        self._maybe_fail()
        return self.inner.get(key, byte_range)

    def put(self, key, data):
        self.inner.put(key, data)

    def put_atomic(self, key, data):
        self.inner.put_atomic(key, data)

    def list(self, prefix=""):
        return self.inner.list(prefix)

    def delete(self, key):
        self.inner.delete(key)

    def exists(self, key):
        return self.inner.exists(key)

    def open_write(self, key):
        return self.inner.open_write(key)

    def lock(self, name):
        return self.inner.lock(name)

    @property
    def url(self) -> str:
        return f"flaky+{self.inner.url}"
