"""Error-feedback compressed cross-pod gradient reduction.

The CubismZ insight applied to the training fabric: the pod-to-pod
interconnect is the slowest link, and gradients tolerate ε-bounded lossy
compression *with error feedback*.  Structure:

* ``shard_map`` manual over the "pod" axis only ("data"/"model" stay under
  GSPMD inside the body) — each pod computes gradients on its half of the
  global batch;
* per-leaf top-k selection (the fixed-shape TPU analogue of the paper's
  wavelet threshold decimation — see ``repro.core.threshold.topk_details``)
  on the error-feedback-corrected gradient;
* the (values, indices) payload — 2*k*(4+4) bytes instead of 4*n — is
  all-gathered across pods and scatter-added locally;
* the unsent residual is carried to the next step (error feedback), which
  keeps convergence close to dense all-reduce (bench_gradcomp.py).

Cross-pod traffic drops by ~ratio/4 (values+indices vs dense f32); the
effect is visible in the dry-run HLO as smaller all-gather operand sizes on
the pod groups (§Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pod_compressed_grads", "topk_compress", "topk_decompress"]


_BLOCK = 1 << 20   # top-k block length (paper-style block-structured selection)


def topk_compress(g, ratio: int):
    """Blockwise top-|k| selection: the flat tensor is split into 2^20-long
    blocks and each keeps its top (block/ratio) entries — the fixed-shape,
    int32-safe analogue of the paper's per-block threshold decimation
    (billion-element stacked leaves overflow a single top_k).
    Returns (vals (nb, k), idx int32 (nb, k) block-local)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.size
    bl = min(_BLOCK, n)
    pad = (-n) % bl
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    rows = flat.reshape(-1, bl)
    k = max(1, bl // ratio)
    _, idx = jax.lax.top_k(jnp.abs(rows), k)
    vals = jnp.take_along_axis(rows, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def topk_decompress(vals, idx, shape):
    n = 1
    for s in shape:
        n *= s
    bl = min(_BLOCK, n)
    nb = -(-n // bl)
    rows = jnp.zeros((nb, bl), jnp.float32)
    rows = rows.at[jnp.arange(nb)[:, None], idx].add(vals)
    return rows.reshape(-1)[:n].reshape(shape)


def pod_compressed_grads(loss_fn, params, residual, batch, cfg, settings,
                         mesh, method: str = "topk32"):
    """Returns ((loss, metrics), grads, new_residual, compress_metrics).

    ``loss_fn(params)`` must close over nothing pod-dependent; the batch is
    split across pods here.
    """
    import dataclasses

    ratio = int(method.replace("topk", "") or 32)
    n_pods = mesh.shape["pod"]

    from repro.models import lm_loss

    # inside the manual-"pod" region only auto axes may appear in sharding
    # constraints; the per-pod batch is sharded over "data" alone
    settings = dataclasses.replace(
        settings,
        batch_axes=tuple(a for a in settings.batch_axes if a != "pod"),
        n_batch=max(1, settings.n_batch // n_pods))

    def body(params, residual, batch):
        # per-pod gradients on this pod's slice of the global batch
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg, settings), has_aux=True)(params)

        def one_small(g, r):
            corrected = g.astype(jnp.float32) + r
            vals, idx = topk_compress(corrected, ratio)
            # what this pod actually transmits
            sent = topk_decompress(vals, idx, g.shape)
            new_r = corrected - sent
            # exchange compressed payloads across pods; replicate the
            # payload within the pod first so the pod-axis collective has
            # trivial device groups (SPMD partitioner CHECKs otherwise)
            vals = jax.lax.with_sharding_constraint(vals, P(None, None))
            idx = jax.lax.with_sharding_constraint(idx, P(None, None))
            all_vals = jax.lax.all_gather(vals, "pod")      # (n_pods, nb, k)
            all_idx = jax.lax.all_gather(idx, "pod")
            mean = sum(
                topk_decompress(all_vals[i], all_idx[i], g.shape)
                for i in range(n_pods)) / n_pods
            return mean.astype(g.dtype), new_r

        def one(g, r):
            if g.size < 4 * ratio:          # tiny leaf: send dense
                g_sum = jax.lax.psum(g, "pod") / n_pods
                return g_sum, jnp.zeros_like(r)
            if g.size < (1 << 28):
                return one_small(g, r)
            # XLA-CPU top-k/scatter lowerings abort near the int32 element
            # boundary: loop the leading (layer-stack) dim, slices stay small
            L0 = g.shape[0]
            gs = g.reshape(L0, -1)
            rs = r.reshape(L0, -1)
            out_g, out_r = jax.lax.map(lambda ab: one_small(ab[0], ab[1]),
                                       (gs, rs))
            return out_g.reshape(g.shape).astype(g.dtype), out_r.reshape(g.shape)

        out = jax.tree.map(one, grads, residual)
        new_grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_resid = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        return (loss, metrics), new_grads, new_resid

    # manual over "pod" only; GSPMD keeps handling data/model inside
    shmapped = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(), P("pod")),
        out_specs=((P(), P()), P(), P()),
        axis_names=frozenset({"pod"}),
        check_vma=False,
    )
    batch_split = jax.tree.map(lambda a: a, batch)  # batch dim: P("pod") slices
    (loss, metrics), grads, new_residual = shmapped(params, residual, batch_split)
    n_leaves = len(jax.tree.leaves(params))
    cmx = {"grad_compress_ratio": jnp.float32(ratio),
           "grad_compress_leaves": jnp.float32(n_leaves)}
    return (loss, metrics), grads, new_residual, cmx
