"""Always-on tail-based trace sampling for the serve tier.

Head sampling (trace every Nth request) misses exactly the requests worth
keeping; tail sampling records *every* request cheaply and decides at
completion.  The flow:

1. each request runs inside a collecting :class:`~repro.obs.context.
   RequestContext` — every span the request touched lands on its bounded
   per-request timeline;
2. at completion, :meth:`TailSampler.finish` keeps the trace iff the
   request **errored** or its latency landed **at or above the tail
   threshold** — by default the live p99 estimated from the serve tier's
   own ``cz_serve_request_seconds`` histogram (so the definition of "slow"
   tracks the traffic, not a hardcoded constant);
3. kept traces live in a byte-budgeted FIFO (oldest evicted first) exposed
   at ``GET /debug/traces`` / ``/debug/traces/{id}``, and each keep
   attaches an OpenMetrics exemplar to the latency histogram — the
   ``/metrics`` bucket points at the trace that exemplifies it (exemplars
   reach scrapers only on OpenMetrics-negotiated renders; the legacy
   0.0.4 exposition stays exemplar-free).

Everything else is dropped on the floor at request end: steady-state
traffic pays one context allocation and a handful of bounded list appends
per request.

Stdlib only — importable before numpy/jax.
"""
from __future__ import annotations

import collections
import json
import threading
import time

from repro.obs import events as _events
from repro.obs.context import RequestContext
from repro.obs.registry import Histogram

__all__ = ["TailSampler", "chrome_trace"]


class TailSampler:
    """Keep-the-interesting-tail trace retention for one serve front.

    Parameters
    ----------
    latency:
        The live request-latency :class:`~repro.obs.registry.Histogram`
        (the serve tier's ``cz_serve_request_seconds``) — both the source
        of the dynamic slow threshold and the target for exemplars.
    budget_bytes:
        Hard cap on retained trace bytes (JSON-encoded size); oldest
        retained traces are evicted first.
    slow_s:
        Fixed slow threshold in seconds.  ``None`` (default) tracks the
        live ``quantile`` of ``latency`` instead.
    quantile / min_count / default_slow_s:
        Dynamic-threshold shape: the threshold is the upper bound of the
        first bucket whose cumulative count reaches ``quantile`` of the
        total — once at least ``min_count`` requests have been observed;
        before that (cold start) ``default_slow_s`` applies.
    max_traces:
        Secondary cap on the number of retained traces.
    """

    def __init__(self, latency: Histogram, budget_bytes: int = 4 << 20,
                 slow_s: float | None = None, quantile: float = 0.99,
                 min_count: int = 100, default_slow_s: float = 0.25,
                 max_traces: int = 256):
        if not isinstance(latency, Histogram):
            raise TypeError("TailSampler needs the live latency Histogram")
        if not 0.0 < quantile < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {quantile}")
        self.latency = latency
        self.budget_bytes = int(budget_bytes)
        self.slow_s = None if slow_s is None else float(slow_s)
        self.quantile = float(quantile)
        self.min_count = int(min_count)
        self.default_slow_s = float(default_slow_s)
        self.max_traces = int(max_traces)
        self._lock = threading.Lock()
        self._traces: collections.OrderedDict[str, dict] = \
            collections.OrderedDict()
        self._bytes = 0
        self.sampled = 0
        self.kept_error = 0
        self.kept_slow = 0
        self.evicted = 0

    # -- decision ------------------------------------------------------------

    def threshold(self) -> float:
        """The current slow threshold in seconds (fixed or live-quantile)."""
        if self.slow_s is not None:
            return self.slow_s
        snap = self.latency.snapshot()
        total = snap["count"]
        if total < self.min_count:
            return self.default_slow_s
        target = self.quantile * total
        prev = 0.0
        for bound, cum in snap["buckets"]:
            if cum >= target:
                # a request at/above this bucket's bound is in the tail; the
                # +Inf bucket has no usable bound — fall back to the last
                # finite one (keeps a little more than 1 - quantile)
                return prev if bound == float("inf") else bound
            prev = bound
        return self.default_slow_s  # unreachable (last bucket is +Inf)

    def finish(self, ctx: RequestContext | None, duration_s: float,
               error: str | None = None) -> bool:
        """Decide one completed request; returns True iff its trace was
        kept.  Idempotent per context (``ctx.finished`` latch) and safe to
        call with ``ctx=None`` (nothing was collected — a no-op)."""
        if ctx is None or ctx.finished:
            return False
        ctx.finished = True
        with self._lock:
            self.sampled += 1
        reason = ("error" if error is not None
                  else "slow" if duration_s >= self.threshold()
                  else None)
        if reason is None:
            return False
        rec = {
            "request_id": ctx.rid,
            "reason": reason,
            "error": error,
            "duration_ms": round(duration_s * 1e3, 3),
            "wall_time": round(ctx.wall_time, 6),
            "events": list(ctx.events),
            "dropped_events": ctx.dropped,
        }
        rec["bytes"] = len(json.dumps(rec, default=str).encode())
        with self._lock:
            old = self._traces.pop(ctx.rid, None)
            if old is not None:  # client-reused ID: newest wins
                self._bytes -= old["bytes"]
            self._traces[ctx.rid] = rec
            self._bytes += rec["bytes"]
            if reason == "error":
                self.kept_error += 1
            else:
                self.kept_slow += 1
            while self._traces and (self._bytes > self.budget_bytes
                                    or len(self._traces) > self.max_traces):
                _, dropped = self._traces.popitem(last=False)
                self._bytes -= dropped["bytes"]
                self.evicted += 1
        self.latency.exemplar(duration_s, ctx.rid)
        _events.event("trace.kept", level="debug", reason=reason,
                      duration_ms=rec["duration_ms"],
                      trace_bytes=rec["bytes"])
        return True

    # -- readback ------------------------------------------------------------

    def traces(self) -> list[dict]:
        """Summaries of the retained set, oldest first (the
        ``/debug/traces`` listing)."""
        with self._lock:
            items = list(self._traces.values())
        return [{k: r[k] for k in ("request_id", "reason", "error",
                                   "duration_ms", "wall_time", "bytes")}
                | {"events": len(r["events"])}
                for r in items]

    def get(self, request_id: str) -> dict:
        """One retained trace in full (KeyError if not retained)."""
        with self._lock:
            return dict(self._traces[request_id])

    def stats(self) -> dict:
        with self._lock:
            return {
                "sampled": self.sampled,
                "kept_error": self.kept_error,
                "kept_slow": self.kept_slow,
                "evicted": self.evicted,
                "retained": len(self._traces),
                "bytes": self._bytes,
                "budget_bytes": self.budget_bytes,
                "threshold_s": self.threshold(),
            }

    def clear(self) -> None:
        with self._lock:
            self._traces.clear()
            self._bytes = 0


def chrome_trace(rec: dict) -> dict:
    """One retained trace record as a Chrome trace-event document — load
    the response of ``/debug/traces/{id}?format=chrome`` straight into
    Perfetto."""
    events = [{"name": ev["name"], "ph": "X", "cat": "repro",
               "ts": ev["ts_us"], "dur": ev["dur_us"], "pid": 0, "tid": 0,
               **({"args": ev["args"]} if ev.get("args") else {})}
              for ev in rec.get("events", [])]
    meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": f"request {rec.get('request_id', '?')}"}}]
    return {"traceEvents": meta + sorted(events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
            "metadata": {"request_id": rec.get("request_id"),
                         "reason": rec.get("reason"),
                         "duration_ms": rec.get("duration_ms"),
                         "epoch_us": int(rec.get("wall_time", time.time())
                                         * 1e6)}}
