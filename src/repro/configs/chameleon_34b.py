"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818].

Dense decoder; the VQ image tokenizer is a stub (image tokens are ordinary
ids inside the 65536 vocab, per the assignment: frontend provides token ids).
Chameleon uses qk-norm for training stability — enabled here.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    rope_theta=1e4,
    notes="early-fusion VQ image tokens enter as vocab ids (frontend stubbed)",
)
