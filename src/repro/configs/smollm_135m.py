"""smollm-135m — llama-architecture small model [hf:HuggingFaceTB/SmolLM-135M].

Also the end-to-end *trained* example (examples/train_smollm.py)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    tie_embeddings=True,
    rope_theta=1e4,
)
