"""Pallas TPU kernel: multi-level 3D wavelet transform over a block batch.

TPU adaptation of the paper's core-layer wavelet kernels.  The CPU code uses
4-tap stencil loops; on TPU we express each 1D predict/update step as a small
dense banded matmul ``s @ P^T`` — the prediction matrix P (coarse_len x
coarse_len) encodes the interior stencil *and* the one-sided boundary
stencils, so the MXU does the whole "on the interval" transform with no
gather and no divergent control flow.  All levels are statically unrolled
inside one kernel invocation; each grid step owns a tile of whole blocks
resident in VMEM.  The per-level matrices are kernel operands (Pallas
forbids captured constants) with a constant index map — they stay resident.

VMEM budget: a tile of ``TB`` 32-cubed fp32 blocks is 128 KiB * TB for input
plus the same for output; the default TB=4 keeps the working set ~1 MiB,
comfortably inside v5e VMEM while giving the MXU (m x m) x (m x m) matmuls
with m in {16, 8, 4}.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wavelets as wv

__all__ = ["wavelet3d_forward", "wavelet3d_inverse", "DEFAULT_TILE_BLOCKS"]

DEFAULT_TILE_BLOCKS = 4


@functools.lru_cache(maxsize=None)
def _matrices(kind: str, m: int) -> tuple[np.ndarray, np.ndarray]:
    """(P, U): predicted_odds = s @ P.T ; lifted s' = s + d @ U.T (w4l only)."""
    idx, W = wv._predict_table(kind, m)
    P = np.zeros((m, m), np.float32)
    for i in range(m):
        for j in range(idx.shape[1]):
            P[i, idx[i, j]] += W[i, j]
    U = np.zeros((m, m), np.float32)
    if kind == "w4l":
        for i in range(m):
            U[i, i] += 0.25
            U[i, max(i - 1, 0)] += 0.25
    return P, U


def _fwd_axis_last(x, kind: str, Pt, Ut):
    """One forward step along the last axis via banded matmuls (in-kernel)."""
    n = x.shape[-1]
    m = n // 2
    pairs = x.reshape(*x.shape[:-1], m, 2)
    e, o = pairs[..., 0], pairs[..., 1]
    if kind in ("w4i", "w4l"):
        s = e
        d = o - s @ Pt
        if kind == "w4l":
            s = s + d @ Ut
    else:  # w3ai
        s = (e + o) * 0.5
        d = o - s @ Pt
    return jnp.concatenate([s, d], axis=-1)


def _inv_axis_last(x, kind: str, Pt, Ut):
    n = x.shape[-1]
    m = n // 2
    s, d = x[..., :m], x[..., m:]
    if kind in ("w4i", "w4l"):
        if kind == "w4l":
            s = s - d @ Ut
        o = d + s @ Pt
        e = s
    else:
        o = d + s @ Pt
        e = 2.0 * s - o
    return jnp.stack([e, o], axis=-1).reshape(*x.shape[:-1], n)


def _axis_step(x, axis, kind, Pt, Ut, inverse):
    x = jnp.moveaxis(x, axis, -1)
    x = (_inv_axis_last if inverse else _fwd_axis_last)(x, kind, Pt, Ut)
    return jnp.moveaxis(x, -1, axis)


def _kernel(x_ref, *rest, kind: str, levels: int, inverse: bool):
    o_ref = rest[-1]
    mats = [r[...] for r in rest[:-1]]          # [Pt_0, Ut_0, Pt_1, Ut_1, ...]
    x = x_ref[...]
    n = x.shape[-1]
    if not inverse:
        out = x
        for lvl in range(levels):
            c = n >> lvl
            Pt, Ut = mats[2 * lvl], mats[2 * lvl + 1]
            sub = out[..., :c, :c, :c]
            for axis in (-3, -2, -1):
                sub = _axis_step(sub, axis, kind, Pt, Ut, False)
            out = sub if c == n else out.at[..., :c, :c, :c].set(sub)
    else:
        out = x
        for lvl in reversed(range(levels)):
            c = n >> lvl
            Pt, Ut = mats[2 * lvl], mats[2 * lvl + 1]
            sub = out[..., :c, :c, :c]
            for axis in (-1, -2, -3):
                sub = _axis_step(sub, axis, kind, Pt, Ut, True)
            out = sub if c == n else out.at[..., :c, :c, :c].set(sub)
    o_ref[...] = out


def _call(blocks, kind: str, levels: int | None, inverse: bool,
          tile_blocks: int, interpret: bool):
    b, n = blocks.shape[0], blocks.shape[-1]
    levels = wv.default_levels(n, levels)
    tb = min(tile_blocks, b)
    if b % tb:
        tb = 1
    mats = []
    for lvl in range(levels):
        m = (n >> lvl) // 2
        P, U = _matrices(kind, m)
        mats += [np.ascontiguousarray(P.T), np.ascontiguousarray(U.T)]
    in_specs = [pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0))]
    in_specs += [pl.BlockSpec(M.shape, lambda i: (0, 0)) for M in mats]
    kern = functools.partial(_kernel, kind=kind, levels=levels, inverse=inverse)
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, jnp.float32),
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.float32), *[jnp.asarray(M) for M in mats])


def wavelet3d_forward(blocks, kind: str = "w3ai", levels: int | None = None,
                      tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    """Forward multi-level 3D DWT of (B, n, n, n) blocks via Pallas."""
    return _call(blocks, kind, levels, False, tile_blocks, interpret)


def wavelet3d_inverse(blocks, kind: str = "w3ai", levels: int | None = None,
                      tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    return _call(blocks, kind, levels, True, tile_blocks, interpret)
