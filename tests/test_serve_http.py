"""repro.serve HTTP service coverage (ISSUE 5 acceptance): socket
round-trips byte-identical to direct ``CZDataset.read_box``, single-flight
decode coalescing under eviction pressure, tiered-cache metrics, the
close-ownership contract, and the manifest serializer shared with
``cz-compress inspect --json``."""
import contextlib
import io
import json
import os
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import CompressionSpec, Pipeline
from repro.launch.compress import inspect_main
from repro.serve import (
    Client,
    FieldRegionServer,
    RegionCache,
    RegionHTTPServer,
    SingleFlight,
)
from repro.store import CZDataset

N = 32
BS = 8
# 4 KiB buffers -> 2 blocks per chunk at 8^3 float32: 32 chunks per member
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 12)

RNG = np.random.default_rng(42)
FIELDS = {"p": RNG.normal(size=(N, N, N)).astype(np.float32),
          "rho": RNG.normal(size=(N, N, N)).astype(np.float32)}


def _make_dataset(root):
    with CZDataset(root, "a", spec=SPEC) as ds:
        for k in range(2):
            ds.append({q: f + np.float32(k) for q, f in FIELDS.items()},
                      time=float(k))
    return root


@pytest.fixture(scope="module")
def ds_root(tmp_path_factory):
    return _make_dataset(str(tmp_path_factory.mktemp("serve") / "ds"))


@pytest.fixture(scope="module")
def server(ds_root):
    with RegionHTTPServer(ds_root, port=0).start() as srv:
        yield srv


# ---------------------------------------------------------------------------
# Acceptance: HTTP payloads byte-identical to direct read_box
# ---------------------------------------------------------------------------

def test_http_region_roundtrip_bit_identical(ds_root, server):
    lo, hi = (3, 9, 14), (19, 25, 30)  # interior, block-unaligned
    with CZDataset(ds_root) as ds:
        ref = ds.read_box("rho", 1, lo, hi)
    with Client(server.url) as c:
        via_npy = c.region("rho", 1, lo, hi)
        via_raw = c.region_raw("rho", 1, lo, hi)
    assert via_npy.dtype == ref.dtype and via_npy.shape == ref.shape
    assert via_npy.tobytes() == ref.tobytes()
    assert via_raw.tobytes() == ref.tobytes()


def test_manifest_endpoint_shares_inspect_json_serializer(ds_root, server):
    with Client(server.url) as c:
        manifest = c.manifest()
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert inspect_main(["--json", "--no-verify", ds_root]) == 0
    via_cli = json.loads(buf.getvalue())
    # inspect --json adds root + per-member chunk tables on top of the same
    # CZDataset.describe() document the HTTP endpoint serves
    assert {k: via_cli[k] for k in manifest} == manifest
    assert manifest["quantities"]["p"]["timesteps"][0]["file"] == "p/t000000.cz"
    assert set(via_cli["members"]) == {
        f"{q}/t{t:06d}.cz" for q in FIELDS for t in range(2)}


def test_inspect_json_single_container(ds_root, capsys):
    member = os.path.join(ds_root, "p", "t000000.cz")
    assert inspect_main(["--json", member]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["container"] == "CZ2"
    assert out["scheme"] == "raw"
    assert out["crc_ok"] is True
    assert all(row["crc_ok"] for row in out["chunks"])
    assert sum(row["blocks"] for row in out["chunks"]) == out["nblocks"]


def test_healthz_and_http_errors(server):
    with Client(server.url) as c:
        assert c.healthz()
    for path, code in [
        ("/nope", 404),
        ("/v1/region/vorticity/0?lo=0,0,0&hi=4,4,4", 404),      # quantity
        ("/v1/region/p/9?lo=0,0,0&hi=4,4,4", 404),              # timestep
        ("/v1/region/p/0?lo=0,0,0&hi=99,4,4", 400),             # out of range
        ("/v1/region/p/0?lo=0,0&hi=4,4,4", 400),                # 2 components
        ("/v1/region/p/0?hi=4,4,4", 400),                       # missing lo
        ("/v1/region/p/xx?lo=0,0,0&hi=4,4,4", 400),             # bad t
        ("/v1/region/p/0?lo=0,0,0&hi=4,4,4&format=xml", 400),   # bad format
    ]:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(server.url + path)
        assert ei.value.code == code, path
        assert "error" in json.load(ei.value)
    req = urllib.request.Request(server.url + "/v1/manifest", data=b"x")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)                             # POST
    assert ei.value.code == 405


# ---------------------------------------------------------------------------
# Acceptance: /metrics hit-rate reflects the decoded-region LRU
# ---------------------------------------------------------------------------

def test_metrics_reflect_region_cache(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    with RegionHTTPServer(root, port=0).start() as srv:
        with Client(srv.url) as c:
            for _ in range(3):
                box = c.region("p", 0, (0, 0, 0), (BS, BS, BS))
            np.testing.assert_array_equal(box, FIELDS["p"][:BS, :BS, :BS])
            assert c.metric("cz_serve_queries_total") == 3
            assert c.metric("cz_serve_region_cache_misses_total") == 1
            assert c.metric("cz_serve_region_cache_hits_total") == 2
            # one 8^3 box sits inside one chunk: exactly one decode ever
            assert c.metric("cz_serve_chunks_decoded_total") == 1
            assert c.metric("cz_serve_chunk_cache_misses_total") == 1
            assert c.metric("cz_serve_bytes_served_total") == 3 * BS**3 * 4
            assert c.metric("cz_serve_bytes_decoded_total") > 0
            # histogram: count equals queries, +Inf bucket is cumulative
            text = c.metrics()
            assert 'cz_serve_request_seconds_bucket{le="+Inf"} 3' in text
            assert "cz_serve_request_seconds_count 3" in text
            assert 'cz_serve_http_responses_total{code="200"}' in text


# ---------------------------------------------------------------------------
# Acceptance: concurrent duplicate requests decode each chunk exactly once
# ---------------------------------------------------------------------------

def _slow_decode(monkeypatch, seconds=0.05):
    orig = Pipeline.decompress_chunk

    def slow(self, *a, **k):
        time.sleep(seconds)
        return orig(self, *a, **k)

    monkeypatch.setattr(Pipeline, "decompress_chunk", slow)


def test_concurrent_duplicate_requests_single_decode(tmp_path, monkeypatch):
    root = _make_dataset(str(tmp_path / "ds"))
    _slow_decode(monkeypatch)
    n_clients = 6
    lo, hi = (0, 0, 0), (BS, 3 * BS, BS)  # spans multiple chunks
    barrier = threading.Barrier(n_clients)
    out = []

    # cache_chunks=1: the reader LRU alone cannot stop duplicate decodes —
    # only the single-flight scheduler can
    with RegionHTTPServer(root, port=0, cache_chunks=1,
                          max_inflight=n_clients).start() as srv:
        covering = srv.region.ds.reader("p", 0).box_chunks(lo, hi)
        assert len(covering) > 1

        def fetch():
            with Client(srv.url) as c:
                barrier.wait()
                out.append(c.region("p", 0, lo, hi).tobytes())

        threads = [threading.Thread(target=fetch) for _ in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.region.stats()

    ref = FIELDS["p"][:BS, :3 * BS, :BS].tobytes()
    assert out == [ref] * n_clients
    assert stats["chunks_decoded"] == len(covering), \
        "coalescing must decode each covering chunk exactly once"
    assert stats["queries"] == n_clients
    # everyone but the leader was answered by a shared flight or the LRU
    assert (stats["region_cache_hits"] + stats["region_flights_joined"]
            + stats["flights_joined"]) == n_clients - 1


def test_overlapping_boxes_coalesce_shared_chunk(tmp_path, monkeypatch):
    """Two *different* concurrent boxes sharing their first chunk split the
    decode: chunk-level flights, not just whole-region memoisation."""
    root = _make_dataset(str(tmp_path / "ds"))
    _slow_decode(monkeypatch)
    srv = FieldRegionServer(root, cache_chunks=1)
    r = srv.ds.reader("p", 0)
    # both boxes start at block (0,0,0) -> both fetch chunk 0 first
    box_a = ((0, 0, 0), (BS, BS, 2 * BS))
    box_b = ((0, 0, 0), (BS, 2 * BS, BS))
    chunks = set(r.box_chunks(*box_a)) | set(r.box_chunks(*box_b))
    barrier = threading.Barrier(2)

    def q(box):
        barrier.wait()
        srv.query("p", 0, *box)

    threads = [threading.Thread(target=q, args=(b,)) for b in (box_a, box_b)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = srv.stats()
    srv.close()
    assert stats["chunks_decoded"] == len(chunks)
    assert stats["flights_joined"] >= 1  # the shared chunk was coalesced


# ---------------------------------------------------------------------------
# SingleFlight semantics (deterministic, event-driven)
# ---------------------------------------------------------------------------

def test_single_flight_leader_and_follower():
    sf = SingleFlight()
    started, release = threading.Event(), threading.Event()
    calls, results = [], []

    def leader_fn():
        started.set()
        assert release.wait(5)
        calls.append("leader")
        return 42

    t1 = threading.Thread(target=lambda: results.append(sf.do("k", leader_fn)))
    t1.start()
    assert started.wait(5)

    def follower_fn():  # pragma: no cover - must never run
        calls.append("follower")
        return -1

    t2 = threading.Thread(
        target=lambda: results.append(sf.do("k", follower_fn)))
    t2.start()
    deadline = time.time() + 5
    while sf.joined < 1:
        assert time.time() < deadline, "follower never joined the flight"
        time.sleep(0.001)
    release.set()
    t1.join(5)
    t2.join(5)
    assert results == [42, 42]
    assert calls == ["leader"]
    assert (sf.led, sf.joined) == (1, 1)
    # the flight landed: a later call runs again (memory is the cache's job)
    assert sf.do("k", lambda: 7) == 7
    assert sf.led == 2


def test_single_flight_propagates_exceptions():
    sf = SingleFlight()

    def boom():
        raise RuntimeError("decode failed")

    with pytest.raises(RuntimeError, match="decode failed"):
        sf.do("k", boom)
    assert sf.do("k", lambda: 1) == 1  # a failed flight is not sticky


# ---------------------------------------------------------------------------
# RegionCache unit behaviour
# ---------------------------------------------------------------------------

def test_region_cache_byte_budget_lru():
    a = np.ones(256, np.float32)  # 1 KiB each
    cache = RegionCache(max_bytes=2 * a.nbytes)
    assert cache.get("a") is None
    assert cache.put("a", a) and cache.put("b", a + 1)
    assert cache.get("a")[0] == 1  # refresh "a": "b" is now LRU
    assert cache.put("c", a + 2)
    assert cache.get("b") is None  # evicted by the byte budget
    assert cache.get("a") is not None and cache.get("c") is not None
    s = cache.stats()
    assert (s["entries"], s["evictions"]) == (2, 1)
    assert s["bytes"] == 2 * a.nbytes
    assert not cache.get("a").flags.writeable  # shared entries are frozen
    # an array bigger than the whole budget is never admitted
    assert not cache.put("huge", np.ones(4096, np.float32))
    assert cache.get("huge") is None
    assert RegionCache(0).put("x", a) is False  # 0 disables caching


def test_server_query_copy_semantics(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    with FieldRegionServer(root) as srv:
        box = srv.query("p", 0, (0, 0, 0), (BS, BS, BS))
        assert box.flags.writeable  # default: private copy
        shared = srv.query("p", 0, (0, 0, 0), (BS, BS, BS), copy=False)
        assert not shared.flags.writeable  # zero-copy cache view


# ---------------------------------------------------------------------------
# Satellite: close() ownership
# ---------------------------------------------------------------------------

def test_close_only_closes_owned_dataset(tmp_path):
    root = _make_dataset(str(tmp_path / "ds"))
    # borrowed: the caller's dataset must survive the server's close()
    with CZDataset(root, "a") as ds:
        srv = FieldRegionServer(ds)
        srv.query("p", 0, (0, 0, 0), (BS, BS, BS))
        srv.close()
        assert srv.closed
        # still readable AND appendable: the writer pool was not shut down
        ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))
        ds.append({q: f for q, f in FIELDS.items()})
    # owned: constructed from a path, so close() closes the dataset
    srv = FieldRegionServer(root)
    srv.close()
    srv.close()  # idempotent
    with pytest.raises(IOError, match="closed"):
        srv.query("p", 0, (0, 0, 0), (BS, BS, BS))
    with pytest.raises(IOError, match="closed"):
        srv.manifest()
