"""CZ container: single file per quantity, chunked, random-access decompress.

Mirrors CubismZ's output format design: one shared file per quantity with
independently-decompressible chunks (the per-thread aggregation buffers).

Two on-disk layouts:

* **CZ2** (current, written) — ``b"CZ2\\0"`` magic, a u64 pointer to a JSON
  *footer*, then the chunk data, then the footer.  Because the metadata
  (chunk sizes, CRCs, scheme name + params) comes last, the writer streams
  chunks straight from :meth:`Pipeline.iter_chunks` and patches the pointer
  at the end — the compressed chunk list is never materialized (only one
  compressed chunk is held at a time, beyond the stage-1 transform output
  for the batch), the paper's per-thread-buffer writer.
* **CZ1** (legacy, read-only) — ``b"CZ1\\0"`` magic with the JSON header up
  front.  Seed-era files read back bit-exact: a missing ``format`` field in
  the header marks the v1 chunk byte layout and decode dispatches through
  ``Scheme.decode_spec``.

The reader keeps an LRU cache of recently decompressed chunks so
neighbouring block fetches hit the cache instead of re-inflating
(paper §2.3 "Data decompression").  Decode is registry-driven: any scheme
recorded in the header — including third-party ones registered via
``repro.core.schemes.register_scheme`` — round-trips.

All container I/O flows through the :class:`repro.store.backends.Store`
byte-store protocol: a plain ``path`` argument resolves to a
:class:`FileStore` on the file's directory, and every reader/writer also
takes ``store=`` with the path re-interpreted as a store *key* — the hook
CZDataset uses to put members in memory or object-store backends.  Reads
are byte-range ``store.get`` calls (footer first, then exactly the chunks
touched): no open file handles, no seeks, S3-shaped access.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import io
import json
import os
import struct
import threading
import time
import zlib
from typing import Iterable, Iterator

import numpy as np

from repro import obs
from repro.obs import trace
from repro.store import backends as stores

from . import blocks as blk
from .pipeline import CompressedField, CompressionSpec, Pipeline

# fetch (store byte-range get) vs decode (chunk inflation) split — the two
# halves of a cold read a remote-backend PR must improve independently.
_READS = obs.counter("cz_reader_chunk_reads_total",
                     "FieldReader chunk requests by cache result.",
                     labelnames=("result",))
_FETCHED = obs.counter("cz_reader_fetched_bytes_total",
                       "Compressed bytes fetched from stores by FieldReader.")
_FETCH_SECONDS = obs.histogram("cz_reader_fetch_seconds",
                               "Cold-chunk store fetch wall time.",
                               buckets=obs.FAST_BUCKETS)
_DECODE_SECONDS = obs.histogram("cz_reader_decode_seconds",
                                "Cold-chunk decode wall time.",
                                buckets=obs.FAST_BUCKETS)
_PREFETCHED = obs.counter("cz_reader_prefetch_chunks_total",
                          "Prefetcher chunk outcomes by result.",
                          labelnames=("result",))


def _source(path, store: stores.Store | None) -> tuple[stores.Store, str]:
    """``(store, key)`` for a path-or-key: with no explicit store, a plain
    path gets a :class:`FileStore` rooted at its directory, so every byte
    of container I/O goes through the Store protocol."""
    if store is not None:
        return store, str(path)
    head, tail = os.path.split(os.path.abspath(os.fspath(path)))
    return stores.FileStore(head), tail


def _decode_spec(header: dict, device: str | None) -> CompressionSpec:
    """Spec to decode a container with: the recorded one, optionally re-routed
    to another stage-1 device.  The ``device`` recorded in a header is
    provenance, never a decode requirement — any container decodes on any
    device (bit-exact for integer-exact/lossless schemes, within the scheme's
    declared error bound otherwise)."""
    spec = CompressionSpec.from_json(header["spec"])
    if device is not None and device != spec.device:
        spec = dataclasses.replace(spec, device=device)
    return spec

__all__ = ["write_field", "write_compressed", "write_stream", "commit_footer",
           "build_field_header", "read_field", "describe", "FieldReader",
           "MAGIC", "MAGIC_V1"]

MAGIC = b"CZ2\0"
MAGIC_V1 = b"CZ1\0"
_FOOTER_PTR = struct.Struct("<Q")


def commit_footer(f, base_header: dict, sizes: list[int], nblks: list[int],
                  crcs: list[int], footer_off: int,
                  fsync: bool = False, records: list | None = None) -> int:
    """Append the JSON footer at ``footer_off`` and patch the magic's footer
    pointer; returns the container's total byte count.

    The single source of truth for the CZ2 footer layout (header key order
    included — it decides byte identity), shared by the streaming writer
    below and the cluster engine's rank-parallel assembly
    (``repro.cluster.engine``).

    ``records`` is the per-chunk :meth:`Scheme.chunk_record` collection
    (one entry per chunk, ``None`` where the scheme recorded nothing); it
    becomes the footer's ``chunk_schemes`` table only when some chunk
    actually recorded something, so single-scheme containers stay
    byte-identical.  A ``chunk_schemes`` already present in
    ``base_header`` (a re-written :class:`CompressedField`) is re-inserted
    at the same position, keeping both write routes byte-identical.
    """
    header = dict(base_header)
    recs = header.pop("chunk_schemes", None)
    if records is not None and any(r is not None for r in records):
        recs = records
    header.update({
        "nblocks": int(sum(nblks)),
        "chunk_nblocks": nblks,
        "chunk_sizes": sizes,
        "chunk_crc32": crcs,
    })
    if recs is not None:
        header["chunk_schemes"] = recs
    hbytes = json.dumps(header).encode()
    f.seek(footer_off)
    f.write(hbytes)
    f.seek(len(MAGIC))
    f.write(_FOOTER_PTR.pack(footer_off))
    if fsync:
        f.flush()
        try:
            fd = f.fileno()
        except (OSError, io.UnsupportedOperation):
            fd = None  # store-buffered sink: durability is the put's problem
        if fd is not None:
            os.fsync(fd)
    return footer_off + len(hbytes)


def write_stream(path: str, chunk_iter: Iterable[tuple[bytes, int]],
                 base_header: dict, fsync: bool = False,
                 store: stores.Store | None = None,
                 records: list | None = None) -> int:
    """Stream ``(chunk, nblk)`` pairs to a CZ2 container; one chunk in
    memory.  ``store=`` writes through a byte-store backend (``path`` is
    the key): file backends stream to a real handle, object-store backends
    buffer and commit one whole-object put (they cannot seek to patch the
    footer pointer).  ``records`` is the per-chunk record list the chunk
    iterator fills as it drains (``Pipeline.iter_chunks(records=...)``) —
    read only after the loop, when it is complete."""
    sizes: list[int] = []
    nblks: list[int] = []
    crcs: list[int] = []
    sink = open(path, "wb") if store is None else store.open_write(path)
    with sink as f:
        f.write(MAGIC)
        f.write(_FOOTER_PTR.pack(0))  # patched once the footer offset is known
        for chunk, nblk in chunk_iter:
            f.write(chunk)
            sizes.append(len(chunk))
            nblks.append(nblk)
            crcs.append(zlib.crc32(chunk) & 0xFFFFFFFF)
        return commit_footer(f, base_header, sizes, nblks, crcs, f.tell(),
                             fsync=fsync, records=records)


def build_field_header(pipe: Pipeline, source,
                       extra_header: dict | None = None):
    """Assemble a container header for a 3D field / 4D block batch and
    return ``(header, blocks)``.

    Header key *insertion order* decides byte identity of the JSON footer,
    so this is the one implementation shared by :func:`write_compressed` and
    the cluster engine's rank-parallel writer (``repro.cluster.engine``).
    """
    spec = pipe.spec
    data = np.asarray(source)
    header = pipe.base_header()
    if data.ndim == 3:
        header["field_shape"] = list(data.shape)
        data = np.asarray(
            blk.blockify(np.asarray(data, spec.np_dtype), spec.block_size))
    elif data.ndim != 4:
        raise ValueError(f"expected 3D field or 4D block batch, got {data.shape}")
    header["raw_bytes"] = int(data.size * spec.np_dtype.itemsize)
    if extra_header:
        header.update(extra_header)
    return header, data


def write_compressed(path: str, source, spec: CompressionSpec | None = None,
                     extra_header: dict | None = None, workers: int = 1,
                     executor=None, fsync: bool = False,
                     store: stores.Store | None = None) -> int:
    """Write a CZ2 container; returns total bytes written.

    ``source`` is either a 3D field / 4D block batch compressed on the fly
    through :meth:`Pipeline.iter_chunks` (streaming — the whole chunk list is
    never materialized), or an already-built :class:`CompressedField`.
    ``workers > 1`` encodes chunks on a thread pool (``executor`` supplies an
    external pool, e.g. the store's shared one); the single ordered drain
    keeps the file byte-identical to a serial write.  ``fsync`` flushes the
    file to stable storage before returning (the store's commit protocol).
    ``store=`` writes through a byte-store backend (``path`` is the key).
    """
    if isinstance(source, CompressedField):
        header = dict(source.header)
        for k in ("chunk_nblocks", "chunk_sizes", "chunk_crc32", "nblocks"):
            header.pop(k, None)
        pairs = zip(source.chunks, source.header["chunk_nblocks"])
        return write_stream(path, pairs, header, fsync=fsync, store=store)

    if spec is None:
        raise TypeError("spec is required when writing a raw field/blocks")
    pipe = Pipeline(spec, workers=workers)
    header, data = build_field_header(pipe, source, extra_header)
    records: list = []
    chunk_iter = pipe.iter_chunks(data, workers=workers, executor=executor,
                                  records=records)
    return write_stream(path, chunk_iter, header, fsync=fsync, store=store,
                        records=records)


def write_field(path: str, field: np.ndarray, spec: CompressionSpec,
                workers: int = 1) -> int:
    return write_compressed(path, field, spec, workers=workers)


def _read_header(f) -> tuple[dict, int]:
    """Dispatch on magic; returns (header, data_start).  File-handle variant
    kept for callers that already hold one open (fixtures, tooling)."""
    magic = f.read(4)
    try:
        if magic == MAGIC_V1:
            (hlen,) = _FOOTER_PTR.unpack(f.read(8))
            header = json.loads(f.read(hlen))
            header.setdefault("format", 1)
            return header, 12 + hlen
        if magic == MAGIC:
            (footer_off,) = _FOOTER_PTR.unpack(f.read(8))
            f.seek(footer_off)
            header = json.loads(f.read())
            return header, 12
    except (struct.error, UnicodeDecodeError, json.JSONDecodeError) as e:
        raise IOError(f"corrupt container metadata: {e}") from None
    raise ValueError("not a CZ container")


def _fetch_header(store: stores.Store, key: str) -> tuple[dict, int, bytes]:
    """Read a container's metadata with byte-range gets — magic + pointer
    first, then exactly the header/footer bytes.  Returns
    (header, data_start, magic)."""
    head = store.get(key, (0, len(MAGIC) + _FOOTER_PTR.size))
    if len(head) < len(MAGIC) + _FOOTER_PTR.size:
        raise ValueError("not a CZ container")
    magic = head[:len(MAGIC)]
    (ptr,) = _FOOTER_PTR.unpack(head[len(MAGIC):])
    try:
        if magic == MAGIC_V1:
            header = json.loads(store.get(key, (12, 12 + ptr)))
            header.setdefault("format", 1)
            return header, 12 + ptr, magic
        if magic == MAGIC:
            header = json.loads(store.get(key, (ptr, None)))
            return header, 12, magic
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise IOError(f"corrupt container metadata: {e}") from None
    raise ValueError("not a CZ container")


def _iter_chunk_bytes(store: stores.Store, key: str, header: dict,
                      data_start: int) -> Iterator[tuple[bytes, int, int]]:
    """CRC-checked ``(chunk_bytes, nblk, index)`` stream for a full scan —
    one ranged get over the whole data region (a sequential read is one
    request on an object store, not one per chunk)."""
    sizes = header["chunk_sizes"]
    data = store.get(key, (data_start, data_start + int(sum(sizes))))
    off = 0
    for i, (sz, nblk, crc) in enumerate(zip(sizes, header["chunk_nblocks"],
                                            header["chunk_crc32"])):
        chunk = data[off:off + sz]
        off += sz
        if (zlib.crc32(chunk) & 0xFFFFFFFF) != crc:
            raise IOError("chunk CRC mismatch — corrupt container")
        yield chunk, nblk, i


def iter_compressed(path: str, store: stores.Store | None = None
                    ) -> Iterator[tuple[bytes, int]]:
    """Stream ``(chunk, nblk)`` pairs out of a container, CRC-checked."""
    store, key = _source(path, store)
    header, data_start, _ = _fetch_header(store, key)
    for chunk, nblk, _i in _iter_chunk_bytes(store, key, header, data_start):
        yield chunk, nblk


def read_field(path: str, device: str | None = None,
               store: stores.Store | None = None) -> np.ndarray:
    """Decompress a whole container: the field, or raw blocks if the file was
    written from a block batch (no ``field_shape`` recorded).  ``device``
    overrides the recorded stage-1 routing for the decode (e.g. force a host
    decode of a device-written file); ``store=`` reads ``path`` as a key in
    a byte-store backend."""
    store, key = _source(path, store)
    header, data_start, _ = _fetch_header(store, key)
    pipe = Pipeline(_decode_spec(header, device))
    fmt = int(header.get("format", 1))
    outs = [pipe.decompress_chunk(chunk, nblk, fmt)
            for chunk, nblk, _i in _iter_chunk_bytes(store, key, header,
                                                     data_start)]
    blocks = np.concatenate(outs)
    shape = header.get("field_shape")
    if shape is None:
        return blocks
    return np.asarray(blk.unblockify(blocks, tuple(shape)))


def describe(path: str, verify: bool = False,
             store: stores.Store | None = None) -> dict:
    """Machine-readable container summary: header fields plus the per-chunk
    table, as one JSON-able dict.

    The single serializer behind ``cz-compress inspect --json`` — external
    tooling gets the same shape the CLI prints, so the two can't drift.
    ``verify=True`` re-reads every chunk and adds a ``crc_ok`` verdict per
    chunk (and an aggregate one).
    """
    src, key = _source(path, store)
    header, data_start, magic = _fetch_header(src, key)
    sizes = header["chunk_sizes"]
    crcs = header.get("chunk_crc32", [None] * len(sizes))
    recs = header.get("chunk_schemes")
    chunks = []
    ok = True
    data = src.get(key, (data_start, data_start + int(sum(sizes)))) \
        if verify else b""
    off = 0
    for i, (sz, nblk, crc) in enumerate(
            zip(sizes, header["chunk_nblocks"], crcs)):
        row = {"index": i, "blocks": int(nblk), "bytes": int(sz),
               "crc32": crc}
        if recs is not None:
            rec = recs[i] if i < len(recs) and recs[i] else {}
            row["scheme"] = rec.get("scheme", header.get("scheme"))
            if "eps" in rec:
                row["eps"] = rec["eps"]
        if verify and crc is not None:
            good = (zlib.crc32(data[off:off + sz]) & 0xFFFFFFFF) == crc
            row["crc_ok"] = good
            ok &= good
        off += sz
        chunks.append(row)
    total = int(sum(sizes))
    spec = header["spec"]
    out = {
        "path": path,
        "container": "CZ1" if magic == MAGIC_V1 else "CZ2",
        "format": int(header.get("format", 1)),
        "scheme": header.get("scheme", spec["scheme"]),
        "scheme_params": header.get("scheme_params", {}),
        "dtype": header.get("dtype", spec.get("dtype", "float32")),
        "field_shape": header.get("field_shape"),
        "block_size": spec["block_size"],
        "nblocks": header.get("nblocks"),
        "raw_bytes": header.get("raw_bytes"),
        "compressed_bytes": total,
        "spec": spec,
        "chunks": chunks,
    }
    if recs is not None:
        # scheme -> chunk-count histogram for mixed-scheme (auto) members
        hist: dict[str, int] = {}
        for row in chunks:
            name = row.get("scheme") or header.get("scheme") or "?"
            hist[name] = hist.get(name, 0) + 1
        out["schemes"] = dict(sorted(hist.items()))
    if verify:
        out["crc_ok"] = ok
    return out


_PREFETCH_POOL = None
_PREFETCH_POOL_GUARD = threading.Lock()


def _prefetch_pool():
    """Shared daemon pool for prefetch batches.  Separate from the store
    layer's I/O pool (``shared_io_pool``): a batch task here fans out into
    ``store.get_many``, which may submit to *that* pool — one pool for both
    would deadlock once saturated with waiting parents."""
    global _PREFETCH_POOL
    with _PREFETCH_POOL_GUARD:
        if _PREFETCH_POOL is None:
            _PREFETCH_POOL = concurrent.futures.ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="cz-prefetch")
        return _PREFETCH_POOL


class ChunkPrefetcher:
    """Overlaps upcoming chunks' store fetches with the current chunk's
    decode — the async half of the remote read path.

    ``read_box`` walks its covering chunks in a known order, so while chunk
    *i* inflates, the byte-range gets for chunks *i+1 .. i+depth* can
    already be on the wire (one ``store.get_many`` batch per scheduling
    step, pipelined by remote backends).  ``fetch_chunk`` then consumes the
    prefetched bytes via :meth:`take` instead of issuing its own get.

    Discipline, so prefetch can never change results or duplicate work:

    * a chunk already in the reader's decode cache, already in flight here,
      or claimed by the caller's ``skip`` predicate (the serve tier passes
      ``SingleFlight.in_flight``) is not scheduled;
    * the buffer is bounded (``max_buffered``, default ``2×depth``): the
      oldest unconsumed entry is evicted and simply refetched on demand if
      its turn ever comes — eviction is a perf event, not an error;
    * a failed or evicted prefetch makes :meth:`take` return ``None`` and
      the caller falls back to a direct ``store.get`` — the prefetcher is
      purely advisory.

    Outcomes are counted in
    ``cz_reader_prefetch_chunks_total{result=issued|used|evicted|failed}``.
    """

    def __init__(self, reader: "FieldReader", depth: int = 2,
                 max_buffered: int | None = None):
        self.reader = reader
        self.depth = max(1, int(depth))
        self.max_buffered = int(max_buffered or 2 * self.depth)
        self._pending: collections.OrderedDict[
            int, concurrent.futures.Future] = collections.OrderedDict()
        self._guard = threading.Lock()
        self._closed = False

    def schedule(self, cis, skip=None) -> int:
        """Issue ranged fetches for the chunk indices not already cached,
        in flight, or skipped.  Returns how many were newly issued."""
        todo = []
        with self._guard:
            if self._closed:
                return 0
            for ci in cis:
                ci = int(ci)
                if ci in self._pending or ci in self.reader._cache:
                    continue
                if skip is not None and skip(ci):
                    continue
                fut = concurrent.futures.Future()
                self._pending[ci] = fut
                todo.append((ci, fut))
            while len(self._pending) > self.max_buffered:
                _ci, old = self._pending.popitem(last=False)
                old.cancel()  # batch may still be running: set_* is guarded
                _PREFETCHED.inc(result="evicted")
        if todo:
            _PREFETCHED.inc(len(todo), result="issued")
            _prefetch_pool().submit(self._fetch_batch, todo)
        return len(todo)

    def _fetch_batch(self, todo):
        r = self.reader
        reqs = []
        for ci, _fut in todo:
            off = int(r._chunk_off[ci])
            reqs.append((r.key, (off, off + r.header["chunk_sizes"][ci])))
        try:
            results = r.store.get_many(reqs)
        except BaseException as e:  # delivered through the futures
            for _ci, fut in todo:
                if not fut.cancelled():
                    try:
                        fut.set_exception(e)
                    except concurrent.futures.InvalidStateError:
                        pass
            return
        for (_ci, fut), data in zip(todo, results):
            try:
                fut.set_result(data)
            except concurrent.futures.InvalidStateError:
                pass  # evicted while the batch was in flight

    def take(self, ci: int) -> bytes | None:
        """Prefetched compressed bytes for ``ci`` (waiting on an in-flight
        batch), or ``None`` when the chunk was never scheduled, was evicted,
        or its fetch failed — callers fall back to a direct get."""
        with self._guard:
            fut = self._pending.pop(int(ci), None)
        if fut is None:
            return None
        try:
            data = fut.result()
        except (concurrent.futures.CancelledError, Exception):
            _PREFETCHED.inc(result="failed")
            return None
        if len(data) != self.reader.header["chunk_sizes"][ci]:
            _PREFETCHED.inc(result="failed")  # short read: refetch directly
            return None
        _PREFETCHED.inc(result="used")
        return data

    def close(self) -> None:
        with self._guard:
            self._closed = True
            for fut in self._pending.values():
                fut.cancel()
            self._pending.clear()


class FieldReader:
    """Random block/region access with an LRU chunk cache (paper's
    decompressor).  Thread-safe: chunk inflation and the cache are guarded by
    a lock, so concurrent readers (e.g. the store's region-query server) can
    share one reader and its decode cache.

    Chunks are fetched as **byte ranges** from the backing store — footer at
    open, then ``store.get(key, (off, off + sz))`` per cold chunk.  The
    reader holds no open file handle, so an idle reader costs nothing and a
    serve tier can keep thousands pooled; ``close()`` is terminal (it only
    marks the reader dead and drops its cache — use after close raises
    ``ValueError``).
    """

    def __init__(self, path: str, cache_chunks: int = 8,
                 device: str | None = None,
                 store: stores.Store | None = None,
                 prefetch: int = 0):
        self.path = str(path)
        self.store, self.key = _source(path, store)
        self.header, data_start, _ = _fetch_header(self.store, self.key)
        self.spec = _decode_spec(self.header, device)
        self.format = int(self.header.get("format", 1))
        self._pipe = Pipeline(self.spec)
        sizes = self.header["chunk_sizes"]
        self._chunk_off = np.concatenate([[0], np.cumsum(sizes)])[:-1] + data_start
        self._chunk_nblk = self.header["chunk_nblocks"]
        self._blk0 = np.concatenate([[0], np.cumsum(self._chunk_nblk)])
        if "field_shape" not in self.header:
            raise ValueError(
                "container was written from a block batch (no field_shape); "
                "use read_field for raw blocks")
        self.shape = tuple(self.header["field_shape"])
        self.nb = blk.num_blocks(self.shape, self.spec.block_size)
        self._cache: collections.OrderedDict[int, np.ndarray] = collections.OrderedDict()
        self._cache_chunks = cache_chunks
        self._lock = threading.Lock()
        self._closed = False
        self.cache_hits = 0
        self.cache_misses = 0
        self.prefetch = max(0, int(prefetch))
        self._prefetcher = (ChunkPrefetcher(self, depth=self.prefetch)
                            if self.prefetch else None)

    @property
    def nchunks(self) -> int:
        return len(self._chunk_nblk)

    @property
    def chunks_decoded(self) -> int:
        """Chunks actually inflated so far (== cache misses) — lets callers
        assert a region read decoded fewer chunks than a full-field read."""
        return self.cache_misses

    @property
    def dtype(self) -> np.dtype:
        return self.spec.np_dtype

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self):
        """Terminal and idempotent: marks the reader dead and drops its
        chunk cache.  There is no file handle to release — any later fetch
        raises ``ValueError`` (a holder that outlives its owner's close must
        fail loudly, not resurrect a retired cache)."""
        if self._prefetcher is not None:
            self._prefetcher.close()
        with self._lock:
            self._closed = True
            self._cache.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _chunk(self, ci: int) -> np.ndarray:
        return self.fetch_chunk(ci)[0]

    def fetch_chunk(self, ci: int) -> tuple[np.ndarray, bool]:
        """One chunk plus whether this call actually inflated it (``False``
        = LRU hit).  The flag is decided under the reader lock, so accounting
        built on it (e.g. the serve scheduler's bytes-decoded counter) stays
        exact under concurrency."""
        with self._lock:
            if self._closed:
                raise ValueError(
                    f"FieldReader for {self.path!r} is closed "
                    "(close() is terminal)")
            if ci in self._cache:
                self._cache.move_to_end(ci)
                self.cache_hits += 1
                _READS.inc(result="hit")
                return self._cache[ci], False
            self.cache_misses += 1
            _READS.inc(result="miss")
            off = int(self._chunk_off[ci])
            t0 = time.perf_counter_ns()
            buf = (self._prefetcher.take(ci)
                   if self._prefetcher is not None else None)
            if buf is None:
                buf = self.store.get(
                    self.key, (off, off + self.header["chunk_sizes"][ci]))
            t1 = time.perf_counter_ns()
            out = self._pipe.decompress_chunk(buf, self._chunk_nblk[ci], self.format)
            t2 = time.perf_counter_ns()
            _FETCHED.inc(len(buf))
            _FETCH_SECONDS.observe((t1 - t0) / 1e9)
            _DECODE_SECONDS.observe((t2 - t1) / 1e9)
            trace.record("fetch", t0, t1, chunk=ci, bytes=len(buf))
            self._cache[ci] = out
            while len(self._cache) > self._cache_chunks:
                self._cache.popitem(last=False)
            return out, True

    def block_chunk(self, bx: int, by: int, bz: int) -> tuple[int, int]:
        """``(chunk index, block offset within chunk)`` for one block
        coordinate — the geometry hook serving tiers coalesce on."""
        _, by_n, bz_n = self.nb
        flat = (bx * by_n + by) * bz_n + bz
        ci = int(np.searchsorted(self._blk0, flat, side="right")) - 1
        return ci, flat - self._blk0[ci]

    def box_blocks(self, lo, hi):
        """Block coordinates covering the box ``[lo, hi)`` (validated)."""
        lo = tuple(int(v) for v in lo)
        hi = tuple(int(v) for v in hi)
        for a, b, s in zip(lo, hi, self.shape):
            if not 0 <= a < b <= s:
                raise ValueError(f"box [{lo}, {hi}) outside field {self.shape}")
        bs = self.spec.block_size
        return [(bx, by, bz)
                for bx in range(lo[0] // bs, (hi[0] - 1) // bs + 1)
                for by in range(lo[1] // bs, (hi[1] - 1) // bs + 1)
                for bz in range(lo[2] // bs, (hi[2] - 1) // bs + 1)]

    def box_chunks(self, lo, hi) -> list[int]:
        """Distinct chunk indices covering the box ``[lo, hi)``, ascending."""
        return sorted({self.block_chunk(*b)[0] for b in self.box_blocks(lo, hi)})

    def read_block(self, bx: int, by: int, bz: int) -> np.ndarray:
        """Decompress and return one (bs, bs, bs) block."""
        ci, off = self.block_chunk(bx, by, bz)
        return self._chunk(ci)[off]

    def read_box(self, lo: tuple[int, int, int],
                 hi: tuple[int, int, int], chunk_getter=None,
                 prefetch_skip=None) -> np.ndarray:
        """Decode the sub-box ``[lo, hi)`` touching only the covering chunks.

        The box is assembled block by block through the LRU chunk cache — the
        full field is never inflated, and ``chunks_decoded`` counts exactly
        the chunks that were.  ``chunk_getter`` substitutes another
        ``ci -> chunk array`` source (e.g. the serve tier's single-flight
        scheduler) for the reader's own ``_chunk``.

        With ``prefetch`` enabled on the reader, the walk schedules the next
        ``prefetch`` chunks' byte-range fetches just before decoding each
        chunk, so wire time overlaps decode time.  ``prefetch_skip`` vetoes
        individual chunk indices (the serve tier passes its single-flight
        in-flight check so prefetch never duplicates a fetch another request
        is already performing).
        """
        lo = tuple(int(v) for v in lo)
        hi = tuple(int(v) for v in hi)
        get = self._chunk if chunk_getter is None else chunk_getter
        bs = self.spec.block_size
        blocks = self.box_blocks(lo, hi)  # validates the box
        out = np.empty(tuple(b - a for a, b in zip(lo, hi)), self.dtype)
        pf = self._prefetcher
        sched = None
        if pf is not None:
            order: list[int] = []
            for b in blocks:  # distinct covering chunks, visit order
                c = self.block_chunk(*b)[0]
                if not order or order[-1] != c:
                    order.append(c)
            pos = {c: i for i, c in enumerate(order)}
            fired: set[int] = set()

            def sched(ci):
                i = pos[ci]
                if i in fired:
                    return
                fired.add(i)
                upcoming = order[i + 1:i + 1 + pf.depth]
                if upcoming:
                    pf.schedule(upcoming, skip=prefetch_skip)

        for bx, by, bz in blocks:
            ci, off = self.block_chunk(bx, by, bz)
            if sched is not None:
                sched(ci)  # next chunks' fetches ride while this one decodes
            block = get(ci)[off]
            # intersection of this block's extent with the box
            b0 = (bx * bs, by * bs, bz * bs)
            s0 = tuple(max(a, c) for a, c in zip(lo, b0))
            s1 = tuple(min(b, c + bs) for b, c in zip(hi, b0))
            out[tuple(slice(a - o, b - o) for a, b, o in zip(s0, s1, lo))] = \
                block[tuple(slice(a - c, b - c) for a, b, c in zip(s0, s1, b0))]
        return out

    def read_all(self) -> np.ndarray:
        blocks = np.concatenate([self._chunk(i) for i in range(len(self._chunk_nblk))])
        return np.asarray(blk.unblockify(blocks, self.shape))
