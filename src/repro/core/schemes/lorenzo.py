"""Lorenzo-predictor scheme: dual-quantized 3D Lorenzo residuals, i32 stream.

The predictor-based arm of the registry (Tao et al. 2017's Lorenzo family):
stage 1 quantizes onto the 2*eps grid and takes the exact integer 3D Lorenzo
difference — the same transform ``szx`` uses — but the byte layout keeps the
full int32 residual stream (shuffled, then stage-2 coded) instead of szx's
int8+escape coding.  That trades raw stream size for a branch-free layout
whose serialize/deserialize is pure ``tobytes``/``frombuffer``, and leaves
entropy coding entirely to the shuffle + stage-2 combination.

``spec.device="jax"`` routes encode/decode through the fused Pallas kernels
(``repro.kernels.ops.lorenzo_*`` — quantization fused with the axis diffs /
prefix sums).  The kernels are integer-exact vs the host path, so device-
and host-written containers are mutually bit-exact to decode.  The error
bound |x - xhat| <= eps holds exactly, like SZ's.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .. import szx as _szx
from . import Scheme, register_scheme, route, shuffle_bytes, unshuffle_bytes


@register_scheme
class LorenzoScheme(Scheme):
    name = "lorenzo"
    device_capable = True

    def validate(self, spec) -> None:
        if spec.eps <= 0:
            raise ValueError(
                "lorenzo requires eps > 0 (error-bounded lossy codec)")

    def params(self, spec) -> dict:
        return {"eps": spec.eps, **super().params(spec)}

    def error_bound(self, spec) -> float:
        return spec.eps

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        _szx.check_eps(float(jnp.max(jnp.abs(x))), spec.eps)
        res = route(spec, _szx.encode, "lorenzo_encode")(x, eps=spec.eps)
        return {"res": np.asarray(res)}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        r = s1["res"][lo:hi].astype(np.int32, copy=False)
        return shuffle_bytes(r.tobytes(), spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        r = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, 4), np.int32)
        r = r.reshape(nblk, n, n, n)
        dec = route(spec, _szx.decode, "lorenzo_decode")
        return np.asarray(dec(jnp.asarray(r), eps=spec.eps))
