"""Compressed-field region serving: ``(quantity, t, lo, hi)`` queries
against a CZDataset answered through a tiered decode cache.

Deliberately free of jax/model imports — serving compressed fields must not
pull in the LLM decode stack (:mod:`repro.serve.step`).

Three tiers answer a query, cheapest first:

1. **decoded-region LRU** (:class:`repro.serve.cache.RegionCache`) — the
   exact box was served before and is still resident: zero decode, zero
   assembly.
2. **chunk LRU** (the store's pooled :class:`FieldReader` caches) — the
   covering chunks are resident: block gather + box assembly only.
3. **decode** — cold chunks are inflated, with concurrent duplicate work
   coalesced by :class:`repro.serve.scheduler.ChunkScheduler` so each chunk
   is decoded once per miss however many requests are waiting on it.

:class:`FieldRegionServer` is transport-agnostic (in-process callers use it
directly; :mod:`repro.serve.http` puts a socket in front) and safe for
concurrent request threads.
"""
from __future__ import annotations

import contextlib
import threading
import time

from .cache import RegionCache
from .scheduler import ChunkScheduler, SingleFlight

__all__ = ["FieldRegionServer", "LatencyHistogram", "LATENCY_BUCKETS"]

#: Prometheus-style cumulative bucket bounds, seconds (+Inf is implicit).
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5)


class LatencyHistogram:
    """Fixed-bucket latency histogram in the Prometheus text-format shape
    (cumulative ``le`` buckets plus sum and count)."""

    def __init__(self, buckets=LATENCY_BUCKETS):
        self.bounds = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, seconds: float) -> None:
        i = 0
        while i < len(self.bounds) and seconds > self.bounds[i]:
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += seconds

    def snapshot(self) -> dict:
        """``{"buckets": [(le, cumulative_count), ...], "sum": s, "count": n}``
        with the +Inf bucket last."""
        with self._lock:
            counts = list(self._counts)
            total = self._sum
        cum, rows = 0, []
        for bound, c in zip(self.bounds + (float("inf"),), counts):
            cum += c
            rows.append((bound, cum))
        return {"buckets": rows, "sum": total, "count": cum}


class FieldRegionServer:
    """Serves ``(quantity, t, lo, hi)`` region queries from a CZDataset.

    The paper's §2.3 decompressor turned into a query server: the tiered
    cache + single-flight scheduler described in the module docstring, with
    request counters and a latency histogram for ``/metrics``.

    Parameters
    ----------
    dataset:
        A :class:`repro.store.CZDataset` **or** a dataset root — a local
        path or a store URL (``file://``, ``mem://``, any registered
        backend); the serve tier is backend-agnostic.  A root is opened —
        and therefore closed — by this server; a dataset object is
        borrowed, and :meth:`close` leaves it untouched (the caller opened
        it, the caller closes it).
    cache_bytes:
        Byte budget for the decoded-region LRU (``0`` disables it; chunk
        caching below is unaffected).
    max_inflight:
        Cap on *concurrent region decodes* (admission control; ``None`` =
        unbounded).  Deliberately scoped to the decode path only: cache
        hits and flight joins never wait on it, so a burst of cold requests
        cannot serialize the zero-cost hot path behind decodes.
    """

    def __init__(self, dataset, cache_readers: int = 16,
                 cache_chunks: int = 32, cache_bytes: int = 64 << 20,
                 max_inflight: int | None = None):
        from repro.store import CZDataset

        self._owns_dataset = isinstance(dataset, (str, bytes)) or \
            hasattr(dataset, "__fspath__")
        if self._owns_dataset:
            dataset = CZDataset(str(dataset), mode="r",
                                cache_readers=cache_readers,
                                cache_chunks=cache_chunks)
        self.ds = dataset
        self.closed = False
        self.cache = RegionCache(cache_bytes)
        self.admission = (threading.BoundedSemaphore(int(max_inflight))
                          if max_inflight else contextlib.nullcontext())
        self.scheduler = ChunkScheduler(dataset)
        self._region_sf = SingleFlight()
        self._lock = threading.Lock()
        self.queries = 0
        self.bytes_served = 0
        self.latency = LatencyHistogram()

    # -- queries -----------------------------------------------------------

    def query(self, quantity: str, t: int, lo, hi, copy: bool = True):
        """Decode (or fetch from cache) the box ``[lo, hi)`` of one quantity
        at one timestep.

        ``copy=False`` returns the cache's shared **read-only** array —
        zero-copy for callers that only serialize it (the HTTP tier); the
        default hands back a private writable copy.
        """
        if self.closed:
            raise IOError("FieldRegionServer is closed")
        key = (str(quantity), int(t),
               tuple(int(v) for v in lo), tuple(int(v) for v in hi))
        t0 = time.perf_counter()
        out = self.cache.get(key)
        if out is None:
            # coalesce identical in-flight regions, then chunk-level flights
            # inside read_box take care of partial overlaps
            out = self._region_sf.do(
                key, lambda: self._decode_region(key))
        dt = time.perf_counter() - t0
        self.latency.observe(dt)
        with self._lock:
            self.queries += 1
            self.bytes_served += out.nbytes
        return out.copy() if copy else out

    def _decode_region(self, key):
        quantity, t, lo, hi = key
        with self.admission:  # only actual decode work queues here
            out = self.scheduler.read_box(quantity, t, lo, hi)
        self.cache.put(key, out)  # freezes `out` read-only
        return out

    def manifest(self) -> dict:
        """The dataset summary served at ``/v1/manifest`` (one serializer
        shared with ``cz-compress inspect --json``)."""
        if self.closed:
            raise IOError("FieldRegionServer is closed")
        return self.ds.describe()

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Flat counter dict: store chunk-cache counters + region-cache,
        scheduler, and request-level counters."""
        s = self.ds.stats()
        lat = self.latency.snapshot()
        with self._lock:
            s.update({
                "queries": self.queries,
                "bytes_served": self.bytes_served,
                "mean_latency_ms": 1e3 * lat["sum"] / max(1, lat["count"]),
            })
        s.update({f"region_cache_{k}": v
                  for k, v in self.cache.stats().items()})
        s.update(self.scheduler.stats())
        s["region_flights_led"] = self._region_sf.led
        s["region_flights_joined"] = self._region_sf.joined
        return s

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Idempotent.  Closes the dataset only when this server opened it
        from a path — a borrowed :class:`CZDataset` stays open for its
        owner."""
        if self.closed:
            return
        self.closed = True
        if self._owns_dataset:
            self.ds.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
