"""Attention-free mixers: RWKV6 (Finch) time-mix and Mamba selective SSM.

Both are implemented in their *recurrent* form with ``lax.scan`` over time —
exact for decode (one step) and correct for training.  The scan keeps the
HLO small and the state in registers/VMEM; the chunked-parallel (GLA-style)
formulation is a recorded §Perf candidate for the train shapes.

RWKV6 time-mix (per head h, head dim d):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: d x d per head)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent decay  w_t = exp(-exp(w0 + tanh(x_t W_a) W_b)).

Mamba (diagonal selective SSM):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t h_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import rmsnorm

__all__ = ["rwkv6_timemix", "rwkv6_timemix_chunked", "rwkv6_channelmix",
           "rwkv6_decode", "mamba_mix", "mamba_decode"]


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def _token_shift(x, mu, x_prev=None):
    """lerp(x_t, x_{t-1}, mu); x (B,S,D). x_prev: (B,1,D) carry for decode."""
    if x_prev is None:
        prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        prev = x_prev
    return x + mu * (prev - x)


def _rwkv_proj(x, p, cfg, x_prev=None):
    H, hd = cfg.n_heads, cfg.hd
    r = jnp.einsum("bsd,de->bse", _token_shift(x, p["mu_r"], x_prev), p["wr"])
    k = jnp.einsum("bsd,de->bse", _token_shift(x, p["mu_k"], x_prev), p["wk"])
    v = jnp.einsum("bsd,de->bse", _token_shift(x, p["mu_v"], x_prev), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _token_shift(x, p["mu_g"], x_prev), p["wg"]))
    xw = _token_shift(x, p["mu_w"], x_prev)
    dd = jnp.einsum("bsk,kd->bsd", jnp.tanh(jnp.einsum("bsd,dk->bsk", xw, p["ww1"])), p["ww2"])
    logw = -jnp.exp(jnp.clip(p["w0"] + dd, -8.0, 4.0).astype(jnp.float32))   # log decay < 0
    w = jnp.exp(logw)                                                        # (B,S,D) in (0,1)
    B_, S, D = x.shape
    shp = (B_, S, H, hd)
    return (a.reshape(shp) for a in (r, k, v, w, g))


def _wkv_step(S, inputs):
    """S (B,H,dk,dv); r,k,v,w (B,H,d)."""
    r, k, v, w, u = inputs
    kv = k[..., :, None] * v[..., None, :]               # (B,H,dk,dv)
    out = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S_new = w[..., :, None] * S + kv
    return S_new, out


def rwkv6_timemix(x, p, cfg, state=None, x_prev=None):
    """x (B,S,D) -> (out, (new_state, new_x_prev)). State (B,H,hd,hd) f32."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    r, k, v, w, g = _rwkv_proj(x, p, cfg, x_prev)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    u = p["u"].astype(jnp.float32)                        # (H,hd) bonus

    def step(S_, rkvw):
        r_, k_, v_, w_ = rkvw
        S_new, out = _wkv_step(S_, (r_, k_, v_, w_, u))
        return S_new, out

    seq = (r.swapaxes(0, 1).astype(jnp.float32).transpose(0, 1, 2, 3),
           k.swapaxes(0, 1).astype(jnp.float32),
           v.swapaxes(0, 1).astype(jnp.float32),
           w.swapaxes(0, 1).astype(jnp.float32))
    # scan over time: elements (B,H,hd)
    state, outs = jax.lax.scan(step, state, tuple(s.reshape(S, B, H, hd) for s in seq))
    o = outs.swapaxes(0, 1).reshape(B, S, H, hd)          # (B,S,H,hd)
    o = rmsnorm(o, p["gn"].reshape(H, hd), cfg.norm_eps)  # per-head group norm
    o = (o.reshape(B, S, D) * g.reshape(B, S, D)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, (state, x[:, -1:, :])


def rwkv6_timemix_chunked(x, p, cfg, state=None, x_prev=None, chunk: int = 16):
    """Chunk-parallel WKV (GLA-style): O(T/c) state round-trips instead of
    O(T) — the §Perf fix for the memory-bound rwkv train/prefill cells.

    Per chunk (all per-channel decays; exponent differences are always <= 0,
    so no clamping is needed):

      l       = cumsum(log w)                 (inclusive), l_ex = l - log w
      A[t,s]  = sum_d r[t,d] k[s,d] exp(l_ex[t,d] - l[s,d])   for s < t
      A[t,t]  = (r_t * u) . k_t                               (bonus)
      out     = A @ v + (r * exp(l_ex)) @ S_in
      S_out   = exp(l_last) * S_in + (k * exp(l_last - l))^T @ v

    Exactly equivalent to the sequential recurrence (tested to fp tolerance).
    """
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    c = chunk
    assert S % c == 0, f"seq {S} % chunk {c} != 0"
    r, k, v, w, g = _rwkv_proj(x, p, cfg, x_prev)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    u = p["u"].astype(jnp.float32)

    nc = S // c
    def to_chunks(a):  # (B,S,H,hd) -> (nc, B, H, c, hd) f32
        return a.reshape(B, nc, c, H, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = jnp.log(jnp.maximum(to_chunks(w), 1e-38))

    causal = jnp.tril(jnp.ones((c, c), jnp.float32), -1)        # s < t strictly

    def one_chunk(S_, inp):
        r_, k_, v_, lw_ = inp                                    # (B,H,c,hd)
        l = jnp.cumsum(lw_, axis=2)
        l_ex = l - lw_
        # intra-chunk scores with per-channel decay; exponents are <= 0 for
        # every *used* (s < t) pair — clamp so the masked s >= t entries
        # cannot overflow to inf (inf * 0 mask = NaN)
        E2 = jnp.exp(jnp.minimum(
            l_ex[:, :, :, None, :] - l[:, :, None, :, :], 0.0))  # (B,H,t,s,d)
        A = jnp.einsum("bhtd,bhsd,bhtsd->bhts", r_, k_, E2)
        A = A * causal
        diag = jnp.einsum("bhtd,bhtd->bht", r_ * u[None, :, None, :], k_)
        A = A + diag[..., None] * jnp.eye(c)
        out = jnp.einsum("bhts,bhsv->bhtv", A, v_)
        # inter-chunk: state contribution
        out = out + jnp.einsum("bhtd,bhdv->bhtv", r_ * jnp.exp(l_ex), S_)
        # state update
        l_last = l[:, :, -1:, :]                                  # (B,H,1,hd)
        kdec = k_ * jnp.exp(l_last - l)
        S_new = jnp.exp(l_last[:, :, 0, :])[..., None] * S_ +             jnp.einsum("bhsd,bhsv->bhdv", kdec, v_)
        return S_new, out

    state, outs = jax.lax.scan(one_chunk, state, (rc, kc, vc, lw))
    o = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)       # (B,S,H,hd)
    o = rmsnorm(o, p["gn"].reshape(H, hd), cfg.norm_eps)
    o = (o.reshape(B, S, D) * g.reshape(B, S, D)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, (state, x[:, -1:, :])


def rwkv6_decode(x, p, cfg, state, x_prev):
    """Single-token decode: x (B,1,D)."""
    return rwkv6_timemix(x, p, cfg, state=state, x_prev=x_prev)


def rwkv6_channelmix(x, p, cfg, x_prev=None):
    xk = _token_shift(x, p["mu_ck"], x_prev)
    xr = _token_shift(x, p["mu_cr"], x_prev)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"]))
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"])
    k = jnp.square(jax.nn.relu(k))
    return r * jnp.einsum("bsf,fd->bsd", k, p["cv"]), x[:, -1:, :]


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def _mamba_proj(x, p, cfg, conv_state=None):
    """Returns (xz gate z, conv'd activation u, dt, Bc, Cc, new_conv_state)."""
    B_, S, D = x.shape
    Di = cfg.ssm_expand * D
    K = cfg.conv_kernel
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])       # (B,S,2Di)
    u, z = xz[..., :Di], xz[..., Di:]
    # causal depthwise conv along time
    if conv_state is None:
        pad = jnp.zeros((B_, K - 1, Di), u.dtype)
    else:
        pad = conv_state
    uc = jnp.concatenate([pad, u], axis=1)                # (B,S+K-1,Di)
    new_conv_state = uc[:, -(K - 1):, :] if K > 1 else jnp.zeros((B_, 0, Di), u.dtype)
    conv = sum(uc[:, i : i + S, :] * p["conv_w"][:, i] for i in range(K))
    u = jax.nn.silu(conv + p["conv_b"])
    bc = jnp.einsum("bse,en->bsn", u, p["x_bc"])          # (B,S,2*dstate)
    ds = cfg.d_state
    Bc, Cc = bc[..., :ds], bc[..., ds:]
    dt = jnp.einsum("bse,er->bsr", u, p["w_dt1"])
    dt = jnp.einsum("bsr,re->bse", dt, p["w_dt2"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # (B,S,Di)
    return u, z, dt, Bc, Cc, new_conv_state


def mamba_mix(x, p, cfg, state=None, conv_state=None):
    """x (B,S,D) -> (out, (ssm_state (B,Di,ds) f32, conv_state))."""
    B_, S, D = x.shape
    Di = cfg.ssm_expand * D
    ds = cfg.d_state
    u, z, dt, Bc, Cc, new_conv = _mamba_proj(x, p, cfg, conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (Di,ds) negative
    if state is None:
        state = jnp.zeros((B_, Di, ds), jnp.float32)

    def step(h, inp):
        u_, dt_, B_t, C_t = inp                            # (B,Di),(B,Di),(B,ds),(B,ds)
        a = jnp.exp(dt_[..., None] * A[None])              # (B,Di,ds)
        bx = dt_[..., None] * B_t[:, None, :] * u_[..., None].astype(jnp.float32)
        h = a * h + bx
        y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y

    inps = (u.swapaxes(0, 1), dt.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state, inps)
    y = ys.swapaxes(0, 1).astype(x.dtype)                  # (B,S,Di)
    y = y + u * p["Dskip"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (state, new_conv)


def mamba_decode(x, p, cfg, state, conv_state):
    return mamba_mix(x, p, cfg, state=state, conv_state=conv_state)
