"""Decision layer: trial-per-chunk by default, signature cache on request.

Default (``tune_cache`` unset or 0): **every chunk is trialled**.  The
decision is then a pure function of the chunk's bytes, which is what makes
the ``auto`` scheme safe under the cluster engine — any partitioning of
the chunk stream across ranks reproduces the serial writer's choices
byte-for-byte.

Opt-in (``spec.extra["tune_cache"] = K``): decisions are cached under a
cheap chunk-statistics signature (quantized log range / log std /
smoothness — the features that separate compression regimes), and a chunk
whose signature was already decided reuses that decision; every K-th
chunk of a signature is re-trialled anyway (the periodic re-trial budget),
so a drifting stream cannot ride a stale winner forever.  The cache trades
per-chunk optimality and cross-partitioning byte-determinism for trial
cost — steady streams pay trials on ~1/K of their chunks.  Serial encodes
remain deterministic (same chunk order, same hits); rank-parallel encodes
with the cache enabled are *not* guaranteed byte-identical to serial,
which is why it is off by default.

Cache hits count in ``cz_tune_cache_hits_total``; every actual (re-)trial
emits a ``tune.decision`` event recording the winner and why the trial ran.
"""
from __future__ import annotations

import math
import threading

import numpy as np

from repro import obs
from repro.obs import events as _events
from repro.core.pipeline import CompressionSpec

from .bound import Target
from .trial import Decision, run_trials

__all__ = ["DecisionPolicy", "chunk_signature", "policy_for"]

_CACHE_HITS = obs.counter(
    "cz_tune_cache_hits_total",
    "Auto-tuning decisions served from the chunk-signature cache.")

#: signature quantization step in log2 space — chunks whose range/std/
#: smoothness agree within ~2^0.5 share a cache line
_GRID = 0.5


def chunk_signature(blocks_np: np.ndarray, grid: float = _GRID) -> tuple:
    """Cheap content signature of a chunk: quantized ``log2`` of value
    range, standard deviation, and mean |first difference| (smoothness).
    One pass over the data, no encode — the features that separate the
    regimes where different schemes win."""
    x = np.asarray(blocks_np, np.float64)

    def q(v: float) -> int:
        return -(10 ** 6) if v <= 0 or not math.isfinite(v) \
            else round(math.log2(v) / grid)

    return (q(float(x.max() - x.min())),
            q(float(x.std())),
            q(float(np.mean(np.abs(np.diff(x, axis=-1))))
              if x.shape[-1] > 1 else 0.0))


class DecisionPolicy:
    """Per-spec decision maker: trials, plus the optional signature cache.

    ``retrial_every`` is the ``tune_cache`` knob: 0 disables caching
    (trial every chunk); K > 0 reuses a signature's cached decision and
    re-trials every K-th occurrence.
    """

    def __init__(self, retrial_every: int = 0):
        self.retrial_every = max(0, int(retrial_every))
        self._cache: dict[tuple, Decision] = {}
        self._uses: dict[tuple, int] = {}
        self._guard = threading.Lock()

    def decide(self, blocks_np: np.ndarray, spec: CompressionSpec,
               target: Target) -> Decision:
        if self.retrial_every <= 0:
            d = run_trials(blocks_np, spec, target)
            _events.event("tune.decision", scheme=d.winner.scheme,
                          eps=d.winner.eps, target=d.target,
                          abs_bound=d.abs_bound, reason="uncached")
            return d
        sig = chunk_signature(blocks_np)
        with self._guard:
            uses = self._uses.get(sig, 0)
            self._uses[sig] = uses + 1
            cached = self._cache.get(sig)
            if cached is not None and uses % self.retrial_every != 0:
                _CACHE_HITS.inc()
                return cached
        d = run_trials(blocks_np, spec, target)
        with self._guard:
            self._cache[sig] = d
        _events.event("tune.decision", scheme=d.winner.scheme,
                      eps=d.winner.eps, target=d.target,
                      abs_bound=d.abs_bound,
                      reason="retrial" if cached is not None else "first",
                      signature=list(sig))
        return d


_POLICIES: dict[CompressionSpec, DecisionPolicy] = {}
_POLICIES_GUARD = threading.Lock()


def policy_for(spec: CompressionSpec) -> DecisionPolicy:
    """The process-wide policy for this spec (specs hash by value, so the
    cache persists across pipelines/fields of one steady stream)."""
    retrial = int(spec.extra.get("tune_cache", 0)) if spec.extra else 0
    with _POLICIES_GUARD:
        pol = _POLICIES.get(spec)
        if pol is None or pol.retrial_every != retrial:
            pol = _POLICIES[spec] = DecisionPolicy(retrial)
        return pol
