"""Identity scheme: blocks passed straight to shuffle + stage 2.

The control arm of the testbed — isolates what the lossless stage alone buys.
The only scheme whose value stream is stored in the spec's tagged dtype
(float16/float32/float64 round-trip bit-exact); lossy schemes keep their
float32 internal streams and cast on decode.
"""
from __future__ import annotations

import numpy as np

from . import Scheme, register_scheme, shuffle_bytes, unshuffle_bytes


@register_scheme
class RawScheme(Scheme):
    name = "raw"

    def stage1(self, blocks_np, spec):
        return {"raw": np.asarray(blocks_np, spec.np_dtype)}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        dt = spec.np_dtype
        buf = s1["raw"][lo:hi].astype(dt, copy=False).tobytes()
        return shuffle_bytes(buf, spec.shuffle, dt.itemsize)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        dt = spec.np_dtype
        raw = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, dt.itemsize),
                            dt)
        return raw.reshape(nblk, n, n, n).copy()
