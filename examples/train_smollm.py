"""End-to-end training: reduced smollm-135m on synthetic Markov data for a
few hundred steps, with compressed checkpoints — loss must drop.

Run:  PYTHONPATH=src python examples/train_smollm.py
(The full-size config is trained the same way on a real fleet via
repro.launch.train --arch smollm-135m.)
"""
import shutil

from repro.launch.train import main

shutil.rmtree("artifacts/example_ckpt", ignore_errors=True)  # hermetic demo
first, last = main([
    "--arch", "smollm-135m", "--reduced",
    "--steps", "600", "--batch", "16", "--seq", "64", "--lr", "1e-2",
    "--data-branching", "2", "--data-regimes", "1",
    "--ckpt-dir", "artifacts/example_ckpt", "--ckpt-every", "100",
    "--log-every", "50",
])
assert last < first * 0.7, f"loss did not drop: {first} -> {last}"
print(f"OK: loss {first:.3f} -> {last:.3f}")
