"""Single-flight decode scheduling for concurrent region queries.

The sglang-style batching analog for a *decompression* server: when many
request threads need the same CZ2 chunk at the same time, exactly one of
them (the *leader*) decodes it; the rest park on a future and share the
result.  Without this, N concurrent cold requests for a hot region decode
every covering chunk up to N times — the store's per-reader LRU only
dedupes *sequential* repeats, and under eviction pressure (small
``cache_chunks``) not even those.

Flights are keyed by ``(member path, chunk index)``: the member path is
stable across the dataset's reader pool (a reader evicted and re-created
mid-flight still coalesces), and chunk granularity means two requests for
*different* boxes that merely share one chunk still split the decode work.
"""
from __future__ import annotations

import concurrent.futures
import threading

import numpy as np

__all__ = ["SingleFlight", "ChunkScheduler"]


class SingleFlight:
    """Generic duplicate-call suppressor: concurrent :meth:`do` calls with
    the same key run ``fn`` once and all observe its result (or its
    exception).  Calls that arrive after the flight lands run ``fn`` again —
    long-term memory is the *cache's* job, not the scheduler's."""

    def __init__(self):
        self._lock = threading.Lock()
        self._flights: dict[object, concurrent.futures.Future] = {}
        self.led = 0        # calls that executed fn
        self.joined = 0     # calls coalesced onto an existing flight

    def do(self, key, fn):
        with self._lock:
            fut = self._flights.get(key)
            leader = fut is None
            if leader:
                fut = self._flights[key] = concurrent.futures.Future()
                self.led += 1
            else:
                self.joined += 1
        if leader:
            try:
                fut.set_result(fn())
            except BaseException as e:
                fut.set_exception(e)
            finally:
                # land the flight *after* the result is set: late arrivals
                # start a fresh flight (and hit the cache) instead of joining
                # a completed one
                with self._lock:
                    self._flights.pop(key, None)
        return fut.result()


class ChunkScheduler:
    """Coalesces chunk decodes across all request threads of one dataset.

    Wraps :meth:`FieldReader.read_box` with a ``chunk_getter`` that routes
    every chunk fetch through a :class:`SingleFlight`, so each chunk is
    decoded **once per cache miss** no matter how many requests need it
    concurrently.  Chunk *caching* stays where it was — in the reader's LRU
    (and the region LRU above) — the scheduler only owns in-flight work.
    """

    def __init__(self, dataset):
        self.ds = dataset
        self._sf = SingleFlight()
        self._lock = threading.Lock()
        self.bytes_decoded = 0

    @property
    def flights_led(self) -> int:
        return self._sf.led

    @property
    def flights_joined(self) -> int:
        return self._sf.joined

    def read_box(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        reader = self.ds.reader(quantity, int(t))
        # pin each covering chunk for the duration of this request: under
        # LRU pressure (small cache_chunks + concurrent cross-traffic) the
        # reader's cache alone would let one box re-decode its own chunk
        pinned: dict[int, np.ndarray] = {}

        def get(ci: int) -> np.ndarray:
            out = pinned.get(ci)
            if out is None:
                out = pinned[ci] = self._chunk(reader, ci)
            return out

        return reader.read_box(lo, hi, chunk_getter=get)

    def _chunk(self, reader, ci: int) -> np.ndarray:
        return self._sf.do((reader.path, ci),
                           lambda: self._fetch(reader, ci))

    def _fetch(self, reader, ci: int) -> np.ndarray:
        out, decoded = reader.fetch_chunk(ci)
        if decoded:  # a real decode, not an LRU hit
            with self._lock:
                self.bytes_decoded += out.nbytes
        return out

    def stats(self) -> dict:
        return {
            "flights_led": self._sf.led,
            "flights_joined": self._sf.joined,
            "bytes_decoded": self.bytes_decoded,
        }
