"""Figs. 9/10/11 — parallel scaling.

This container exposes one CPU core, so thread scaling cannot be measured
directly; what we *can* measure is the basis of the paper's scaling claims:

1. block-throughput linearity: per-block stage-1 time is constant across
   batch sizes (blocks are independent -> embarrassingly parallel);
2. the stage-1 (device) / stage-2 (host zlib) split that bounds Amdahl
   scaling of a node;
3. a calibrated weak-scaling model of Fig. 11: per-node compress time
   (measured) + shared-file write time (paper's measured 81 GB/s effective
   file-system bandwidth at full machine) + prefix-sum offset latency.

Every modeled (vs measured) number is labeled "model"."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import CompressionSpec, compress_blocks
from repro.core import wavelets
from repro.core.blocks import blockify

from .common import dataset, emit, save_json


def run(quick: bool = True):
    field = dataset("10k")["p"]
    blocks = np.asarray(blockify(field, 32))
    nb = blocks.shape[0]

    # 1. linearity of stage-1 in block count (jit once, then measure)
    fwd = lambda b: wavelets.forward3d(jnp.asarray(b), "w3ai")
    _ = fwd(blocks[:1]).block_until_ready()
    rows = []
    for k in (1, 4, 9, nb):
        t0 = time.time()
        _ = fwd(blocks[:k]).block_until_ready()
        rows.append({"blocks": k, "t_s": time.time() - t0})
    per_block = [(r["t_s"] / r["blocks"]) for r in rows[1:]]
    linearity = max(per_block) / max(min(per_block), 1e-12)

    # 2. Amdahl split on one node
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3)
    t0 = time.time()
    np.asarray(wavelets.forward3d(jnp.asarray(blocks), "w3ai"))
    t_stage1 = time.time() - t0
    t0 = time.time()
    comp = compress_blocks(blocks, spec)
    t_total = time.time() - t0
    t_stage2 = max(t_total - t_stage1, 1e-9)

    # 3. weak-scaling model (Fig 11): 4 GB/node, paper file system
    node_mb = 4 * 1024.0
    comp_MBps = field.nbytes / 2**20 / t_total
    cr = comp.header["raw_bytes"] / comp.nbytes
    fs_MBps_total = 81 * 1024.0          # paper: 81 GB/s effective peak
    model = []
    for nodes in (1, 2, 8, 32, 128, 512):
        t_comp = node_mb / comp_MBps     # perfectly parallel across nodes
        write_mb = nodes * node_mb / cr
        t_io = write_mb / fs_MBps_total + 0.002 * np.log2(max(nodes, 2))
        model.append({"nodes": nodes, "t_compress_s": t_comp,
                      "t_io_s": t_io, "t_total_s": t_comp + t_io,
                      "eff_io_GBps": nodes * node_mb / 1024.0 / (t_comp + t_io),
                      "kind": "model"})
    out = {"linearity_ratio": linearity, "stage1_s": t_stage1,
           "stage2_s": t_stage2, "comp_MBps": comp_MBps, "cr": cr,
           "block_rows": rows, "weak_scaling_model": model}
    save_json("fig9_11_scaling", out)
    emit("fig9_block_linearity", t_total * 1e6, f"{linearity:.2f}")
    emit("fig10_stage2_fraction", t_total * 1e6,
         f"{t_stage2 / (t_stage1 + t_stage2):.3f}")
    emit("fig11_model_512node_eff_GBps", t_total * 1e6,
         f"{model[-1]['eff_io_GBps']:.1f}")
    return out


if __name__ == "__main__":
    run(quick=False)
