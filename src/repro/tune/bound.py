"""Error-bound targets and their mapping onto scheme ``error_bound`` contracts.

Users of error-bounded compressors think in bounds, not codec names: an
absolute tolerance, a value-range-relative tolerance, or a PSNR floor.
:class:`Target` is that vocabulary —

* ``abs=V``  — max absolute error ``<= V`` everywhere;
* ``rel=V``  — max absolute error ``<= V * (chunk value range)``; the range
  is evaluated **per chunk**, so smooth quiet regions get proportionally
  tighter bounds than energetic ones (and the decision stays a pure
  function of chunk content — rank-invariant);
* ``psnr=DB`` — target PSNR (paper Eq. 1) of at least ``DB``; mapped to an
  absolute bound per chunk via the uniform-quantization error model
  (``rmse ~ a / sqrt(3)`` for a bound ``a``), then enforced against the
  *measured* trial PSNR, so the mapping is a search seed, not a promise
  made blind.

:func:`candidate_spec` inverts a registered scheme's declared
``error_bound`` contract (every in-tree lossy scheme declares a bound
linear in ``eps``) to derive the candidate eps that meets a chunk's
absolute bound; lossless schemes are always admissible.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.pipeline import CompressionSpec
from repro.core.schemes import get_scheme

__all__ = ["MODES", "Target", "target_from_spec", "candidate_spec"]

MODES = ("abs", "rel", "psnr")

#: relative slack when re-checking an inverted eps against the declared
#: bound — absorbs float rounding of the inversion, nothing more
_INVERT_SLACK = 1e-9


@dataclasses.dataclass(frozen=True)
class Target:
    """One user-facing quality target: a mode (see :data:`MODES`) + value."""

    mode: str
    value: float

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown target mode {self.mode!r}; one of {MODES}")
        v = float(self.value)
        if not math.isfinite(v) or v <= 0:
            raise ValueError(
                f"target {self.mode}={self.value!r} must be a finite "
                "positive number")
        object.__setattr__(self, "value", v)

    def __str__(self) -> str:
        return f"{self.mode}={self.value:g}"

    @staticmethod
    def parse(text: str) -> "Target":
        """Parse ``"abs=1e-3" | "rel=1e-4" | "psnr=80"`` (the CLI/extra
        syntax).  Raises ValueError on anything else."""
        mode, sep, val = str(text).partition("=")
        if not sep:
            raise ValueError(
                f"bad target {text!r}: expected MODE=VALUE with MODE one "
                f"of {MODES} (e.g. psnr=80, abs=1e-3, rel=1e-4)")
        try:
            value = float(val)
        except ValueError:
            raise ValueError(
                f"bad target value {val!r} in {text!r}: not a number"
            ) from None
        return Target(mode.strip(), value)

    def abs_bound(self, vmin: float, vmax: float) -> float:
        """The absolute error bound this target implies for data spanning
        ``[vmin, vmax]`` (a chunk's value range).  ``rel``/``psnr`` targets
        collapse to 0 for constant data — only lossless candidates remain
        admissible there."""
        if self.mode == "abs":
            return self.value
        rng = float(vmax) - float(vmin)
        if self.mode == "rel":
            return self.value * rng
        # psnr (paper Eq. 1): 20*log10(rng / (2*rmse)) >= DB, with the
        # uniform-error model rmse ~ a/sqrt(3) for a max-abs bound a
        return rng * math.sqrt(3.0) / (2.0 * 10.0 ** (self.value / 20.0))


def target_from_spec(spec: CompressionSpec) -> Target:
    """The spec's target: ``spec.extra["target"]`` when set, else the
    spec's own ``eps`` read as an absolute bound — so ``auto`` behaves as
    an eps-parameterized scheme anywhere a plain spec is expected."""
    raw = spec.extra.get("target") if spec.extra else None
    if raw is None:
        return Target("abs", spec.eps)
    if isinstance(raw, Target):
        return raw
    return Target.parse(raw)


def candidate_spec(name: str, spec: CompressionSpec,
                   abs_bound: float) -> CompressionSpec | None:
    """A candidate spec for scheme ``name`` meeting ``abs_bound``, derived
    from ``spec`` (everything but scheme/eps is inherited — shuffle,
    stage2, block size, dtype, device), or ``None`` when the scheme cannot
    promise the bound:

    * lossless schemes (declared bound ``None``) are always admissible;
    * lossy schemes with a finite declared bound linear in eps get
      ``eps = abs_bound / bound(eps=1)`` (re-checked, not assumed);
    * unbounded-lossy configurations and specs the scheme's own
      ``validate`` rejects are dropped.
    """
    cand = dataclasses.replace(spec, scheme=name, extra={})
    sch = get_scheme(name)
    try:
        b1 = sch.error_bound(dataclasses.replace(cand, eps=1.0))
        if b1 is not None:
            if not (math.isfinite(b1) and b1 > 0 and abs_bound > 0):
                return None
            eps = abs_bound / b1
            cand = dataclasses.replace(cand, eps=eps)
            bound = sch.error_bound(cand)
            if bound is None or bound > abs_bound * (1 + _INVERT_SLACK):
                return None  # the scheme's bound is not linear in eps
        cand.validate()
    except ValueError:
        return None  # scheme rejects this combination by contract
    return cand
