"""CubismZ core: block-structured two-substage scientific data compression."""
from .codec import (  # noqa: F401
    SCHEMES,
    CompressedField,
    CompressionSpec,
    analyze_field,
    compress_blocks,
    compress_field,
    decompress_blocks,
    decompress_field,
)
from .metrics import compression_ratio, mse, psnr  # noqa: F401
