"""Fig. 4 — CR vs PSNR for W4 / W4l / W3ai on p and rho after 10k steps."""
from __future__ import annotations

import time

from repro.core import CompressionSpec

from .common import dataset, emit, eps_sweep, save_json, sweep


def run(quick: bool = True):
    fields = dataset("10k")
    eps_list = eps_sweep(n=4 if quick else 8)
    rows = []
    t0 = time.time()
    for q in ("p", "rho"):
        for wav in ("w4i", "w4l", "w3ai"):
            specs = [CompressionSpec(scheme="wavelet", wavelet=wav, eps=e)
                     for e in eps_list]
            for e, r in zip(eps_list, sweep(fields[q], specs)):
                rows.append({"qoi": q, "wavelet": wav, "eps": e,
                             "cr": r["cr"], "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig4_wavelet_types", rows)

    # validation: at every eps W3ai CR >= 0.9x the best of the other two
    ok = 0
    tot = 0
    for q in ("p", "rho"):
        for e in eps_list:
            by = {r["wavelet"]: r["cr"] for r in rows
                  if r["qoi"] == q and r["eps"] == e}
            tot += 1
            if by["w3ai"] >= 0.9 * max(by["w4i"], by["w4l"]):
                ok += 1
    emit("fig4_w3ai_wins_frac", dt * 1e6 / max(len(rows), 1), f"{ok}/{tot}")
    return rows


if __name__ == "__main__":
    run(quick=False)
