"""ZFP-style fixed-accuracy scheme: 4^3 cells, block-floating-point + lifting.

Byte layout per chunk: per-cell exponents (i8) followed by the shuffled
quantized-coefficient stream (i32).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .. import zfpx as _zfp
from . import Scheme, register_scheme, shuffle_bytes, unshuffle_bytes


@register_scheme
class ZfpxScheme(Scheme):
    name = "zfpx"

    def validate(self, spec) -> None:
        if spec.block_size % 4:
            raise ValueError("zfpx needs block_size % 4 == 0")

    def params(self, spec) -> dict:
        return {"eps": spec.eps, **super().params(spec)}

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        emax, q = _zfp.encode(x, eps=spec.eps)
        return {"emax": np.asarray(emax), "q": np.asarray(q)}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        emax = np.clip(s1["emax"][lo:hi], -127, 127).astype(np.int8)
        q = s1["q"][lo:hi].astype(np.int32)
        return emax.tobytes() + shuffle_bytes(q.tobytes(), spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        nc = (n // 4) ** 3
        emax = np.frombuffer(payload[: nblk * nc], np.int8).astype(np.int32)
        q = np.frombuffer(
            unshuffle_bytes(payload[nblk * nc :], spec.shuffle, 4), np.int32
        )
        emax = emax.reshape(nblk, nc)
        q = q.reshape(nblk, nc, 64)
        return np.asarray(
            _zfp.decode(jnp.asarray(emax), jnp.asarray(q), eps=spec.eps, n=n)
        )
