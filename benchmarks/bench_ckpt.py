"""Restart-snapshot compression (paper: lossless FPZIP 2.62-4.25x on fluid
states).  Here the restart payload is *training state*: lossless fpzipx on
params and AdamW moments, plus the CFD restart case itself for the direct
paper comparison."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import save_checkpoint
from repro.core import CompressionSpec, compress_field
from repro.fields import CloudConfig, cavitation_fields

from .common import emit, save_json


def run(quick: bool = True):
    rows = {}
    t0 = time.time()

    # 1) CFD restart fields, lossless fpzipx (direct paper analogue)
    f = cavitation_fields(CloudConfig(n=64 if quick else 128), 9.4)
    spec = CompressionSpec(scheme="fpzipx", precision=32, shuffle="byte")
    crs = {}
    for q, a in f.items():
        comp = compress_field(a, spec)
        crs[q] = comp.header["raw_bytes"] / comp.nbytes
    rows["cfd_lossless_cr"] = crs

    # 2) training-state restart: briefly-trained reduced model
    from repro.configs import ARCHS, reduced
    from repro.data.tokens import DataConfig, batch_at
    from repro.models import ModelSettings
    from repro.train.step import build_train_step, init_train_state

    cfg = reduced(ARCHS["smollm-135m"])
    st = ModelSettings(q_chunk=16, kv_chunk=32, ce_chunk=32, remat="none",
                       compute_dtype=jnp.float32)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab, batch=4, seq=32)
    _, jit_for, _ = build_train_step(cfg, mesh, settings=st, donate=True)
    b0 = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    jitted = jit_for(jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), b0))
    with mesh:
        for step in range(10 if quick else 40):
            batch = {k: jnp.asarray(v) for k, v in batch_at(dc, step).items()}
            state, _ = jitted(state, batch)

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        m = save_checkpoint(d, jax.device_get(state), 1)
    rows["train_state_cr"] = m["cr"]

    # 3) dtype-lossy restart: bf16-cast params + lossless fpzipx on the rest
    with tempfile.TemporaryDirectory() as d:
        bf_state = {
            "params": jax.tree.map(
                lambda a: np.asarray(a, np.float32), jax.device_get(
                    jax.tree.map(lambda a: a.astype(jnp.bfloat16), state["params"]))),
        }
        m2 = save_checkpoint(d, bf_state, 1)
    rows["params_bf16roundtrip_cr"] = m2["cr"]

    dt = time.time() - t0
    save_json("ckpt_compression", rows)
    emit("ckpt_cfd_lossless_cr_p", dt * 1e6, f"{crs['p']:.2f}")
    emit("ckpt_train_state_cr", dt * 1e6, f"{rows['train_state_cr']:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
