"""Two-substage compression pipeline (paper Fig. 1) + codec registry.

Data flow (mirrors CubismZ):

  field -> blocks -> [substage 1: wavelet+threshold | zfpx | szx | fpzipx]
        -> per-"thread" aggregation buffers (~4 MB of blocks)
        -> optional byte shuffle / bit zeroing
        -> [substage 2: zlib | lzma | bz2 | ...]
        -> chunk list + JSON header (the file payload)

Substage 1 runs on device (jit; Pallas kernels available in repro.kernels),
substage 2 and serialization on the host at the I/O boundary — the same
split the paper uses between its core layer and its cluster-layer writer.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np
import jax.numpy as jnp

from . import blocks as blk
from . import fpzipx, lossless, metrics
from . import shuffle as shuf
from . import szx, threshold, wavelets, zfpx

__all__ = ["CompressionSpec", "CompressedField", "compress_field", "decompress_field",
           "compress_blocks", "decompress_blocks", "analyze_field", "SCHEMES"]

SCHEMES = ("wavelet", "zfpx", "szx", "fpzipx", "raw")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    scheme: str = "wavelet"      # wavelet | zfpx | szx | fpzipx | raw
    wavelet: str = "w3ai"        # w4i | w4l | w3ai
    eps: float = 1e-3            # absolute error tolerance (wavelet/zfpx/szx)
    block_size: int = 32
    levels: int | None = None    # wavelet levels (None = max for block size)
    shuffle: str = "byte"        # none | byte | bit
    zero_bits: int = 0           # Z4/Z8 bit zeroing of detail coefficients
    stage2: str = "zlib"         # see repro.core.lossless.METHODS
    buffer_bytes: int = 4 << 20  # per-thread aggregation buffer (paper: 4 MB)
    precision: int = 32          # fpzipx bits of precision (32 = lossless)

    def validate(self) -> "CompressionSpec":
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme}")
        if self.wavelet not in wavelets.WAVELETS:
            raise ValueError(f"unknown wavelet {self.wavelet}")
        if self.shuffle not in ("none", "byte", "bit"):
            raise ValueError(f"unknown shuffle {self.shuffle}")
        if self.stage2 not in lossless.METHODS:
            raise ValueError(f"unknown stage2 {self.stage2}")
        blk.check_block_size(self.block_size)
        if self.scheme == "zfpx" and self.block_size % 4:
            raise ValueError("zfpx needs block_size % 4 == 0")
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "CompressionSpec":
        return CompressionSpec(**d)


class CompressedField:
    """In-memory compressed representation: chunk list + JSON-able header."""

    def __init__(self, chunks: list[bytes], header: dict):
        self.chunks = chunks
        self.header = header

    @property
    def nbytes(self) -> int:
        return sum(len(c) for c in self.chunks) + len(json.dumps(self.header))

    @property
    def spec(self) -> CompressionSpec:
        return CompressionSpec.from_json(self.header["spec"])


def _shuffle_bytes(buf: bytes, mode: str, itemsize: int) -> bytes:
    if mode == "none" or itemsize == 1:
        return buf
    fn = shuf.byte_shuffle if mode == "byte" else shuf.bit_shuffle
    return fn(buf, itemsize)


def _unshuffle_bytes(buf: bytes, mode: str, itemsize: int) -> bytes:
    if mode == "none" or itemsize == 1:
        return buf
    fn = shuf.byte_unshuffle if mode == "byte" else shuf.bit_unshuffle
    return fn(buf, itemsize)


# ---------------------------------------------------------------------------
# Substage 1 — device transforms (whole block batch at once)
# ---------------------------------------------------------------------------

def _stage1(blocks_np: np.ndarray, spec: CompressionSpec) -> dict[str, np.ndarray]:
    x = jnp.asarray(blocks_np, jnp.float32)
    n = spec.block_size
    if spec.scheme == "wavelet":
        coeffs = wavelets.forward3d(x, spec.wavelet, spec.levels)
        mask = threshold.significant_mask(coeffs, spec.eps, spec.levels)
        c = wavelets.coarse_side(n, spec.levels)
        return {
            "mask": np.asarray(mask),
            "coeffs": np.asarray(coeffs),
            "coarse": np.asarray(coeffs[..., :c, :c, :c]),
        }
    if spec.scheme == "zfpx":
        emax, q = zfpx.encode(x, eps=spec.eps)
        return {"emax": np.asarray(emax), "q": np.asarray(q)}
    if spec.scheme == "szx":
        szx.check_eps(float(jnp.max(jnp.abs(x))), spec.eps)
        return {"res": np.asarray(szx.encode(x, eps=spec.eps))}
    if spec.scheme == "fpzipx":
        return {"delta": np.asarray(fpzipx.encode(x, precision=spec.precision))}
    return {"raw": np.asarray(x)}  # scheme == "raw"


# ---------------------------------------------------------------------------
# Chunk serialization (host) — one aggregation buffer at a time
# ---------------------------------------------------------------------------

def _serialize_chunk(s1: dict, lo: int, hi: int, spec: CompressionSpec) -> bytes:
    if spec.scheme == "wavelet":
        mask = s1["mask"][lo:hi]
        coeffs = s1["coeffs"][lo:hi]
        coarse = s1["coarse"][lo:hi].astype(np.float32)
        details = coeffs[mask].astype(np.float32)
        if spec.zero_bits:
            details = shuf.zero_low_bits_np(details, spec.zero_bits)
        counts = mask.reshape(mask.shape[0], -1).sum(-1).astype(np.uint32)
        values = np.concatenate([coarse.reshape(-1), details])
        payload = (
            counts.tobytes()
            + np.packbits(mask.reshape(-1)).tobytes()
            + _shuffle_bytes(values.tobytes(), spec.shuffle, 4)
        )
    elif spec.scheme == "zfpx":
        emax = np.clip(s1["emax"][lo:hi], -127, 127).astype(np.int8)
        q = s1["q"][lo:hi].astype(np.int32)
        payload = emax.tobytes() + _shuffle_bytes(q.tobytes(), spec.shuffle, 4)
    elif spec.scheme == "szx":
        r = s1["res"][lo:hi].reshape(-1)
        small = np.abs(r) <= 127
        stream = np.where(small, r, -128).astype(np.int8)
        outliers = r[~small].astype(np.int32)
        payload = (
            np.uint32(outliers.size).tobytes()
            + stream.tobytes()
            + outliers.tobytes()
        )
    elif spec.scheme == "fpzipx":
        d = s1["delta"][lo:hi].astype(np.uint32)
        payload = _shuffle_bytes(d.tobytes(), spec.shuffle, 4)
    else:  # raw
        payload = _shuffle_bytes(s1["raw"][lo:hi].astype(np.float32).tobytes(), spec.shuffle, 4)
    return lossless.encode(payload, spec.stage2)


def _deserialize_chunk(buf: bytes, nblk: int, spec: CompressionSpec) -> np.ndarray:
    n = spec.block_size
    payload = lossless.decode(buf, spec.stage2)
    if spec.scheme == "wavelet":
        c = wavelets.coarse_side(n, spec.levels)
        counts = np.frombuffer(payload[: 4 * nblk], np.uint32)
        off = 4 * nblk
        mask_bytes = nblk * n * n * n // 8
        mask = np.unpackbits(np.frombuffer(payload[off : off + mask_bytes], np.uint8))
        mask = mask[: nblk * n * n * n].astype(bool).reshape(nblk, n, n, n)
        off += mask_bytes
        values = np.frombuffer(
            _unshuffle_bytes(payload[off:], spec.shuffle, 4), np.float32
        )
        ncoarse = nblk * c * c * c
        coarse = values[:ncoarse].reshape(nblk, c, c, c)
        details = values[ncoarse:]
        coeffs = np.zeros((nblk, n, n, n), np.float32)
        coeffs[mask] = details
        coeffs[:, :c, :c, :c] = coarse
        out = wavelets.inverse3d(jnp.asarray(coeffs), spec.wavelet, spec.levels)
        return np.asarray(out)
    if spec.scheme == "zfpx":
        nc = (n // 4) ** 3
        emax = np.frombuffer(payload[: nblk * nc], np.int8).astype(np.int32)
        q = np.frombuffer(
            _unshuffle_bytes(payload[nblk * nc :], spec.shuffle, 4), np.int32
        )
        emax = emax.reshape(nblk, nc)
        q = q.reshape(nblk, nc, 64)
        return np.asarray(zfpx.decode(jnp.asarray(emax), jnp.asarray(q), eps=spec.eps, n=n))
    if spec.scheme == "szx":
        n_out = int(np.frombuffer(payload[:4], np.uint32)[0])
        nvals = nblk * n * n * n
        stream = np.frombuffer(payload[4 : 4 + nvals], np.int8)
        outliers = np.frombuffer(payload[4 + nvals : 4 + nvals + 4 * n_out], np.int32)
        r = stream.astype(np.int32)
        esc = stream == -128
        r[esc] = outliers
        r = r.reshape(nblk, n, n, n)
        return np.asarray(szx.decode(jnp.asarray(r), eps=spec.eps))
    if spec.scheme == "fpzipx":
        d = np.frombuffer(_unshuffle_bytes(payload, spec.shuffle, 4), np.uint32)
        d = d.reshape(nblk, n, n, n)
        return np.asarray(fpzipx.decode(jnp.asarray(d)))
    raw = np.frombuffer(_unshuffle_bytes(payload, spec.shuffle, 4), np.float32)
    return raw.reshape(nblk, n, n, n).copy()


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _blocks_per_chunk(spec: CompressionSpec) -> int:
    raw_block = 4 * spec.block_size ** 3
    return max(1, spec.buffer_bytes // raw_block)


def compress_blocks(blocks_np: np.ndarray, spec: CompressionSpec,
                    extra_header: dict | None = None) -> CompressedField:
    spec = spec.validate()
    nblocks = blocks_np.shape[0]
    s1 = _stage1(blocks_np, spec)
    bpc = _blocks_per_chunk(spec)
    chunks, chunk_nblocks = [], []
    for lo in range(0, nblocks, bpc):
        hi = min(lo + bpc, nblocks)
        chunks.append(_serialize_chunk(s1, lo, hi, spec))
        chunk_nblocks.append(hi - lo)
    header = {
        "spec": spec.to_json(),
        "nblocks": nblocks,
        "chunk_nblocks": chunk_nblocks,
        "chunk_sizes": [len(c) for c in chunks],
        "raw_bytes": int(blocks_np.size * 4),
    }
    if extra_header:
        header.update(extra_header)
    return CompressedField(chunks, header)


def decompress_blocks(comp: CompressedField) -> np.ndarray:
    spec = comp.spec
    outs = [
        _deserialize_chunk(buf, nb, spec)
        for buf, nb in zip(comp.chunks, comp.header["chunk_nblocks"])
    ]
    return np.concatenate(outs, axis=0)


def compress_field(field: np.ndarray, spec: CompressionSpec) -> CompressedField:
    spec = spec.validate()
    blocks_np = np.asarray(blk.blockify(np.asarray(field, np.float32), spec.block_size))
    return compress_blocks(blocks_np, spec, extra_header={"field_shape": list(field.shape)})


def decompress_field(comp: CompressedField) -> np.ndarray:
    blocks_np = decompress_blocks(comp)
    return np.asarray(blk.unblockify(blocks_np, tuple(comp.header["field_shape"])))


def analyze_field(field: np.ndarray, spec: CompressionSpec) -> dict[str, Any]:
    """Compress + decompress + measure (CR, PSNR, error bound) in one call."""
    comp = compress_field(field, spec)
    dec = decompress_field(comp)
    return {
        "cr": metrics.compression_ratio(comp.header["raw_bytes"], comp.nbytes),
        "psnr": metrics.psnr(field, dec),
        "max_err": float(np.max(np.abs(np.asarray(field) - dec))),
        "comp_bytes": comp.nbytes,
        "raw_bytes": comp.header["raw_bytes"],
        "spec": spec,
    }
