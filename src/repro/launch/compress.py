"""Ex-situ compression tool (the paper's standalone CubismZ CLI).

Compresses 3D fields — from the cavitation generator, the Euler solver, or
a raw .npy file — into CZ containers, reports CR/PSNR per quantity, and can
decompress/verify.

Examples:
  python -m repro.launch.compress --source cavitation --t 9.4 --n 128 \
      --scheme wavelet --wavelet w3ai --eps 1e-3 --out /tmp/fields
  python -m repro.launch.compress --decompress /tmp/fields/p.cz --verify-against /tmp/p.npy
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import SCHEMES, CompressionSpec, compression_ratio, psnr
from repro.core import container
from repro.fields import CloudConfig, cavitation_fields


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="cavitation",
                    choices=["cavitation", "npy"])
    ap.add_argument("--npy", default="", help="input .npy for --source npy")
    ap.add_argument("--t", type=float, default=9.4, help="snapshot time (us)")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--qoi", default="p,rho,E,a2")
    ap.add_argument("--scheme", default="wavelet",
                    help=f"any registered scheme ({', '.join(sorted(SCHEMES))})")
    ap.add_argument("--list-schemes", action="store_true",
                    help="print the scheme registry and exit")
    ap.add_argument("--wavelet", default="w3ai")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--shuffle", default="byte")
    ap.add_argument("--zero-bits", type=int, default=0)
    ap.add_argument("--stage2", default="zlib")
    ap.add_argument("--precision", type=int, default=32)
    ap.add_argument("--out", default="artifacts/fields")
    ap.add_argument("--decompress", default="")
    ap.add_argument("--verify-against", default="")
    args = ap.parse_args(argv)

    if args.list_schemes:
        for name in sorted(SCHEMES):
            print(f"{name:10s} {type(SCHEMES[name]).__module__}")
        return

    if args.decompress:
        t0 = time.time()
        field = container.read_field(args.decompress)
        print(f"decompressed {field.shape} in {time.time()-t0:.2f}s")
        if args.verify_against:
            ref = np.load(args.verify_against)
            print(f"PSNR vs reference: {psnr(ref, field):.2f} dB "
                  f"maxerr {np.max(np.abs(ref-field)):.3e}")
        return

    spec = CompressionSpec(
        scheme=args.scheme, wavelet=args.wavelet, eps=args.eps,
        block_size=args.block_size, shuffle=args.shuffle,
        zero_bits=args.zero_bits, stage2=args.stage2, precision=args.precision)
    os.makedirs(args.out, exist_ok=True)

    if args.source == "npy":
        fields = {"field": np.load(args.npy).astype(np.float32)}
    else:
        fields = cavitation_fields(CloudConfig(n=args.n), args.t)
        fields = {k: v for k, v in fields.items() if k in args.qoi.split(",")}

    report = {}
    for name, f in fields.items():
        t0 = time.time()
        path = os.path.join(args.out, f"{name}.cz")
        nbytes = container.write_field(path, f, spec)
        dt = time.time() - t0
        dec = container.read_field(path)
        report[name] = {
            "cr": compression_ratio(f.nbytes, nbytes),
            "psnr_db": psnr(f, dec),
            "comp_MBps": f.nbytes / 2**20 / dt,
            "bytes": nbytes,
        }
        print(f"{name:5s} CR={report[name]['cr']:8.2f} "
              f"PSNR={report[name]['psnr_db']:7.2f} dB "
              f"{report[name]['comp_MBps']:6.1f} MB/s -> {path}")
    with open(os.path.join(args.out, "report.json"), "w") as f:
        json.dump({"spec": spec.to_json(), "fields": report}, f, indent=1)


if __name__ == "__main__":
    main()
