"""Pallas TPU kernels for the compression hot spots (+ ops wrappers, refs)."""
from .ops import (  # noqa: F401
    lorenzo_decode,
    lorenzo_encode,
    wavelet_forward,
    wavelet_inverse,
    zfpx_decode,
    zfpx_encode,
)
