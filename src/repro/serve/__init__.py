"""serve subsystem: jitted LLM decode/prefill steps (``serve.step``) and
compressed-field region serving (``serve.region``, jax-free import path)."""
from .region import FieldRegionServer  # noqa: F401
