"""Quickstart: compress a 3D scientific field with every codec in 20 lines.

The scheme registry is open — ``repro.core.schemes.register_scheme`` plugs a
new compressor into the same ``Pipeline``/container/CLI without touching core.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import CompressionSpec, Pipeline, SCHEMES
from repro.fields import CloudConfig, cavitation_fields

# a cloud-cavitation pressure snapshot (the paper's flagship dataset)
field = cavitation_fields(CloudConfig(n=64), t=9.4)["p"]

print(f"registered schemes: {', '.join(sorted(SCHEMES))}\n")

for spec in [
    CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3),   # paper's best
    CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-2, zero_bits=8),
    CompressionSpec(scheme="zfpx", eps=1e-3),
    CompressionSpec(scheme="szx", eps=1e-3),
    CompressionSpec(scheme="fpzipx", precision=32),                # lossless
]:
    r = Pipeline(spec).analyze(field)
    print(f"{spec.scheme:8s} eps={spec.eps:g} -> CR {r['cr']:7.2f}x  "
          f"PSNR {r['psnr']:7.2f} dB  max|err| {r['max_err']:.2e}")
