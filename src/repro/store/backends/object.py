"""RangeStore: an object-store-style backend with S3 access semantics.

The test double that keeps the read path honest.  Like a cloud object
store, it permits exactly two data operations:

* **whole-object put** — objects are immutable blobs, there is no seek,
  no append, no rename.  ``put_atomic`` *is* ``put`` (a single PUT is
  atomic), and the CZ2 writer goes through the buffering ``open_write``
  because you cannot patch a footer pointer in place;
* **byte-range get** — ``get(key, byte_range=(off, end))``, the S3
  ``Range: bytes=off-`` request.

Every request is counted (``stats()``), so tests and benchmarks can assert
that a region query fetched *ranges of* a member, not the member — the
access pattern error-bounded compressors are judged on.  An optional
``latency`` models per-request round-trip cost so ``bench_backends`` can
show how chunk caching amortizes a remote store.
"""
from __future__ import annotations

import time

from .memory import MemoryStore

__all__ = ["RangeStore"]


class RangeStore(MemoryStore):
    """Object-store semantics over in-memory blobs, with request counters."""

    scheme = "range"

    #: distinct ``range://`` namespace (MemoryStore's registry is per-class)
    _named: dict[str, "RangeStore"] = {}

    def __init__(self, name: str | None = None, latency: float = 0.0):
        super().__init__(name)
        self.latency = float(latency)
        self.get_requests = 0
        self.range_requests = 0
        self.put_requests = 0
        self.bytes_fetched = 0
        self.bytes_put = 0

    def _request(self) -> None:
        if self.latency:
            time.sleep(self.latency)

    def get(self, key, byte_range=None):
        self._request()
        data = super().get(key, byte_range)
        with self._guard:
            self.get_requests += 1
            if byte_range is not None:
                self.range_requests += 1
            self.bytes_fetched += len(data)
        return data

    def put(self, key, data):
        self._request()
        super().put(key, data)
        with self._guard:
            self.put_requests += 1
            self.bytes_put += len(data)

    def stats(self) -> dict:
        """Request/traffic counters since construction."""
        with self._guard:
            return {
                "get_requests": self.get_requests,
                "range_requests": self.range_requests,
                "put_requests": self.put_requests,
                "bytes_fetched": self.bytes_fetched,
                "bytes_put": self.bytes_put,
                "objects": len(self._objects),
                "bytes_stored": sum(map(len, self._objects.values())),
            }
