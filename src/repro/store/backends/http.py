"""HttpStore: read-only byte store over any static HTTP(S) file server.

The paper's ex situ workflow assumes compressed datasets live on shared
storage and are read back over the network.  This backend closes that loop
with nothing but the stdlib: a dataset directory exported by *any* static
file server (nginx, an S3 website endpoint, ``python -m
repro.store.backends.http``) becomes a mountable ``http://`` /
``https://`` dataset root for CZDataset, the serve tier, and
``cz-compress inspect|serve``.

Design points:

* **byte-range GETs** — ``get(key, (start, end))`` sends ``Range:
  bytes=start-end-1``, so ``FieldReader`` pulls footers and chunks without
  ever transferring whole members.  Servers that ignore ``Range`` (plain
  ``python -m http.server``) answer 200 with the full object; the store
  slices client-side so reads stay *correct*, at whole-object transfer
  cost — the bytes_fetched meter makes that amplification visible;
* **keep-alive connection pooling** — a small pool of
  :class:`http.client.HTTPConnection` per store, reused across requests;
  a request that trips over a stale pooled connection is retried once on a
  fresh one (server restarts between requests are invisible);
* **read-only** — ``put``/``delete``/``list`` raise: a static file server
  has no write or enumeration protocol.  CZDataset opens read-only roots
  fine (the manifest is fetched with ``get``); append/gc need a writable
  backend;
* **remote** — ``Store.remote = True``, so ``open_store`` wraps HttpStore
  in a :class:`~repro.store.backends.retry.RetryStore` by default and
  transient network faults are absorbed by policy.

:class:`StaticFileServer` is the loopback half: a threaded,
range-capable static server over a local directory (stdlib
``http.server`` does **not** honor ``Range``), used by tests and
``bench_backends`` — and runnable standalone via ``python -m
repro.store.backends.http <dir>`` as the quickest way to export a dataset.
"""
from __future__ import annotations

import os
import re
import threading
import time
from http.client import HTTPConnection, HTTPException, HTTPSConnection
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import quote, unquote, urlsplit

from .base import (Store, StoreKeyError, StoreRangeError, check_key,
                   shared_io_pool)
from .instrument import StoreMeter

__all__ = ["HttpStore", "StaticFileServer"]


class HttpStore(Store):
    """Read-only ranged-get store speaking HTTP(S) to a static file server.

    ``base_url`` is the dataset root (``http://host:port/path/to/ds``);
    keys are resolved beneath it.  ``timeout`` is the per-request socket
    timeout (connect + each read); ``pool_size`` bounds the keep-alive
    connection pool.
    """

    scheme = "http"
    remote = True

    def __init__(self, base_url: str, timeout: float = 30.0,
                 pool_size: int = 8):
        super().__init__()
        if "://" not in base_url:
            base_url = "http://" + base_url
        u = urlsplit(base_url)
        if u.scheme not in ("http", "https"):
            raise ValueError(f"HttpStore needs an http(s) URL: {base_url!r}")
        if not u.hostname:
            raise ValueError(f"HttpStore URL needs a host: {base_url!r}")
        self.secure = u.scheme == "https"
        self.host = u.hostname
        self.port = u.port  # None -> protocol default
        self.prefix = u.path.rstrip("/")
        self.timeout = float(timeout)
        self.pool_size = int(pool_size)
        self._pool: list[HTTPConnection] = []
        self._pool_guard = threading.Lock()
        self.meter = StoreMeter("http")

    @classmethod
    def from_url(cls, rest: str, secure: bool = False) -> "HttpStore":
        return cls(("https://" if secure else "http://") + rest)

    # -- connection pool ---------------------------------------------------

    def _connect(self) -> HTTPConnection:
        cls = HTTPSConnection if self.secure else HTTPConnection
        return cls(self.host, self.port, timeout=self.timeout)

    def _borrow(self) -> HTTPConnection:
        with self._pool_guard:
            if self._pool:
                return self._pool.pop()
        return self._connect()

    def _give_back(self, conn: HTTPConnection) -> None:
        with self._pool_guard:
            if len(self._pool) < self.pool_size:
                self._pool.append(conn)
                return
        conn.close()

    def close(self) -> None:
        """Close pooled keep-alive connections (idempotent)."""
        with self._pool_guard:
            pool, self._pool = self._pool, []
        for conn in pool:
            conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _request(self, method: str, key: str, headers=None):
        """One HTTP exchange -> ``(status, lowercase headers, body)``.

        The single stale-keep-alive helper: a pooled connection whose peer
        has since closed fails here, not at the caller — the request is
        replayed once on a fresh connection (safe: everything this store
        sends is an idempotent GET/HEAD).
        """
        target = f"{self.prefix}/{quote(check_key(key))}"
        last: Exception | None = None
        for attempt in (0, 1):
            conn = self._borrow() if attempt == 0 else self._connect()
            try:
                conn.request(method, target, headers=headers or {})
                r = conn.getresponse()
                body = r.read()  # drain fully so the connection is reusable
            except (HTTPException, ConnectionError, OSError) as e:
                conn.close()
                last = e
                continue
            self._give_back(conn)
            return r.status, {k.lower(): v for k, v in r.getheaders()}, body
        raise IOError(f"{method} {self.url}/{key}: {last}") from last

    def _size(self, key: str) -> int:
        status, rh, _ = self._request("HEAD", key)
        if status == 404:
            raise StoreKeyError(key)
        if status != 200:
            raise IOError(f"HEAD {self.url}/{key} -> HTTP {status}")
        return int(rh.get("content-length", 0))

    # -- primitives --------------------------------------------------------

    def get(self, key, byte_range=None):
        t0 = time.perf_counter()
        headers = {}
        start = end = None
        if byte_range is not None:
            start, end = byte_range
            start = int(start)
            if start < 0:
                raise ValueError(f"byte_range start must be >= 0, got {start}")
            if end is not None and int(end) <= start:
                # empty span: nothing to transfer, but the contract still
                # requires key-exists and start-in-range — one HEAD settles
                # both (Range: bytes=N-M with M < N is not expressible)
                size = self._size(key)
                if start and start >= size:
                    raise StoreRangeError(key, start, size)
                return b""
            headers["Range"] = (f"bytes={start}-" if end is None
                                else f"bytes={start}-{int(end) - 1}")
        status, rh, body = self._request("GET", key, headers)
        if status == 404:
            raise StoreKeyError(key)
        if status == 416:
            m = re.match(r"bytes \*/(\d+)", rh.get("content-range", ""))
            raise StoreRangeError(key, start or 0, int(m.group(1)) if m else -1)
        if status == 206:
            data = body
        elif status == 200:
            if byte_range is None:
                data = body
            else:
                # server ignored Range: slice client-side (correct, but the
                # full object crossed the wire — see bytes_fetched)
                if start and start >= len(body):
                    raise StoreRangeError(key, start, len(body))
                data = body[start:] if end is None else body[start:int(end)]
        else:
            raise IOError(f"GET {self.url}/{key} -> HTTP {status}")
        self.meter.record("get", len(data), time.perf_counter() - t0,
                          ranged=byte_range is not None)
        return data

    def get_many(self, requests):
        """Pipelined ranged gets over the connection pool: one pooled
        connection per in-flight request, round-trips overlapped."""
        reqs = list(requests)
        if len(reqs) < 2:
            return [self.get(k, r) for k, r in reqs]
        pool = shared_io_pool()
        return [f.result()
                for f in [pool.submit(self.get, k, r) for k, r in reqs]]

    def exists(self, key):
        status, _, _ = self._request("HEAD", key)
        if status == 200:
            return True
        if status in (404, 410):
            return False
        raise IOError(f"HEAD {self.url}/{key} -> HTTP {status}")

    def put(self, key, data):
        raise IOError(f"HttpStore is read-only ({self.url}): cannot put "
                      f"{key!r} — write through the server's native backend")

    def delete(self, key):
        raise IOError(f"HttpStore is read-only ({self.url}): cannot delete "
                      f"{key!r}")

    def list(self, prefix=""):
        raise IOError(f"HttpStore cannot enumerate keys ({self.url}): static"
                      " HTTP has no listing protocol — gc and append need a"
                      " writable backend")

    def stats(self) -> dict:
        """Request/traffic counters since construction (meter shape)."""
        return self.meter.stats()

    @property
    def url(self) -> str:
        scheme = "https" if self.secure else "http"
        port = f":{self.port}" if self.port else ""
        return f"{scheme}://{self.host}{port}{self.prefix}"


# ---------------------------------------------------------------------------
# loopback static server (tests / benchmarks / quickstart)


class _StaticHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive, so the pool gets exercised
    server_version = "cz-static/1"

    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _path_for(self) -> str | None:
        key = unquote(urlsplit(self.path).path).lstrip("/")
        try:
            check_key(key)
        except ValueError:
            return None
        return os.path.join(self.server.root, *key.split("/"))

    def do_GET(self):
        self._serve(head=False)

    def do_HEAD(self):
        self._serve(head=True)

    def _serve(self, head: bool):
        path = self._path_for()
        if path is None or not os.path.isfile(path):
            body = b"not found\n"
            self.send_response(404)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            if not head:
                self.wfile.write(body)
            return
        size = os.path.getsize(path)
        start, end, status = 0, size, 200
        rng = self.headers.get("Range")
        if rng and size:
            m = re.match(r"bytes=(\d+)-(\d*)$", rng.strip())
            if m:  # unparsable Range falls through to a full 200
                start = int(m.group(1))
                if start >= size:
                    self.send_response(416)
                    self.send_header("Content-Range", f"bytes */{size}")
                    self.send_header("Content-Length", "0")
                    self.end_headers()
                    return
                end = min(int(m.group(2)) + 1 if m.group(2) else size, size)
                status = 206
        self.send_response(status)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Accept-Ranges", "bytes")
        self.send_header("Content-Length", str(end - start))
        if status == 206:
            self.send_header("Content-Range", f"bytes {start}-{end - 1}/{size}")
        self.end_headers()
        if head:
            return
        with open(path, "rb") as f:
            f.seek(start)
            remaining = end - start
            while remaining > 0:
                buf = f.read(min(remaining, 1 << 16))
                if not buf:
                    break
                self.wfile.write(buf)
                remaining -= len(buf)


class StaticFileServer(ThreadingHTTPServer):
    """Range-capable threaded static file server over a directory.

    Exists because ``python -m http.server`` ignores ``Range`` headers —
    correct but amplified for ranged readers.  This one answers 206/416
    properly, so tests and benchmarks exercise true byte-range transfer.

    Usage::

        with StaticFileServer(ds_dir) as srv:
            store = HttpStore(srv.url)
    """

    daemon_threads = True

    def __init__(self, root, host: str = "127.0.0.1", port: int = 0,
                 verbose: bool = False):
        self.root = os.path.abspath(os.fspath(root))
        self.verbose = verbose
        self._thread: threading.Thread | None = None
        super().__init__((host, port), _StaticHandler)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "StaticFileServer":
        """Serve on a daemon thread until :meth:`close`."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self.serve_forever, name="cz-static", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self.shutdown()
            self._thread.join(timeout=5)
            self._thread = None
        self.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()


def main(argv=None) -> int:
    """``python -m repro.store.backends.http DIR`` — export a dataset
    directory over loopback HTTP with byte-range support."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.store.backends.http",
        description="Range-capable static file server (stdlib http.server "
                    "ignores Range; this one answers 206/416).")
    ap.add_argument("dir", help="directory to export (a dataset root)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--verbose", action="store_true",
                    help="log each request to stderr")
    args = ap.parse_args(argv)

    srv = StaticFileServer(args.dir, host=args.host, port=args.port,
                           verbose=args.verbose)
    print(f"serving {srv.root} at {srv.url} (byte ranges supported) — "
          "Ctrl-C to stop")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.server_close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
