"""``repro.store`` — sharded multi-quantity dataset store over CZ2 members.

A petascale run is a *dataset* — many quantities x many timesteps — not a
pile of loose files.  :class:`CZDataset` makes the paper's per-quantity,
per-snapshot output layout first-class (Zarr-style manifest-driven store;
WaveRange-style per-field, per-snapshot records), and since PR 6 it lives
on a pluggable byte store (:mod:`repro.store.backends`): the same dataset
opens from a local directory (``file://`` or a plain path), from process
memory (``mem://``), or from an object-store-style backend (``range://``)
that only speaks whole-object put + byte-range get.

Store layout (keys are relative POSIX paths, shown here on a FileStore)
-----------------------------------------------------------------------

::

    dataset/
      manifest.json            # the ONLY mutable object; atomic put_atomic
      p/
        t000000.cz             # CZ2 container: quantity "p", timestep 0
        t000001.cz
      rho/
        t000000.cz
        t000001.cz

* Every member is an ordinary CZ2 container (``repro.core.container``):
  independently decompressible chunks, per-chunk CRC32, self-describing
  JSON footer (scheme name + params + dtype tag) — each member also reads
  standalone with ``read_field``/``FieldReader``.
* ``manifest.json`` is the commit point.  Schema (format 1)::

      {"magic": "CZDS", "format": 1,
       "version": <int, +1 per commit>, "next_t": <int>,
       "spec": {<dataset-default CompressionSpec>},
       "quantities": {
         "p": {"shape": [nx, ny, nz], "dtype": "float32",
               "timesteps": [{"t": 0, "time": 9.4, "file": "p/t000000.cz",
                              "bytes": ..., "raw_bytes": ...}, ...]}}}

  A timestep exists iff the manifest references it; members are written
  first and the manifest is replaced through ``Store.put_atomic``, so a
  crash mid-append leaves at most orphaned member objects, never a torn
  dataset.
* **Append mode** (``mode="a"``): an in-situ simulation opens the dataset
  once and appends timesteps as they are produced; chunk encoding for all
  quantities of a snapshot runs on one shared thread pool
  (:class:`ShardWriter` — the paper's per-thread writers) with a single
  ordered drain per member, byte-identical to a serial write on every
  backend.
* **Region reads**: ``read_box(quantity, t, lo, hi)`` decodes only the
  chunks covering the sub-box through per-member LRU chunk caches
  (``FieldReader``), fetched as *byte ranges* from the store — never the
  whole member, never the whole field.
* **Multi-writer runs** (``repro.cluster.multiwriter``): per-rank
  ``manifest.rank{r}.json`` sidecars commit independently during in-situ
  append and are folded into ``manifest.json`` by one atomic merge;
  ``CZDataset.gc()`` reclaims orphans from torn appends or aborted merges
  (``Store.list``-driven, so gc works on every backend) without ever
  touching sidecar-referenced (still pending) members.

This module resolves its exports lazily (PEP 562): ``repro.core.container``
imports :mod:`repro.store.backends` for the byte-store protocol, and
:mod:`repro.store.dataset` imports the container — eager re-exports here
would close that loop.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "CZDataset": ".dataset",
    "ShardWriter": ".writer",
    "DtypeCoercionWarning": ".writer",
    "ManifestError": ".manifest",
    "MANIFEST_NAME": ".manifest",
    "Store": ".backends",
    "StoreKeyError": ".backends",
    "FileStore": ".backends",
    "MemoryStore": ".backends",
    "RangeStore": ".backends",
    "FlakyStore": ".backends",
    "InjectedFault": ".backends",
    "open_store": ".backends",
    "register_store_scheme": ".backends",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module, __name__), name)
    globals()[name] = value  # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
