"""FPZIP-style lossless/near-lossless scheme: predictive delta coding of the
monotone ordered-uint mapping of float32 (bit-exact at precision=32).

Byte layout per chunk: one shuffled u32 delta stream.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .. import fpzipx as _fpz
from . import Scheme, register_scheme, shuffle_bytes, unshuffle_bytes


@register_scheme
class FpzipxScheme(Scheme):
    name = "fpzipx"

    def validate(self, spec) -> None:
        if spec.dtype != "float32":
            raise ValueError(
                "fpzipx predicts on the float32 bit pattern; its lossless "
                f"guarantee does not hold for dtype={spec.dtype!r} — use the "
                "'raw' scheme for other dtypes")

    def params(self, spec) -> dict:
        return {"precision": spec.precision, **super().params(spec)}

    def error_bound(self, spec):
        # precision=32 is the lossless configuration; truncated-precision
        # error depends on value magnitudes, so no absolute bound is declared
        return None if spec.precision >= 32 else float("inf")

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        return {"delta": np.asarray(_fpz.encode(x, precision=spec.precision))}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        d = s1["delta"][lo:hi].astype(np.uint32)
        return shuffle_bytes(d.tobytes(), spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        d = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, 4), np.uint32)
        d = d.reshape(nblk, n, n, n)
        return np.asarray(_fpz.decode(jnp.asarray(d)))
