"""RetryStore: retry/backoff policy layer for remote-ish backends.

Networks drop requests; a reader stack in which every caller hand-rolls its
own retry loop ends up with none of them agreeing on what "transient"
means.  This wrapper centralizes the policy: any :class:`Store` op that
fails with a *transient* fault (the :class:`OSError` family — connection
resets, injected :class:`~repro.store.backends.flaky.InjectedFault`s,
socket timeouts) is retried up to ``retries`` times with exponential
backoff and jitter, bounded by an optional per-op ``deadline``.

Permanent errors are never retried: :class:`StoreKeyError` (the object is
not there) and :class:`StoreRangeError` (the range can never be satisfied)
pass straight through — both are checked *before* the transient family,
since ``StoreRangeError`` is itself an ``IOError``.

Every retry bumps ``cz_store_retries_total{backend,op}`` and emits a
``store.retry`` event; a retry budget exhausted against the deadline bumps
``cz_store_deadline_exceeded_total{backend,op}`` and raises
:class:`StoreDeadlineError`.  ``sleep``/``rng`` are injectable so tests run
deterministic schedules without wall-clock waits.

``open_store`` wraps any backend with ``remote = True`` (HttpStore) in this
layer by default; ``retries=0`` opts out, an explicit ``retries=N`` opts
any backend in.
"""
from __future__ import annotations

import random
import time

from repro import obs

from .base import Store, StoreKeyError, StoreRangeError

__all__ = ["RetryStore", "StoreDeadlineError"]

_RETRIES = obs.counter("cz_store_retries_total",
                       "Store operations retried after a transient fault.",
                       labelnames=("backend", "op"))
_DEADLINE = obs.counter(
    "cz_store_deadline_exceeded_total",
    "Store operations abandoned at their per-op retry deadline.",
    labelnames=("backend", "op"))


class StoreDeadlineError(TimeoutError):
    """The per-op deadline expired before a retry could succeed."""


class RetryStore(Store):
    """Delegating store that retries transient faults with backoff.

    ``retries`` is the number of *re*-attempts after the first try;
    ``backoff`` the base delay, doubled each attempt up to ``max_backoff``
    and stretched by up to ``jitter``× of itself (decorrelates a fleet of
    readers hammering one recovering server); ``deadline`` bounds the whole
    op: when the elapsed time plus the next backoff would cross it, the op
    is abandoned with :class:`StoreDeadlineError` instead of sleeping.  The
    deadline governs the retry budget — it cannot interrupt an in-flight
    call, so pair it with the backend's own socket ``timeout`` for hard
    I/O bounds.
    """

    def __init__(self, inner: Store, retries: int = 2,
                 backoff: float = 0.05, max_backoff: float = 2.0,
                 jitter: float = 0.5, deadline: float | None = None,
                 sleep=time.sleep, rng=None):
        super().__init__()
        self.inner = inner
        self.retries = max(0, int(retries))
        self.backoff = float(backoff)
        self.max_backoff = float(max_backoff)
        self.jitter = float(jitter)
        self.deadline = deadline if deadline is None else float(deadline)
        self._sleep = sleep
        self._rng = rng if rng is not None else random.random
        self._label = inner.scheme or type(inner).__name__.lower()

    @property
    def remote(self):  # the wrapper is as remote as what it wraps
        return self.inner.remote

    def _call(self, op, fn, *args):
        t0 = time.monotonic()
        for attempt in range(self.retries + 1):
            try:
                return fn(*args)
            except (StoreKeyError, StoreRangeError):
                raise  # permanent: retrying cannot change the answer
            except OSError as e:
                if attempt >= self.retries:
                    raise
                delay = min(self.max_backoff,
                            self.backoff * (2.0 ** attempt))
                if self.jitter:
                    delay *= 1.0 + self.jitter * self._rng()
                if (self.deadline is not None
                        and time.monotonic() - t0 + delay >= self.deadline):
                    _DEADLINE.inc(backend=self._label, op=op)
                    obs.event("store.deadline", level="error",
                              backend=self._label, op=op,
                              attempts=attempt + 1, deadline_s=self.deadline,
                              error=f"{type(e).__name__}: {e}")
                    raise StoreDeadlineError(
                        f"{op} on {self.inner.url}: {self.deadline}s deadline"
                        f" exceeded after {attempt + 1} attempt(s): {e}"
                    ) from e
                _RETRIES.inc(backend=self._label, op=op)
                obs.event("store.retry", level="warn", backend=self._label,
                          op=op, attempt=attempt + 1,
                          delay_ms=round(delay * 1e3, 3),
                          error=f"{type(e).__name__}: {e}")
                self._sleep(delay)
        raise AssertionError("unreachable")

    # -- wrapped ops -------------------------------------------------------

    def get(self, key, byte_range=None):
        return self._call("get", self.inner.get, key, byte_range)

    def get_many(self, requests):
        return self._call("get_many", self.inner.get_many, list(requests))

    def put(self, key, data):
        return self._call("put", self.inner.put, key, data)

    def put_atomic(self, key, data):
        return self._call("put_atomic", self.inner.put_atomic, key, data)

    def list(self, prefix=""):
        return self._call("list", self.inner.list, prefix)

    def delete(self, key):
        return self._call("delete", self.inner.delete, key)

    def exists(self, key):
        return self._call("exists", self.inner.exists, key)

    # open_write uses the base buffered sink: the commit goes through
    # self.put and is therefore covered by the retry policy.  (Streaming
    # through the inner sink would leave the one op most likely to hit a
    # network fault — the member upload — outside the policy.)

    def lock(self, name):
        return self.inner.lock(name)

    def stats(self) -> dict:
        """Inner store's counters, if it keeps any."""
        inner_stats = getattr(self.inner, "stats", None)
        return inner_stats() if callable(inner_stats) else {}

    @property
    def url(self) -> str:
        return self.inner.url

    def close(self) -> None:
        inner_close = getattr(self.inner, "close", None)
        if callable(inner_close):
            inner_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
