"""repro.store coverage: append/reopen/region round-trips with decode
counters, threaded-vs-serial writer determinism, concurrent readers, and
manifest corruption errors — plus the dtype-tag and parallel-iter_chunks
satellites where they meet the store."""
import concurrent.futures
import json
import os

import numpy as np
import pytest

from repro.core import CompressionSpec, Pipeline, container
from repro.core import blocks as blk
from repro.ckpt import FieldSnapshotter
from repro.serve import FieldRegionServer
from repro.store import CZDataset, ManifestError, ShardWriter

from test_pipeline_api import smooth_field

N = 64
BS = 16
# 16 KiB buffers -> 1 block per chunk at 16^3 float32: many chunks per member
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 14)

FIELDS = {"p": smooth_field(N, seed=1), "rho": smooth_field(N, seed=2)}


def _stepped(k):
    return {q: f + np.float32(k) for q, f in FIELDS.items()}


# ---------------------------------------------------------------------------
# Acceptance: append >= 3 timesteps of >= 2 quantities, reopen, bit-exact
# region read that decodes strictly fewer chunks than a full-field read
# ---------------------------------------------------------------------------

def test_append_reopen_region_read_bit_exact(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC, workers=2) as ds:
        for k in range(3):
            assert ds.append(_stepped(k), time=9.4 + k) == k
        assert ds.version == 3

    ds = CZDataset(root)  # reopen read-only
    assert ds.quantities == ["p", "rho"]
    assert ds.timesteps("p") == [0, 1, 2]
    assert ds.shape("rho") == (N, N, N)

    lo, hi = (5, 17, 36), (27, 30, 60)  # interior, block-unaligned
    box = ds.read_box("rho", 2, lo, hi)
    ref = FIELDS["rho"] + np.float32(2)
    np.testing.assert_array_equal(
        box, ref[lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]])

    r = ds.reader("rho", 2)
    assert 0 < r.chunks_decoded < r.nchunks, \
        "region read must decode strictly fewer chunks than a full read"
    decoded_before = r.chunks_decoded
    np.testing.assert_array_equal(ds.read_field("rho", 2), ref)
    assert r.chunks_decoded > decoded_before  # full read inflated the rest
    assert ds.stats()["chunks_decoded"] == r.chunks_decoded

    with pytest.raises(IOError, match="read-only"):
        ds.append(_stepped(9))
    ds.close()


def test_append_mode_reopens_and_continues(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append(_stepped(0))
    with CZDataset(root, "a") as ds:  # existing dataset: committed spec wins
        assert ds.spec == SPEC
        assert ds.append(_stepped(1)) == 1
        assert ds.timesteps("p") == [0, 1]
    # a reader observing the appender picks up commits via refresh()
    with CZDataset(root) as rd:
        with CZDataset(root, "a") as wr:
            wr.append(_stepped(2))
        assert rd.timesteps("p") == [0, 1]
        rd.refresh()
        assert rd.timesteps("p") == [0, 1, 2]


def test_append_rejects_bad_input(tmp_path):
    with CZDataset(os.path.join(tmp_path, "ds"), "a", spec=SPEC) as ds:
        ds.append(_stepped(0))
        with pytest.raises(ValueError, match="shape"):
            ds.append({"p": np.zeros((BS, BS, BS), np.float32)})
        with pytest.raises(ValueError, match="invalid quantity"):
            ds.append({"../evil": FIELDS["p"]})
        with pytest.raises(ValueError, match="at least one"):
            ds.append({})
        with pytest.raises(KeyError, match="no timestep"):
            ds.read_box("p", 7, (0, 0, 0), (4, 4, 4))
        with pytest.raises(KeyError, match="not in dataset"):
            ds.read_field("vorticity", 0)


# ---------------------------------------------------------------------------
# Threaded vs serial writer determinism
# ---------------------------------------------------------------------------

def test_threaded_and_serial_writers_byte_identical(tmp_path):
    spec = CompressionSpec(scheme="wavelet", block_size=BS,
                           buffer_bytes=1 << 14)
    members = {}
    for workers in (1, 4):
        root = os.path.join(tmp_path, f"w{workers}")
        with CZDataset(root, "a", spec=spec, workers=workers) as ds:
            for k in range(2):
                ds.append(_stepped(k), time=float(k))
        for q in ("p", "rho"):
            for k in range(2):
                rel = os.path.join(q, f"t{k:06d}.cz")
                with open(os.path.join(root, rel), "rb") as f:
                    members.setdefault(rel, []).append(f.read())
    for rel, (serial, threaded) in members.items():
        assert serial == threaded, f"{rel} differs between workers=1 and 4"


def test_pipeline_iter_chunks_parallel_byte_identical():
    blocks = np.asarray(blk.blockify(FIELDS["p"], BS))
    spec = CompressionSpec(scheme="wavelet", block_size=BS,
                           buffer_bytes=1 << 14)
    serial = list(Pipeline(spec).iter_chunks(blocks))
    threaded = list(Pipeline(spec, workers=4).iter_chunks(blocks))
    assert len(serial) > 4
    assert serial == threaded


def test_shard_writer_standalone_member_is_plain_cz2(tmp_path):
    path = os.path.join(tmp_path, "m.cz")
    with ShardWriter(SPEC, workers=2) as w:
        w.write(path, FIELDS["p"], extra_header={"quantity": "p"})
    np.testing.assert_array_equal(container.read_field(path), FIELDS["p"])
    with container.FieldReader(path) as r:
        assert r.header["quantity"] == "p"


# ---------------------------------------------------------------------------
# Concurrent readers on one dataset
# ---------------------------------------------------------------------------

def test_concurrent_readers_share_one_dataset(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC, workers=2) as ds:
        for k in range(3):
            ds.append(_stepped(k))

    ds = CZDataset(root, cache_chunks=4)
    rng = np.random.default_rng(0)
    jobs = [(q, int(t), tuple(int(v) for v in lo))
            for q in FIELDS for t in range(3)
            for lo in rng.integers(0, N - BS, (4, 3))]

    def probe(q, t, lo):
        hi = tuple(v + BS for v in lo)
        box = ds.read_box(q, t, lo, hi)
        ref = (FIELDS[q] + np.float32(t))[tuple(slice(a, b)
                                                for a, b in zip(lo, hi))]
        return bool(np.array_equal(box, ref))

    with concurrent.futures.ThreadPoolExecutor(8) as pool:
        assert all(pool.map(lambda j: probe(*j), jobs))
    assert ds.stats()["chunks_decoded"] > 0
    ds.close()


def test_field_region_server_stats(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append(_stepped(0), time=0.0)
    srv = FieldRegionServer(root)
    for _ in range(3):
        box = srv.query("p", 0, (0, 0, 0), (BS, BS, BS))
    np.testing.assert_array_equal(box, FIELDS["p"][:BS, :BS, :BS])
    s = srv.stats()
    assert s["queries"] == 3
    assert s["chunks_decoded"] == 1   # repeats never touched the chunk tier:
    assert s["region_cache_hits"] == 2  # ...the decoded-region LRU answered
    assert s["bytes_served"] == 3 * BS**3 * 4
    assert s["mean_latency_ms"] > 0
    srv.close()


def test_dataset_stats_expose_hit_and_miss_counters(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append(_stepped(0))
    with CZDataset(root) as ds:
        assert ds.stats()["cache_hit_rate"] is None  # no traffic yet
        ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))   # 1 chunk: miss
        ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))   # same chunk: hit
        s = ds.stats()
        assert s["cache_misses"] == s["chunks_decoded"] == 1
        assert s["cache_hits"] == 1
        assert s["cache_hit_rate"] == 0.5
        # retiring a reader (close) must not lose counters
        ds.close()
        assert ds.stats() == {**s, "open_readers": 0}


def test_concurrent_read_box_under_eviction_pressure(tmp_path):
    """N threads hammering overlapping regions with ``cache_chunks=1`` (every
    fetch may evict every other chunk) must return byte-identical arrays to
    serial reads — the correctness invariant the serve tier's coalescing
    scheduler builds on."""
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC, workers=2) as ds:
        for k in range(2):
            ds.append(_stepped(k))

    # overlapping, block-unaligned boxes clustered around the field centre so
    # every thread contends for the same few chunks
    rng = np.random.default_rng(3)
    jobs = []
    for q in FIELDS:
        for t in range(2):
            for lo in rng.integers(N // 4, N // 2, (6, 3)):
                lo = tuple(int(v) for v in lo)
                hi = tuple(v + BS + 3 for v in lo)
                jobs.append((q, t, lo, hi))
    refs = {(q, t, lo, hi): (FIELDS[q] + np.float32(t))[
        tuple(slice(a, b) for a, b in zip(lo, hi))].tobytes()
        for q, t, lo, hi in jobs}

    with CZDataset(root, cache_chunks=1) as ds:
        def probe(job):
            q, t, lo, hi = job
            return ds.read_box(q, t, lo, hi).tobytes() == refs[job]

        with concurrent.futures.ThreadPoolExecutor(8) as pool:
            results = list(pool.map(probe, jobs * 4))
        assert all(results)
        s = ds.stats()
        assert s["cache_misses"] >= s["open_readers"]  # pressure was real


# ---------------------------------------------------------------------------
# Manifest corruption raises a clear error
# ---------------------------------------------------------------------------

def test_manifest_corruption_raises_clear_error(tmp_path):
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append(_stepped(0))
    mpath = os.path.join(root, "manifest.json")

    with open(mpath, "w") as f:
        f.write('{"truncated": ')
    with pytest.raises(ManifestError, match="corrupt manifest"):
        CZDataset(root)
    with pytest.raises(ManifestError):
        CZDataset(root, "a")  # corrupt manifest must never be overwritten

    with open(mpath, "w") as f:
        json.dump({"not": "a manifest"}, f)
    with pytest.raises(ManifestError, match="bad magic"):
        CZDataset(root)

    os.remove(mpath)
    with pytest.raises(ManifestError, match="not a CZDataset"):
        CZDataset(root)  # read-only + missing manifest is an error, not create


# ---------------------------------------------------------------------------
# Dataset-backed snapshots (ckpt integration)
# ---------------------------------------------------------------------------

def test_field_snapshotter_roundtrip(tmp_path):
    d = os.path.join(tmp_path, "snaps")
    snap = FieldSnapshotter(d, every=5,
                            spec=CompressionSpec(scheme="fpzipx",
                                                 block_size=BS))
    for step in range(11):
        snap.maybe_snapshot(_stepped(step), step)
    snap.close()

    snap2 = FieldSnapshotter(d, every=5)
    fields, step = snap2.restore()
    assert step == 10
    for q in FIELDS:  # fpzipx at precision=32 is lossless -> bit-exact
        np.testing.assert_array_equal(fields[q], FIELDS[q] + np.float32(10))
    snap2.close()


# ---------------------------------------------------------------------------
# Dtype tags through the store (satellite)
# ---------------------------------------------------------------------------

def test_evicted_reader_still_serves(tmp_path):
    """A FieldReader evicted by the dataset's LRU while a thread still holds
    it keeps serving: store-backed readers hold no OS file handle, so
    eviction just folds counters and drops the dataset's reference.  Only an
    *explicit* close() retires a reader — and that close is terminal."""
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        for k in range(3):
            ds.append(_stepped(k))
    ds = CZDataset(root, cache_readers=1)
    held = ds.reader("p", 0)
    ds.reader("p", 1)  # evicts `held` from the dataset's LRU
    assert not held.closed
    box = held.read_box((0, 0, 0), (BS, BS, BS))
    np.testing.assert_array_equal(box, FIELDS["p"][:BS, :BS, :BS])
    assert held.chunks_decoded == 1  # served straight through the store
    ds.close()  # closes the dataset's live readers...
    assert held.closed is False  # ...but not the evicted one it let go of
    held.close()
    assert held.closed
    held.close()  # idempotent
    with pytest.raises(ValueError, match="closed"):
        held.read_box((0, 0, 0), (BS, BS, BS))


def test_append_dtype_unsupported_by_scheme_coerces(tmp_path):
    """fpzipx is float32-only: a float64 append must coerce (the documented
    fallback, surfaced as a DtypeCoercionWarning), not abort mid-append —
    FieldSnapshotter's default hits this."""
    from repro.store import DtypeCoercionWarning

    root = os.path.join(tmp_path, "ds")
    f64 = FIELDS["p"].astype(np.float64)
    with CZDataset(root, "a",
                   spec=CompressionSpec(scheme="fpzipx", block_size=BS)) as ds:
        with pytest.warns(DtypeCoercionWarning):
            ds.append({"p": f64})
    with CZDataset(root) as ds:
        assert ds.dtype("p") == np.float32
        np.testing.assert_array_equal(ds.read_field("p", 0),
                                      f64.astype(np.float32))


@pytest.mark.parametrize("dtype", ["float64", "float16"])
def test_store_auto_dtype_tags_round_trip(tmp_path, dtype):
    root = os.path.join(tmp_path, "ds")
    f = FIELDS["p"].astype(dtype)
    with CZDataset(root, "a", spec=SPEC) as ds:  # spec says float32...
        ds.append({"p": f})
    with CZDataset(root) as ds:  # ...but the member is tagged per-field
        assert ds.dtype("p") == np.dtype(dtype)
        out = ds.read_field("p", 0)
        assert out.dtype == np.dtype(dtype)
        np.testing.assert_array_equal(out, f)
