"""Codec pipeline tests: roundtrips, error bounds, CR sanity, container IO."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip cleanly on a bare interpreter
    from _hypothesis_compat import given, settings, st

from repro.core import (
    CompressionSpec,
    analyze_field,
    compress_field,
    decompress_field,
)
from repro.core import container, fpzipx, szx, zfpx
from repro.core import shuffle as shuf
from repro.core import threshold as th


def smooth_field(n=64, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    g = np.mgrid[0:n, 0:n, 0:n].astype(np.float32)
    f = np.full((n, n, n), 100.0, np.float32)
    for _ in range(8):
        c = rng.uniform(8, n - 8, 3)
        r = rng.uniform(3, 7)
        d = np.sqrt(((g - c[:, None, None, None]) ** 2).sum(0))
        f += -60.0 / (1 + np.exp((d - r) * 2.0))
    if noise:
        f += rng.standard_normal((n, n, n)).astype(np.float32) * noise
    return f


FIELD = smooth_field()


def _ulp(x):
    """One fp32 ulp at the field's max magnitude (irreducible storage error)."""
    return float(np.spacing(np.float32(np.max(np.abs(x)))))


@pytest.mark.parametrize(
    "spec",
    [
        CompressionSpec(scheme="wavelet", wavelet=w, eps=1e-3)
        for w in ("w4i", "w4l", "w3ai")
    ]
    + [
        CompressionSpec(scheme="zfpx", eps=1e-3),
        CompressionSpec(scheme="szx", eps=1e-3),
        CompressionSpec(scheme="fpzipx", precision=32),
        CompressionSpec(scheme="fpzipx", precision=16),
        CompressionSpec(scheme="raw"),
        CompressionSpec(scheme="wavelet", shuffle="bit"),
        CompressionSpec(scheme="wavelet", shuffle="none", stage2="lzma"),
        CompressionSpec(scheme="wavelet", zero_bits=8),
        CompressionSpec(scheme="wavelet", stage2="bz2", block_size=16),
        CompressionSpec(scheme="szx", eps=1e-2, block_size=8),
    ],
)
def test_roundtrip_all_schemes(spec):
    comp = compress_field(FIELD, spec)
    dec = decompress_field(comp)
    assert dec.shape == FIELD.shape
    assert np.isfinite(dec).all()
    if spec.scheme == "raw" or (spec.scheme == "fpzipx" and spec.precision == 32):
        np.testing.assert_array_equal(dec, FIELD)
    elif spec.scheme == "szx":
        assert np.max(np.abs(dec - FIELD)) <= spec.eps * (1 + 1e-4) + _ulp(FIELD)
    else:
        assert np.max(np.abs(dec - FIELD)) < 1.0  # lossy but bounded


def test_lossless_fpzipx_bit_exact_weird_values():
    x = np.array(
        [0.0, -0.0, 1.5, -1.5, 1e-38, -1e38, np.pi, 2**-126, 3.4e38],
        np.float32,
    )
    field = np.tile(x, 8 * 8 * 8 // 8 * 8)[: 8**3].reshape(8, 8, 8)
    spec = CompressionSpec(scheme="fpzipx", precision=32, block_size=8)
    dec = decompress_field(compress_field(field, spec))
    np.testing.assert_array_equal(dec.view(np.uint32), field.view(np.uint32))


def test_szx_error_bound_property():
    for eps in (1e-4, 1e-3, 1e-2, 1e-1):
        spec = CompressionSpec(scheme="szx", eps=eps)
        r = analyze_field(FIELD, spec)
        assert r["max_err"] <= eps * (1 + 1e-4) + _ulp(FIELD), (eps, r["max_err"])


def test_cr_monotone_in_eps():
    crs = []
    for eps in (1e-4, 1e-3, 1e-2):
        spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=eps)
        crs.append(analyze_field(FIELD, spec)["cr"])
    assert crs[0] < crs[1] < crs[2]


def test_shuffle_improves_cr_same_psnr():
    a = analyze_field(FIELD, CompressionSpec(scheme="wavelet", shuffle="none"))
    b = analyze_field(FIELD, CompressionSpec(scheme="wavelet", shuffle="byte"))
    assert b["cr"] > a["cr"] * 0.98  # shuffling should not hurt
    assert abs(a["psnr"] - b["psnr"]) < 1e-9  # reversible: identical distortion


def test_byte_shuffle_roundtrip():
    rng = np.random.default_rng(0)
    buf = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    for itemsize in (2, 4, 8):
        s = shuf.byte_shuffle(buf, itemsize)
        assert shuf.byte_unshuffle(s, itemsize) == buf
        b = shuf.bit_shuffle(buf, itemsize)
        assert shuf.bit_unshuffle(b, itemsize) == buf


def test_zero_low_bits():
    x = np.array([1.23456789, -9.87654e-3], np.float32)
    z = shuf.zero_low_bits_np(x, 8)
    assert np.all(z.view(np.uint32) & 0xFF == 0)
    assert np.max(np.abs(z - x) / np.abs(x)) < 2**-15


def test_zfp_lift_near_lossless():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.integers(-(2**27), 2**27, (64, 4, 4, 4)), jnp.int32)
    r = zfpx.inv_lift_cell(zfpx.fwd_lift_cell(q))
    assert int(jnp.max(jnp.abs(r - q))) <= 32  # bounded transform error


def test_zfpx_zero_block():
    blocks = jnp.zeros((2, 32, 32, 32), jnp.float32)
    emax, q = zfpx.encode(blocks, eps=1e-3)
    out = zfpx.decode(emax, q, eps=1e-3, n=32)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_szx_lorenzo_exact_int_roundtrip():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.integers(-(2**20), 2**20, (4, 16, 16, 16)), jnp.int32)
    r = szx.lorenzo_inv(szx.lorenzo_fwd(q))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(q))


def test_fpzipx_ordered_map_monotone():
    vals = np.array([-3e8, -1.0, -1e-20, -0.0, 0.0, 1e-20, 1.0, 3e8], np.float32)
    u = np.asarray(fpzipx.float_to_ordered(jnp.asarray(vals)))
    assert (np.diff(u.astype(np.int64)) >= 0).all()
    back = np.asarray(fpzipx.ordered_to_float(jnp.asarray(u)))
    np.testing.assert_array_equal(back[1:], vals[1:])  # -0.0 vs 0.0 aside


def test_topk_roundtrip():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((3, 16, 16, 16)), jnp.float32)
    from repro.core import wavelets as wv

    co = wv.forward3d(x, "w3ai")
    vals, idx, coarse = th.topk_details(co, k=128)
    cube = th.scatter_topk(vals, idx, coarse, 16)
    # kept coefficients match
    flat = np.asarray(co).reshape(3, -1)
    cube_flat = np.asarray(cube).reshape(3, -1)
    for b in range(3):
        np.testing.assert_allclose(
            cube_flat[b][np.asarray(idx)[b]], flat[b][np.asarray(idx)[b]], rtol=1e-6
        )


def test_container_roundtrip_and_block_reader(tmp_path):
    path = os.path.join(tmp_path, "p.cz")
    spec = CompressionSpec(scheme="wavelet", eps=1e-3, block_size=16, buffer_bytes=1 << 16)
    container.write_field(path, FIELD, spec)
    out = container.read_field(path)
    assert out.shape == FIELD.shape
    assert np.max(np.abs(out - FIELD)) < 1.0

    r = container.FieldReader(path, cache_chunks=2)
    blockA = r.read_block(0, 0, 0)
    assert blockA.shape == (16, 16, 16)
    np.testing.assert_allclose(blockA, out[:16, :16, :16], atol=1e-5)
    r.read_block(0, 0, 1)
    hits0 = r.cache_hits
    r.read_block(0, 0, 0)  # cached chunk
    assert r.cache_hits > hits0
    r.close()


def test_container_crc_detects_corruption(tmp_path):
    path = os.path.join(tmp_path, "p.cz")
    container.write_field(path, FIELD, CompressionSpec(scheme="raw"))
    with open(path, "r+b") as f:
        f.seek(-3, os.SEEK_END)
        f.write(b"\xff")
    with pytest.raises(IOError):
        container.read_field(path)


@settings(max_examples=15, deadline=None)
@given(
    scheme=st.sampled_from(["wavelet", "zfpx", "szx"]),
    eps=st.sampled_from([1e-4, 1e-3, 1e-2]),
    seed=st.integers(0, 100),
)
def test_property_bounded_error(scheme, eps, seed):
    f = smooth_field(n=32, seed=seed, noise=0.01)
    spec = CompressionSpec(scheme=scheme, eps=eps, block_size=16)
    r = analyze_field(f, spec)
    if scheme == "szx":
        assert r["max_err"] <= eps * (1 + 1e-4) + _ulp(f)
    else:
        assert r["max_err"] <= 300 * eps + 1e-5  # bounded amplification
    assert r["cr"] > 0.5
