"""The ``auto`` meta-scheme: per-chunk winner selection over the registry.

``auto`` owns no transform.  For each aggregation-buffer chunk it asks the
tuner (:mod:`repro.tune`) which registered scheme meets the spec's quality
target at the best measured ratio, delegates encode to that winner, and
makes the chunk **self-describing**: the serialized payload starts with a
compact prelude —

    u8 name_len | name (ascii) | f64 winner eps

— followed by the winner's own byte layout.  Decode parses the prelude and
dispatches through the registry, so mixed-scheme CZ2 containers read
through every existing path (``read_field``, ``FieldReader``, the serve
tier, ranked-parallel shared files) with no reader changes and no format
break beyond the ``CODEC_FORMAT`` bump that introduces the layout.

The target comes from ``spec.extra["target"]`` (``abs=V | rel=V |
psnr=DB``; defaults to ``abs=spec.eps``) and the optional decision cache
from ``spec.extra["tune_cache"]`` (see :mod:`repro.tune.policy`).  The
winning scheme name + eps are also surfaced per chunk in the container
footer (``chunk_schemes``, via :meth:`chunk_record`) so ``cz-compress
inspect`` and dataset manifests can show the scheme mix without decoding.

Decisions depend only on chunk content — never on rank, thread, or
history (with the cache off, its default) — so the cluster engine's
byte-identical rank-invariance guarantee holds for ``auto`` like any
fixed scheme.
"""
from __future__ import annotations

import dataclasses
import struct
import threading

import numpy as np

from . import Scheme, get_scheme, register_scheme

_LEN = struct.Struct("<B")
_EPS = struct.Struct("<d")


@register_scheme
class AutoScheme(Scheme):
    name = "auto"
    #: the meta-scheme itself is host-side control flow (winner stage 1
    #: still routes through spec.device); headers record "host"
    device_capable = False

    # tune imports stay lazy: this module is imported while the schemes
    # package is still initializing, and repro.tune imports the registry

    def validate(self, spec) -> None:
        from repro.tune import bound

        bound.target_from_spec(spec)  # parse errors -> ValueError
        cache = spec.extra.get("tune_cache", 0) if spec.extra else 0
        if not isinstance(cache, int) or isinstance(cache, bool) or cache < 0:
            raise ValueError(
                f"tune_cache must be a non-negative int, got {cache!r}")

    def params(self, spec) -> dict:
        from repro.tune import bound

        p = super().params(spec)
        p["target"] = str(bound.target_from_spec(spec))
        return p

    def error_bound(self, spec):
        from repro.tune import bound

        t = bound.target_from_spec(spec)
        # abs targets are a hard max-abs-error contract; rel/psnr bounds
        # are per-chunk (value-range dependent), declared best-effort here
        # and enforced per chunk by the trial runner
        return t.value if t.mode == "abs" else float("inf")

    def stage1(self, blocks_np, spec):
        # no batch transform: winners transform per chunk in serialize().
        # The dict also carries the per-chunk decision memo chunk_record()
        # reads — guarded, serialize may run on the pipeline's thread pool.
        return {"blocks": np.asarray(blocks_np, spec.np_dtype),
                "used": {}, "lock": threading.Lock()}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        from repro.tune import bound, policy

        chunk = s1["blocks"][lo:hi]
        target = bound.target_from_spec(spec)
        decision = policy.policy_for(spec).decide(chunk, spec, target)
        last_err = None
        for cand in decision.ranked:
            sch = get_scheme(cand.scheme)
            try:
                ws1 = sch.stage1(chunk, cand)
                payload = sch.serialize(ws1, 0, int(chunk.shape[0]), cand)
            except ValueError as e:
                # the sample passed but the full chunk did not (e.g. szx's
                # eps/magnitude guard): fall through to the runner-up —
                # the ranking always ends in a lossless scheme
                last_err = e
                continue
            with s1["lock"]:
                s1["used"][lo] = cand
            nb = cand.scheme.encode("ascii")
            return _LEN.pack(len(nb)) + nb + _EPS.pack(cand.eps) + payload
        raise ValueError(
            f"every ranked candidate failed on chunk [{lo}:{hi}): {last_err}")

    def deserialize(self, payload, nblk, spec):
        n = _LEN.unpack_from(payload, 0)[0]
        name = bytes(payload[1:1 + n]).decode("ascii")
        (eps,) = _EPS.unpack_from(payload, 1 + n)
        wspec = dataclasses.replace(spec, scheme=name, eps=eps, extra={})
        body = payload[1 + n + _EPS.size:]
        return get_scheme(name).deserialize(body, nblk, wspec)

    def chunk_record(self, s1, lo, hi, spec):
        with s1["lock"]:
            cand = s1["used"].get(lo)
        if cand is None:
            return None
        return {"scheme": cand.scheme, "eps": cand.eps}
