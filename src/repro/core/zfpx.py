"""``zfpx`` — TPU-adapted ZFP-style fixed-accuracy transform codec.

Keeps ZFP's actual structure (Lindstrom 2014):

1. partition each block into 4x4x4 cells;
2. block-floating-point: common max exponent ``emax`` per cell, fixed-point
   quantization ``q = round(x * 2^(SCALE_BITS - emax))`` into int32;
3. the (range-contracting, near-lossless) ZFP integer lifting transform along
   each axis;
4. total-sequency coefficient ordering;
5. bit-plane truncation derived from the absolute error tolerance ``eps``.

TPU adaptation (see DESIGN.md §3): ZFP's serial group-testing bit-plane coder
is replaced by vectorized plane truncation — every lane of a cell is processed
with identical control flow, so steps 1-5 run as pure jnp (and as the Pallas
kernel in ``repro.kernels``).  The host finalizes with byte-shuffle + ZLIB
(stage 2), which plays the role of ZFP's entropy back-end.

The truncation shift is a *deterministic function of (emax, eps)*, so the
decoder recovers it without side information; only ``emax`` (int8) and the
truncated coefficients travel.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "SCALE_BITS",
    "sequency_perm",
    "encode",
    "decode",
    "fwd_lift_cell",
    "inv_lift_cell",
]

SCALE_BITS = 28          # q = round(x * 2^(SCALE_BITS - emax)); |q| <= 2^28
_GUARD_BITS = 2          # transform error guard when converting eps -> planes
_ZERO_EMAX = -127        # emax marker for all-zero cells


@functools.lru_cache(maxsize=None)
def sequency_perm() -> np.ndarray:
    """Permutation ordering 4^3 coefficients by total sequency i+j+k."""
    idx = np.arange(64)
    i, j, k = idx // 16, (idx // 4) % 4, idx % 4
    order = np.lexsort((k, j, i, i + j + k))
    return order.astype(np.int32)


def _lift4(x, y, z, w):
    """ZFP forward lifting of a 4-vector (int32, range-contracting)."""
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    return x, y, z, w


def _unlift4(x, y, z, w):
    y = y + (w >> 1); w = w - (y >> 1)
    y = y + w; w = w << 1; w = w - y
    z = z + x; x = x << 1; x = x - z
    y = y + z; z = z << 1; z = z - y
    w = w + x; x = x << 1; x = x - w
    return x, y, z, w


def _apply_axis(cells, axis, fn):
    c = jnp.moveaxis(cells, axis, -1)
    x, y, z, w = c[..., 0], c[..., 1], c[..., 2], c[..., 3]
    out = jnp.stack(fn(x, y, z, w), axis=-1)
    return jnp.moveaxis(out, -1, axis)


def fwd_lift_cell(cells):
    """Forward 3D lifting over trailing (4,4,4) axes of an int32 array."""
    for ax in (-3, -2, -1):
        cells = _apply_axis(cells, ax, _lift4)
    return cells


def inv_lift_cell(cells):
    for ax in (-1, -2, -3):
        cells = _apply_axis(cells, ax, _unlift4)
    return cells


def _to_cells(blocks):
    b, n = blocks.shape[0], blocks.shape[-1]
    m = n // 4
    c = blocks.reshape(b, m, 4, m, 4, m, 4)
    c = jnp.transpose(c, (0, 1, 3, 5, 2, 4, 6))
    return c.reshape(b, m * m * m, 4, 4, 4)


def _from_cells(cells, n):
    b = cells.shape[0]
    m = n // 4
    c = cells.reshape(b, m, m, m, 4, 4, 4)
    c = jnp.transpose(c, (0, 1, 4, 2, 5, 3, 6))
    return c.reshape(b, n, n, n)


def _drop_bits(emax, eps: float):
    """Truncation shift per cell: deterministic in (emax, eps)."""
    # grid unit is 2^(emax - SCALE_BITS); dropping p planes errs <= ~2^p units.
    log_eps = int(np.floor(np.log2(eps))) if eps > 0 else -126
    p = log_eps - (emax - SCALE_BITS) - _GUARD_BITS
    return jnp.clip(p, 0, 31)


@functools.partial(jax.jit, static_argnames=("eps",))
def encode(blocks, eps: float = 1e-3):
    """blocks (B, n, n, n) float32 -> (emax (B, nc) int32, q (B, nc, 64) int32)."""
    cells = _to_cells(jnp.asarray(blocks, jnp.float32))     # (B, nc, 4,4,4)
    amax = jnp.max(jnp.abs(cells), axis=(-3, -2, -1))       # (B, nc)
    _, e = jnp.frexp(amax)                                   # amax = m * 2^e, m in [0.5,1)
    emax = jnp.where(amax > 0, e, _ZERO_EMAX).astype(jnp.int32)
    scale = jnp.exp2((SCALE_BITS - emax).astype(jnp.float32))
    q = jnp.round(cells * scale[..., None, None, None]).astype(jnp.int32)
    q = fwd_lift_cell(q)
    q = q.reshape(*q.shape[:-3], 64)[..., jnp.asarray(sequency_perm())]
    p = _drop_bits(emax, eps)[..., None]
    q = jnp.where(emax[..., None] == _ZERO_EMAX, 0, (q >> p) << p)
    return emax, q


@functools.partial(jax.jit, static_argnames=("eps", "n"))
def decode(emax, q, eps: float = 1e-3, n: int = 32):
    """Inverse of :func:`encode` -> (B, n, n, n) float32."""
    inv = jnp.argsort(jnp.asarray(sequency_perm()))
    cells = q[..., inv].reshape(*q.shape[:-1], 4, 4, 4)
    cells = inv_lift_cell(cells)
    scale = jnp.exp2((emax - SCALE_BITS).astype(jnp.float32))
    out = cells.astype(jnp.float32) * scale[..., None, None, None]
    out = jnp.where((emax == _ZERO_EMAX)[..., None, None, None], 0.0, out)
    return _from_cells(out, n)
