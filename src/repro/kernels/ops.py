"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: real Pallas lowering on TPU, interpret mode
elsewhere (this container is CPU-only; interpret mode executes the kernel
body faithfully for correctness validation).
"""
from __future__ import annotations

import functools

import jax

from .lorenzo import lorenzo_decode_pallas, lorenzo_encode_pallas
from .wavelet3d import wavelet3d_forward, wavelet3d_inverse
from .zfp_transform import zfpx_decode_pallas, zfpx_encode_pallas

__all__ = [
    "wavelet_forward",
    "wavelet_inverse",
    "zfpx_encode",
    "zfpx_decode",
    "lorenzo_encode",
    "lorenzo_decode",
]


def _interp(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("kind", "levels", "interpret"))
def wavelet_forward(blocks, kind: str = "w3ai", levels: int | None = None,
                    interpret: bool | None = None):
    return wavelet3d_forward(blocks, kind, levels, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("kind", "levels", "interpret"))
def wavelet_inverse(blocks, kind: str = "w3ai", levels: int | None = None,
                    interpret: bool | None = None):
    return wavelet3d_inverse(blocks, kind, levels, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def zfpx_encode(blocks, eps: float = 1e-3, interpret: bool | None = None):
    return zfpx_encode_pallas(blocks, eps, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "n", "interpret"))
def zfpx_decode(emax, q, eps: float = 1e-3, n: int = 32,
                interpret: bool | None = None):
    return zfpx_decode_pallas(emax, q, eps, n, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def lorenzo_encode(blocks, eps: float = 1e-3, interpret: bool | None = None):
    return lorenzo_encode_pallas(blocks, eps, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def lorenzo_decode(residuals, eps: float = 1e-3, interpret: bool | None = None):
    return lorenzo_decode_pallas(residuals, eps, interpret=_interp(interpret))
