"""CubismZ core: block-structured two-substage scientific data compression.

Module map:

* ``blocks``     — field <-> (nblk, bs, bs, bs) block layout (cluster layer)
* ``schemes/``   — open registry of substage-1 compressors (``Scheme`` ABC,
  ``@register_scheme``); one self-registering module per scheme:
  ``wavelet``, ``zfpx``, ``szx``, ``fpzipx``, ``raw``.  Third-party schemes
  plug in without touching core.
* ``pipeline``   — ``CompressionSpec`` + ``Pipeline``: validated spec bound
  to its scheme; ``compress``/``decompress`` and the streaming
  ``iter_chunks`` generator (one aggregation buffer at a time)
* ``lossless``   — substage-2 host coders (zlib/lzma/bz2/spdp)
* ``shuffle``    — byte/bit shuffle + low-bit zeroing of value streams
* ``container``  — CZ2 on-disk format (streaming writer, JSON footer,
  registry-driven ``FieldReader`` with LRU chunk cache; reads legacy CZ1)
* ``codec``      — seed-era thin wrappers (``compress_field`` & co.)
* ``wavelets`` / ``threshold`` / ``zfpx`` / ``szx`` / ``fpzipx`` — the device
  transform math the built-in schemes call into
* ``metrics``    — CR / MSE / PSNR
"""
from .pipeline import (  # noqa: F401
    CODEC_FORMAT,
    DEVICES,
    DTYPES,
    CompressedField,
    CompressionSpec,
    Pipeline,
)
from .schemes import SCHEMES, Scheme, get_scheme, register_scheme  # noqa: F401
from .codec import (  # noqa: F401
    analyze_field,
    compress_blocks,
    compress_field,
    decompress_blocks,
    decompress_field,
)
from .metrics import compression_ratio, mse, psnr  # noqa: F401
