"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import szx, wavelets, zfpx

__all__ = [
    "wavelet3d_forward_ref",
    "wavelet3d_inverse_ref",
    "zfpx_encode_ref",
    "zfpx_decode_ref",
    "lorenzo_encode_ref",
    "lorenzo_decode_ref",
]


def wavelet3d_forward_ref(blocks, kind="w3ai", levels=None):
    return wavelets.forward3d(jnp.asarray(blocks, jnp.float32), kind, levels)


def wavelet3d_inverse_ref(blocks, kind="w3ai", levels=None):
    return wavelets.inverse3d(jnp.asarray(blocks, jnp.float32), kind, levels)


def zfpx_encode_ref(blocks, eps=1e-3):
    return zfpx.encode(jnp.asarray(blocks, jnp.float32), eps=eps)


def zfpx_decode_ref(emax, q, eps=1e-3, n=32):
    return zfpx.decode(emax, q, eps=eps, n=n)


def lorenzo_encode_ref(blocks, eps=1e-3):
    return szx.encode(jnp.asarray(blocks, jnp.float32), eps=eps)


def lorenzo_decode_ref(residuals, eps=1e-3):
    return szx.decode(jnp.asarray(residuals, jnp.int32), eps=eps)
