"""Decoded-region LRU for the serving tier.

Sits *above* the store's per-member chunk LRU: a chunk-cache hit still pays
block gather + box assembly, a region-cache hit pays nothing — the array
that answered the last identical query is handed back as-is.  Budgeted in
bytes (decoded regions vary wildly in size, so an entry-count cap would be
meaningless), thread-safe, and entries are frozen read-only so a hit can be
shared across request threads without copies.
"""
from __future__ import annotations

import collections
import threading

import numpy as np

__all__ = ["RegionCache"]


class RegionCache:
    """Byte-budgeted LRU of decoded region arrays.

    Keys are whatever tuple the caller hashes a query down to (the serving
    tier uses ``(quantity, t, lo, hi)``).  Values are numpy arrays; they are
    marked non-writeable on insert, and :meth:`get` returns the shared
    read-only array — callers that need to mutate must copy.

    An array larger than the whole budget is never admitted (it would evict
    everything for a single entry); ``max_bytes <= 0`` disables caching
    entirely while keeping the counters alive.
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict[tuple, np.ndarray] = \
            collections.OrderedDict()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key) -> np.ndarray | None:
        with self._lock:
            arr = self._entries.get(key)
            if arr is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return arr

    def put(self, key, arr: np.ndarray) -> bool:
        """Admit one decoded region; returns whether it was cached.

        Admitted arrays are frozen read-only **in place** (when already
        contiguous) — the cache and its callers share one buffer."""
        if arr.nbytes > self.max_bytes:
            return False  # would evict everything for one entry
        arr = np.ascontiguousarray(arr)
        arr.flags.writeable = False
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes -= old.nbytes
            self._entries[key] = arr
            self.bytes += arr.nbytes
            while self.bytes > self.max_bytes:
                _, evicted = self._entries.popitem(last=False)
                self.bytes -= evicted.nbytes
                self.evictions += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.bytes = 0

    def stats(self) -> dict:
        with self._lock:
            n = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "bytes": self.bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / n if n else None,
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (f"RegionCache({s['entries']} entries, {s['bytes']}/"
                f"{s['max_bytes']}B, hits={s['hits']} misses={s['misses']})")
