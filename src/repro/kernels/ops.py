"""Jit'd public wrappers for the Pallas kernels, instrumented per call.

``interpret=None`` auto-selects: real Pallas lowering on TPU, interpret mode
elsewhere (this container is CPU-only; interpret mode executes the kernel
body faithfully for correctness validation).

Every wrapper is wrapped in device-tier observability: first call per
argument signature (shapes/dtypes + static values — the same key ``jax.jit``
compiles on) is a **compile**, later calls are steady-state **execute**, and
the two phases get separate span names (``kernel.compile`` /
``kernel.execute``) and separate ``cz_kernel_seconds`` series — a
compilation stall and a slow steady-state kernel are different problems and
must not share a histogram.

Timing is synchronized (``jax.block_until_ready``) only when someone is
looking: on first-call compiles (jit compilation is host-synchronous
anyway), while the process tracer is enabled, or inside a collecting
request context (the serve tier's tail sampling) — then async dispatch
can't flatter the numbers.  Otherwise the wrapper records dispatch time
only and returns the unforced value, preserving JAX's async-dispatch
pipelining on accelerator backends.  ``CZ_KERNEL_SYNC=1``/``0`` in the
environment (or assigning :data:`SYNC`) forces the choice either way.
"""
from __future__ import annotations

import functools
import os
import threading
import time

import jax

from repro import obs
from repro.obs import context as _context
from repro.obs import trace

from .lorenzo import lorenzo_decode_pallas, lorenzo_encode_pallas
from .wavelet3d import wavelet3d_forward, wavelet3d_inverse
from .zfp_transform import zfpx_decode_pallas, zfpx_encode_pallas

__all__ = [
    "wavelet_forward",
    "wavelet_inverse",
    "zfpx_encode",
    "zfpx_decode",
    "lorenzo_encode",
    "lorenzo_decode",
]

_COMPILES = obs.counter(
    "cz_kernel_compiles_total",
    "Kernel calls that hit jit compilation (first call per signature).",
    labelnames=("kernel", "device"))
_CALLS = obs.counter(
    "cz_kernel_calls_total", "Kernel wrapper calls.",
    labelnames=("kernel", "device"))
_SECONDS = obs.histogram(
    "cz_kernel_seconds",
    "Kernel wall time split by compile/execute phase (block_until_ready "
    "on compiles and while tracing/tail collection is active; async "
    "dispatch time otherwise).",
    buckets=obs.FAST_BUCKETS, labelnames=("kernel", "device", "phase"))

#: tri-state host-device sync override for kernel timing: ``True`` forces
#: ``block_until_ready`` on every call, ``False`` never blocks, ``None``
#: (default) blocks only when the timing is observable — first-call
#: compile, process tracer enabled, or a collecting request context.
#: Seeded from ``CZ_KERNEL_SYNC`` when set.
SYNC: bool | None = (None if "CZ_KERNEL_SYNC" not in os.environ
                     else os.environ["CZ_KERNEL_SYNC"].lower()
                     not in ("0", "false", ""))


def _sig(x):
    """One argument's contribution to the compile key — shape/dtype for
    arrays (tracing abstracts values away), the value itself for statics."""
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return ("arr", tuple(x.shape), str(x.dtype))
    return ("val", x)


def _instrument(name: str):
    """Wrap one jitted kernel with compile/execute phase detection, spans,
    and the ``cz_kernel_*`` metrics.

    Phase detection mirrors ``jax.jit``'s cache key (argument
    shapes/dtypes + static values) with a per-wrapper seen-set: the first
    call for a signature is ``compile``, the rest ``execute``.  An
    approximation — jit cache eviction can recompile a "seen" signature —
    but right for the question the metrics answer: how much wall time is
    warm-up vs steady state.
    """

    def deco(fn):
        seen: set = set()
        lock = threading.Lock()

        @functools.wraps(fn)
        def wrapper(*a, **k):
            key = (tuple(_sig(x) for x in a),
                   tuple(sorted((kk, _sig(v)) for kk, v in k.items())))
            with lock:
                first = key not in seen
                if first:
                    seen.add(key)
            device = jax.default_backend()
            phase = "compile" if first else "execute"
            sync = SYNC
            if sync is None:
                # block only when the timing is observable: compiles are
                # host-synchronous anyway, and an active tracer/collecting
                # request context needs honest span durations; steady-state
                # uninstrumented calls keep async dispatch pipelining
                ctx = _context.current()
                sync = (first or trace.tracing()
                        or (ctx is not None and ctx.collecting))
            t0 = time.perf_counter_ns()
            out = fn(*a, **k)
            if sync:
                out = jax.block_until_ready(out)
            t1 = time.perf_counter_ns()
            if first:
                _COMPILES.inc(kernel=name, device=device)
            _CALLS.inc(kernel=name, device=device)
            _SECONDS.observe((t1 - t0) / 1e9, kernel=name, device=device,
                             phase=phase)
            trace.record(f"kernel.{phase}", t0, t1, kernel=name,
                         device=device)
            return out

        return wrapper

    return deco


def _interp(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@_instrument("wavelet_forward")
@functools.partial(jax.jit, static_argnames=("kind", "levels", "interpret"))
def wavelet_forward(blocks, kind: str = "w3ai", levels: int | None = None,
                    interpret: bool | None = None):
    return wavelet3d_forward(blocks, kind, levels, interpret=_interp(interpret))


@_instrument("wavelet_inverse")
@functools.partial(jax.jit, static_argnames=("kind", "levels", "interpret"))
def wavelet_inverse(blocks, kind: str = "w3ai", levels: int | None = None,
                    interpret: bool | None = None):
    return wavelet3d_inverse(blocks, kind, levels, interpret=_interp(interpret))


@_instrument("zfpx_encode")
@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def zfpx_encode(blocks, eps: float = 1e-3, interpret: bool | None = None):
    return zfpx_encode_pallas(blocks, eps, interpret=_interp(interpret))


@_instrument("zfpx_decode")
@functools.partial(jax.jit, static_argnames=("eps", "n", "interpret"))
def zfpx_decode(emax, q, eps: float = 1e-3, n: int = 32,
                interpret: bool | None = None):
    return zfpx_decode_pallas(emax, q, eps, n, interpret=_interp(interpret))


@_instrument("lorenzo_encode")
@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def lorenzo_encode(blocks, eps: float = 1e-3, interpret: bool | None = None):
    return lorenzo_encode_pallas(blocks, eps, interpret=_interp(interpret))


@_instrument("lorenzo_decode")
@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def lorenzo_decode(residuals, eps: float = 1e-3, interpret: bool | None = None):
    return lorenzo_decode_pallas(residuals, eps, interpret=_interp(interpret))
