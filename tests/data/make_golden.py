"""Regenerate the golden container fixtures (run from the repo root):

    PYTHONPATH=src:tests python tests/data/make_golden.py

The committed fixtures pin the on-disk byte layouts *and* the decoded values
of both container generations.  ``test_golden.py`` asserts current code
decodes them byte-exact — a future ``CODEC_FORMAT`` bump (or a scheme layout
change without a ``decode_spec`` shim) fails loudly instead of silently
corrupting old archives.  Only regenerate when a change is *supposed* to
alter the fixtures, and say why in the commit.

``--only STEM[,STEM...]`` regenerates just the named fixtures (e.g.
``--only cz2_auto``) — adding a new fixture must not rewrite the committed
bytes of the existing ones.
"""
import argparse
import json
import os
import struct
import zlib

import numpy as np

from repro.core import CompressionSpec, container
from repro.core import blocks as blk
from repro.core import lossless
from repro.core.schemes import get_scheme

HERE = os.path.dirname(os.path.abspath(__file__))
N, BS = 16, 8


def golden_field() -> np.ndarray:
    # fixed analytic field + hashed index "noise": reproducible from source
    # forever, independent of any RNG implementation
    g = np.mgrid[0:N, 0:N, 0:N].astype(np.float32) / N
    f = 40.0 + 8.0 * np.sin(6 * g[0]) * np.cos(5 * g[1]) - 6.0 * g[2] ** 2
    idx = np.arange(N ** 3, dtype=np.uint32).reshape(N, N, N)
    h = (idx * np.uint32(2654435761)) >> np.uint32(24)   # 0..255 hash
    return (f + h.astype(np.float32) / 255.0 * 0.1).astype(np.float32)


def golden_auto_field() -> np.ndarray:
    """Heterogeneous field for the mixed-scheme (``auto``) fixture: regimes
    aligned with the 8^3 block raster — constant, smooth, and hash-noise
    chunks — so the tuner's per-chunk winners genuinely differ within one
    container.  Analytic + hashed-index noise: reproducible from source
    forever, independent of any RNG implementation."""
    g = np.mgrid[0:N, 0:N, 0:N].astype(np.float32) / N
    f = 2.0 + np.sin(5 * g[0]) * np.cos(4 * g[1]) + g[2]
    idx = np.arange(N ** 3, dtype=np.uint32).reshape(N, N, N)
    h = ((idx * np.uint32(2654435761)) >> np.uint32(20)).astype(np.float32)
    f[:8, :8, :] = 0.5                           # constant blocks
    f[8:, 8:, :] = h[8:, 8:, :] / 2048.0 - 1.0   # incompressible blocks
    return f.astype(np.float32)


def spec_for(scheme: str) -> CompressionSpec:
    return CompressionSpec(scheme=scheme, eps=1e-3, block_size=BS,
                           buffer_bytes=1 << 13).validate()


def auto_spec() -> CompressionSpec:
    # 2 KiB buffer -> one 8^3 float32 block per chunk: every block-aligned
    # regime of golden_auto_field gets its own tuning decision
    return CompressionSpec(scheme="auto", eps=1e-3, block_size=BS,
                           buffer_bytes=1 << 11).validate()


def write_cz1(path: str, field: np.ndarray, spec: CompressionSpec,
              legacy_szx: bool) -> None:
    """The seed-era CZ1 writer: header-first, v1 chunk byte layout (szx wrote
    its outlier stream unshuffled whatever the spec said)."""
    blocks = np.asarray(blk.blockify(field, spec.block_size))
    sch = get_scheme(spec.scheme)
    s1 = sch.stage1(blocks, spec)
    bpc = max(1, spec.buffer_bytes // (4 * spec.block_size ** 3))
    chunks, nblks = [], []
    for lo in range(0, blocks.shape[0], bpc):
        hi = min(lo + bpc, blocks.shape[0])
        if legacy_szx:
            r = s1["res"][lo:hi].reshape(-1)
            small = np.abs(r) <= 127
            payload = (np.uint32((~small).sum()).tobytes()
                       + np.where(small, r, -128).astype(np.int8).tobytes()
                       + r[~small].astype(np.int32).tobytes())
        else:
            payload = sch.serialize(s1, lo, hi, spec)
        chunks.append(lossless.encode(payload, spec.stage2))
        nblks.append(hi - lo)
    spec_json = spec.to_json()
    for post_seed_key in ("dtype", "device"):   # seed-era specs had neither
        spec_json.pop(post_seed_key, None)
    header = {
        "spec": spec_json,
        "nblocks": int(blocks.shape[0]),
        "chunk_nblocks": nblks,
        "chunk_sizes": [len(c) for c in chunks],
        "raw_bytes": int(blocks.size * 4),
        "field_shape": list(field.shape),
        "chunk_crc32": [zlib.crc32(c) & 0xFFFFFFFF for c in chunks],
    }
    hbytes = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(b"CZ1\0")
        f.write(struct.pack("<Q", len(hbytes)))
        f.write(hbytes)
        for c in chunks:
            f.write(c)


def main(only: str | None = None) -> None:
    todo = set(only.split(",")) if only else None

    def want(stem: str) -> bool:
        return todo is None or stem in todo

    field = golden_field()
    if want("golden_input"):
        np.save(os.path.join(HERE, "golden_input.npy"), field)

    for scheme, legacy_szx in (("raw", False), ("szx", True)):
        if not want(f"cz1_{scheme}"):
            continue
        path = os.path.join(HERE, f"cz1_{scheme}.cz")
        write_cz1(path, field, spec_for(scheme), legacy_szx)
        np.save(os.path.join(HERE, f"cz1_{scheme}.decoded.npy"),
                container.read_field(path))

    for scheme in ("wavelet", "lorenzo", "zfpx"):
        if not want(f"cz2_{scheme}"):
            continue
        path = os.path.join(HERE, f"cz2_{scheme}.cz")
        container.write_field(path, field, spec_for(scheme))
        np.save(os.path.join(HERE, f"cz2_{scheme}.decoded.npy"),
                container.read_field(path))

    if want("cz2_auto"):
        auto_field = golden_auto_field()
        np.save(os.path.join(HERE, "golden_auto_input.npy"), auto_field)
        path = os.path.join(HERE, "cz2_auto.cz")
        container.write_field(path, auto_field, auto_spec())
        mix = container.describe(path)["schemes"]
        assert len(mix) >= 2, f"auto fixture must mix schemes, got {mix}"
        np.save(os.path.join(HERE, "cz2_auto.decoded.npy"),
                container.read_field(path))

    for name in sorted(os.listdir(HERE)):
        if name.endswith((".cz", ".npy")):
            print(f"{name}: {os.path.getsize(os.path.join(HERE, name))} bytes")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated fixture stems to regenerate "
                         "(default: all)")
    main(ap.parse_args().only)
