"""Dataset manifest: one JSON file, committed atomically.

The manifest is the *only* mutable object in a CZDataset.  Member files are
immutable once written; a timestep exists iff the manifest references it, so
the commit protocol is write-members -> write ``manifest.json.tmp`` -> fsync
-> ``os.replace``.  A crash between member write and manifest commit leaves
orphaned member files but never a dataset that references missing or partial
data.

Rank sidecars (``manifest.rank{r}.json``) extend the same protocol to
multi-writer runs: each rank commits its own sidecar atomically, with no
contention on ``manifest.json``, and a coordinator later folds them into the
main manifest (``repro.cluster.multiwriter.merge_manifests``).  A sidecar
entry is *live* — :meth:`CZDataset.gc` must not collect its member — until
the merge commits it and deletes the sidecar.
"""
from __future__ import annotations

import json
import os
import re

__all__ = ["MANIFEST_NAME", "MANIFEST_FORMAT", "QUANTITY_RE", "ManifestError",
           "new_manifest", "read_manifest", "write_manifest",
           "RANK_MANIFEST_RE", "rank_manifest_name", "list_rank_manifests",
           "new_rank_manifest", "read_rank_manifest", "write_rank_manifest"]

MANIFEST_NAME = "manifest.json"
MANIFEST_FORMAT = 1

#: legal quantity names (also member subdirectory names); the lookahead
#: rejects all-dot names ('.', '..') that would escape the dataset root
QUANTITY_RE = re.compile(r"^(?!\.+$)[A-Za-z0-9_.\-]+$")

RANK_MANIFEST_RE = re.compile(r"^manifest\.rank(\d+)\.json$")


class ManifestError(IOError):
    """The dataset manifest is missing, unreadable, or structurally invalid."""


def new_manifest(spec_json: dict) -> dict:
    return {
        "magic": "CZDS",
        "format": MANIFEST_FORMAT,
        "version": 0,          # bumped on every commit
        "next_t": 0,           # next timestep index to assign
        "spec": spec_json,     # dataset-default CompressionSpec
        "quantities": {},      # name -> {shape, dtype, timesteps: [...]}
    }


def _check(m: dict, root: str) -> dict:
    if not isinstance(m, dict) or m.get("magic") != "CZDS":
        raise ManifestError(
            f"{os.path.join(root, MANIFEST_NAME)} is not a CZDataset manifest "
            "(bad magic)")
    if int(m.get("format", 0)) > MANIFEST_FORMAT:
        raise ManifestError(
            f"manifest format {m['format']} is newer than supported "
            f"({MANIFEST_FORMAT}) — upgrade repro to read {root}")
    for key in ("version", "next_t", "spec", "quantities"):
        if key not in m:
            raise ManifestError(f"manifest in {root} is missing {key!r}")
    for q, ent in m["quantities"].items():
        for key in ("shape", "dtype", "timesteps"):
            if key not in ent:
                raise ManifestError(
                    f"manifest entry for quantity {q!r} is missing {key!r}")
    return m


def _load_json(path: str, what: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ManifestError(f"corrupt {what} {path}: {e}") from None


def read_manifest(root: str) -> dict:
    path = os.path.join(root, MANIFEST_NAME)
    try:
        m = _load_json(path, "manifest")
    except FileNotFoundError:
        raise ManifestError(f"no {MANIFEST_NAME} in {root} — not a CZDataset "
                            "(or the first commit never completed)") from None
    return _check(m, root)


def _atomic_json(root: str, name: str, obj: dict) -> None:
    """tmp write + fsync + rename + directory fsync — the commit primitive
    shared by the main manifest and the per-rank sidecars."""
    path = os.path.join(root, name)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(root, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def write_manifest(root: str, manifest: dict) -> None:
    """Atomic commit: tmp write + fsync + rename over the old manifest, then
    fsync the directory so the rename itself is durable.  (Member files are
    fsynced by :class:`~repro.store.ShardWriter` before this is called.)"""
    _atomic_json(root, MANIFEST_NAME, manifest)


# -- per-rank sidecars -------------------------------------------------------

def rank_manifest_name(rank: int) -> str:
    return f"manifest.rank{int(rank)}.json"


def list_rank_manifests(root: str) -> list[int]:
    """Ranks with a committed sidecar in ``root``, ascending."""
    ranks = []
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return ranks
    for name in names:
        m = RANK_MANIFEST_RE.match(name)
        if m:
            ranks.append(int(m.group(1)))
    return sorted(ranks)


def new_rank_manifest(rank: int) -> dict:
    return {"magic": "CZRK", "format": MANIFEST_FORMAT,
            "rank": int(rank), "entries": []}


def read_rank_manifest(root: str, rank: int) -> dict:
    path = os.path.join(root, rank_manifest_name(rank))
    side = _load_json(path, "rank sidecar")  # FileNotFoundError propagates
    if not isinstance(side, dict) or side.get("magic") != "CZRK":
        raise ManifestError(f"{path} is not a rank sidecar (bad magic)")
    if int(side.get("rank", -1)) != int(rank):
        raise ManifestError(
            f"{path} claims rank {side.get('rank')}, expected {rank}")
    for e in side.get("entries", []):
        for key in ("quantity", "t", "time", "file", "bytes", "raw_bytes",
                    "shape", "dtype"):
            if key not in e:
                raise ManifestError(f"sidecar entry in {path} missing {key!r}")
    return side


def write_rank_manifest(root: str, side: dict) -> None:
    """Atomic sidecar commit — a rank's private, contention-free analogue of
    :func:`write_manifest`."""
    _atomic_json(root, rank_manifest_name(side["rank"]), side)
