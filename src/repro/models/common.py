"""Shared model building blocks: norms, RoPE, positions, param makers."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["Maker", "rmsnorm", "rope", "sinusoidal_positions", "gelu", "swiglu_act"]


class Maker:
    """Dual-mode parameter factory: ShapeDtypeStruct specs or real init.

    Guarantees identical pytree structure between the dry-run (specs, no
    allocation) and smoke tests / training (real arrays), because both paths
    run the same builder code.
    """

    def __init__(self, mode: str, key=None, dtype=jnp.float32):
        assert mode in ("spec", "init")
        self.mode = mode
        self.dtype = dtype
        self._key = key
        self._count = 0

    def __call__(self, shape, kind: str = "normal", scale: float | None = None):
        if self.mode == "spec":
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        self._count += 1
        key = jax.random.fold_in(self._key, self._count)
        if kind == "zeros":
            return jnp.zeros(shape, self.dtype)
        if kind == "ones":
            return jnp.ones(shape, self.dtype)
        if scale is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5
        return (jax.random.normal(key, shape) * scale).astype(self.dtype)


def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def _rope_freqs(hd: int, theta: float, positions):
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    cos, sin = _rope_freqs(hd, theta, positions)
    cos = cos[..., :, None, :]  # (..., S, 1, half)
    sin = sin[..., :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset: int = 0):
    pos = np.arange(offset, offset + seq, dtype=np.float32)
    half = d // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half, dtype=np.float32) / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1))


def sinusoidal_position_at(pos, d: int):
    """Traced single-position sinusoidal embedding (decode path)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = pos.astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu_act(gate, up):
    return jax.nn.silu(gate) * up
