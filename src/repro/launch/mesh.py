"""Production mesh construction (assignment-mandated shapes).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "make_production_mesh", "batch_axes", "model_axis"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported.

    ``jax.sharding.AxisType`` only exists from jax 0.5; on 0.4.x meshes are
    implicitly Auto, so the kwarg is simply omitted.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod leading axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that shard the batch (data parallel, incl. pods)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
