"""Flash attention (custom VJP) vs naive reference: values and gradients."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import flash_attention

def naive(q, k, v, causal):
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * hd**-0.5
    if causal:
        m = jnp.tril(jnp.ones((Sq, k.shape[1]), bool), k.shape[1] - Sq)
        s = jnp.where(m[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, hd)

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("impl", ["masked", "triangular"])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,qc,kc", [
    (2, 64, 4, 2, 16, 16, 32),
    (1, 128, 6, 3, 8, 32, 32),
    (2, 96, 4, 4, 16, 32, 48),
])
def test_flash_matches_naive(causal, impl, B, S, Hq, Hkv, hd, qc, kc):
    if impl == "triangular" and not causal:
        pytest.skip("triangular only for causal")
    rng = np.random.default_rng(B * S + Hq)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)

    got = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc, impl=impl)
    want = naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, causal=causal, q_chunk=qc,
                                kv_chunk=kc, impl=impl) ** 2).sum()

    def f_naive(q, k, v):
        return (naive(q, k, v, causal) ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-3, err_msg=name)
