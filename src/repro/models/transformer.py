"""Model composition: layer stacks, LM loss, prefill and decode paths.

One code path per *family* (dense/vlm, moe, ssm, hybrid, encdec), all built
from the same primitives and all scanned over layers (compile-time O(1) in
depth) with configurable remat.  Parameters are dicts of stacked leaves
(leading layer/period dim) so the layer scan carries them as xs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import ssm
from .attention import attention, decode_attention
from .common import (Maker, rmsnorm, sinusoidal_position_at,
                     sinusoidal_positions)
from .moe import dense_ffn, moe_ffn

__all__ = ["ModelSettings", "param_specs", "init_params", "lm_loss",
           "prefill", "decode_step", "cache_spec", "count_params"]


@dataclasses.dataclass(frozen=True)
class ModelSettings:
    attn_impl: str = "masked"       # masked | triangular (§Perf)
    q_chunk: int = 256
    kv_chunk: int = 512
    ce_chunk: int = 1024
    remat: str = "full"             # none | dots | full
    compute_dtype: Any = jnp.bfloat16
    rwkv_chunk: int = 0             # 0 = sequential scan; >0 = chunked WKV (§Perf)
    attn_shard: str = "auto"        # auto | replicate | heads (§Perf)
    # distribution-aware fields (filled in by the step builders from the mesh)
    act_shard: str = "seq"          # none | seq | hidden — layer-boundary
    batch_axes: tuple = ("data",)   # mesh axes sharding the batch dim
    n_model: int = 1                # "model" axis size (1 = no constraint)
    n_batch: int = 1


# ---------------------------------------------------------------------------
# Parameter construction (spec/init dual mode via Maker)
# ---------------------------------------------------------------------------

def _attn_leaves(mk, cfg, lead=()):
    Hq, Hkv, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    p = {
        "wq": mk((*lead, D, Hq * hd)),
        "wk": mk((*lead, D, Hkv * hd)),
        "wv": mk((*lead, D, Hkv * hd)),
        "wo": mk((*lead, Hq * hd, D)),
    }
    if cfg.qkv_bias:
        p |= {"bq": mk((*lead, Hq * hd), "zeros"),
              "bk": mk((*lead, Hkv * hd), "zeros"),
              "bv": mk((*lead, Hkv * hd), "zeros")}
    if cfg.qk_norm:
        p |= {"qnorm": mk((*lead, hd), "ones"), "knorm": mk((*lead, hd), "ones")}
    return p


def _mlp_leaves(mk, cfg, lead=()):
    D, F = cfg.d_model, cfg.d_ff
    p = {"w1": mk((*lead, D, F)), "w2": mk((*lead, F, D))}
    if cfg.act == "swiglu":
        p["w3"] = mk((*lead, D, F))
    return p


def _moe_leaves(mk, cfg, lead=()):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": mk((*lead, D, E)),
        "we1": mk((*lead, E, D, F)),
        "we2": mk((*lead, E, F, D)),
    }
    if cfg.act == "swiglu":
        p["we3"] = mk((*lead, E, D, F))
    if cfg.shared_expert:
        p |= {"ws1": mk((*lead, D, F)), "ws2": mk((*lead, F, D)),
              "ws3": mk((*lead, D, F))}
    return p


def _rwkv_leaves(mk, cfg, lead=()):
    D, H, hd, F = cfg.d_model, cfg.n_heads, cfg.hd, cfg.d_ff
    lr = 64  # low-rank width of the data-dependent decay
    tm = {
        **{f"mu_{n}": mk((*lead, D), "zeros") for n in "rkvwg"},
        "wr": mk((*lead, D, D)), "wk": mk((*lead, D, D)), "wv": mk((*lead, D, D)),
        "wg": mk((*lead, D, D)), "wo": mk((*lead, D, D)),
        "ww1": mk((*lead, D, lr)), "ww2": mk((*lead, lr, D)),
        "w0": mk((*lead, D), "zeros"),
        "u": mk((*lead, H, hd), "zeros"),
        "gn": mk((*lead, D), "ones"),
    }
    cm = {
        "mu_ck": mk((*lead, D), "zeros"), "mu_cr": mk((*lead, D), "zeros"),
        "ck": mk((*lead, D, F)), "cv": mk((*lead, F, D)), "cr": mk((*lead, D, D)),
    }
    return {"tm": tm, "cm": cm}


def _mamba_leaves(mk, cfg, lead=()):
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    ds, K = cfg.d_state, cfg.conv_kernel
    dtr = max(8, D // 16)
    return {
        "in_proj": mk((*lead, D, 2 * Di)),
        "conv_w": mk((*lead, Di, K), scale=0.5),
        "conv_b": mk((*lead, Di), "zeros"),
        "x_bc": mk((*lead, Di, 2 * ds)),
        "w_dt1": mk((*lead, Di, dtr)),
        "w_dt2": mk((*lead, dtr, Di)),
        "dt_bias": mk((*lead, Di), "zeros"),
        "A_log": mk((*lead, Di, ds), "zeros"),
        "Dskip": mk((*lead, Di), "ones"),
        "out_proj": mk((*lead, Di, D)),
    }


def _blocks_params(mk, cfg):
    L, D = cfg.n_layers, cfg.d_model
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"ln1": mk((L, D), "ones"), "ln2": mk((L, D), "ones"),
                "attn": _attn_leaves(mk, cfg, (L,)), "mlp": _mlp_leaves(mk, cfg, (L,))}
    if fam == "moe":
        return {"ln1": mk((L, D), "ones"), "ln2": mk((L, D), "ones"),
                "attn": _attn_leaves(mk, cfg, (L,)), "moe": _moe_leaves(mk, cfg, (L,))}
    if fam == "ssm":  # rwkv6
        return {"ln1": mk((L, D), "ones"), "ln2": mk((L, D), "ones"),
                **_rwkv_leaves(mk, cfg, (L,))}
    if fam == "hybrid":  # jamba periods
        P = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1                    # mamba layers per period
        nf = cfg.attn_period // cfg.moe_period      # moe ffns per period
        nd = cfg.attn_period - nf                   # dense ffns per period
        return {
            "mamba_ln": mk((P, nm, D), "ones"),
            "mamba": _mamba_leaves(mk, cfg, (P, nm)),
            "attn_ln": mk((P, D), "ones"),
            "attn": _attn_leaves(mk, cfg, (P,)),
            "mlp_ln": mk((P, nd, D), "ones"),
            "mlp": _mlp_leaves(mk, cfg, (P, nd)),
            "moe_ln": mk((P, nf, D), "ones"),
            "moe": _moe_leaves(mk, cfg, (P, nf)),
        }
    if fam == "encdec":
        Le = cfg.encoder_layers
        enc = {"ln1": mk((Le, D), "ones"), "ln2": mk((Le, D), "ones"),
               "attn": _attn_leaves(mk, cfg, (Le,)), "mlp": _mlp_leaves(mk, cfg, (Le,))}
        dec = {"ln1": mk((L, D), "ones"), "lnx": mk((L, D), "ones"),
               "ln2": mk((L, D), "ones"),
               "attn": _attn_leaves(mk, cfg, (L,)),
               "xattn": _attn_leaves(mk, cfg, (L,)),
               "mlp": _mlp_leaves(mk, cfg, (L,))}
        return {"enc": enc, "dec": dec, "enc_norm": mk((D,), "ones")}
    raise ValueError(f"unknown family {fam}")


def _top_params(mk, cfg):
    D, V = cfg.d_model, cfg.vocab
    p = {"embed": mk((V, D), scale=0.02), "blocks": _blocks_params(mk, cfg),
         "final_norm": mk((D,), "ones")}
    if not cfg.tie_embeddings:
        p["lm_head"] = mk((D, V), scale=D ** -0.5)
    return p


def param_specs(cfg, dtype=jnp.float32):
    return _top_params(Maker("spec", dtype=dtype), cfg)


def init_params(cfg, key, dtype=jnp.float32):
    return _top_params(Maker("init", key=key, dtype=dtype), cfg)


def count_params(cfg, active_only: bool = False) -> int:
    specs = param_specs(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        key = "/".join(getattr(k, "key", str(k)) for k in path)
        if active_only and "/we" in key:
            n = n * (cfg.top_k / cfg.n_experts)
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _zero_aux():
    return {"load_balance": jnp.float32(0), "router_z": jnp.float32(0),
            "drop_fraction": jnp.float32(0)}


def _ffn_or_moe(x, bp, cfg, moe_key="moe"):
    if moe_key in bp:
        return moe_ffn(x, bp[moe_key], cfg)
    return dense_ffn(x, bp["mlp"], cfg), _zero_aux()


def _decoder_body(x, bp, cfg, st: ModelSettings):
    """One dense/moe decoder layer; returns (x, aux)."""
    h = attention(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg,
                  causal=True, impl=st.attn_impl, q_chunk=st.q_chunk,
                  kv_chunk=st.kv_chunk, attn_shard=st.attn_shard,
                  batch_axes=st.batch_axes, n_model=st.n_model)
    x = x + h
    y, aux = _ffn_or_moe(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp, cfg)
    return x + y, aux


def _rwkv_body(x, bp, cfg, st):
    xin = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if st.rwkv_chunk > 0 and x.shape[1] % st.rwkv_chunk == 0:
        h, _ = ssm.rwkv6_timemix_chunked(xin, bp["tm"], cfg,
                                         chunk=st.rwkv_chunk)
    else:
        h, _ = ssm.rwkv6_timemix(xin, bp["tm"], cfg)
    x = x + h
    y, _ = ssm.rwkv6_channelmix(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["cm"], cfg)
    return x + y, _zero_aux()


def _hybrid_period_body(x, bp, cfg, st):
    """One jamba period: attn_period sublayers (mamba x (p-1), attn x 1),
    FFN alternating dense/MoE every moe_period.  Each mamba mixer is
    individually rematerialized: its inner time-scan saves per-step primals
    for the backward pass, and without per-mixer checkpointing all 7 layers'
    saved xs are live at once (~30 GiB at 4k x 16 batch)."""
    P_at = cfg.attn_period
    attn_pos = P_at // 2
    aux_acc = _zero_aux()
    mi = di = oi = 0

    def mamba_fn(xin, lp):
        return ssm.mamba_mix(xin, lp, cfg)[0]

    if st.remat != "none":
        mamba_fn = jax.checkpoint(
            mamba_fn, policy=jax.checkpoint_policies.nothing_saveable)
    for i in range(P_at):
        if i == attn_pos:
            h = attention(rmsnorm(x, bp["attn_ln"], cfg.norm_eps), bp["attn"], cfg,
                          causal=True, impl=st.attn_impl, q_chunk=st.q_chunk,
                          kv_chunk=st.kv_chunk, attn_shard=st.attn_shard,
                          batch_axes=st.batch_axes, n_model=st.n_model)
        else:
            lp = jax.tree.map(lambda a: a[mi], bp["mamba"])
            h = mamba_fn(rmsnorm(x, bp["mamba_ln"][mi], cfg.norm_eps), lp)
            mi += 1
        x = x + h
        if i % cfg.moe_period == 1:
            lp = jax.tree.map(lambda a: a[oi], bp["moe"])
            y, aux = moe_ffn(rmsnorm(x, bp["moe_ln"][oi], cfg.norm_eps), lp, cfg)
            aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc, aux)
            oi += 1
        else:
            lp = jax.tree.map(lambda a: a[di], bp["mlp"])
            y = dense_ffn(rmsnorm(x, bp["mlp_ln"][di], cfg.norm_eps), lp, cfg)
            di += 1
        x = x + y
    return x, aux_acc


def _enc_body(x, bp, cfg, st):
    h = attention(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg,
                  causal=False, impl="masked", q_chunk=st.q_chunk,
                  kv_chunk=st.kv_chunk, attn_shard=st.attn_shard,
                  batch_axes=st.batch_axes, n_model=st.n_model)
    x = x + h
    y = dense_ffn(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
    return x + y, _zero_aux()


def _cross_attention(x, enc_out, p, cfg, st):
    """Decoder cross-attention: q from x, k/v from encoder output."""
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, Hq, hd)
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(B, -1, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(B, -1, Hkv, hd)
    from .attention import flash_attention

    o = flash_attention(q, k, v, causal=False, q_chunk=st.q_chunk,
                        kv_chunk=st.kv_chunk)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])


def _dec_body(x, enc_out, bp, cfg, st):
    h = attention(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"], cfg,
                  causal=True, impl=st.attn_impl, q_chunk=st.q_chunk,
                  kv_chunk=st.kv_chunk, attn_shard=st.attn_shard,
                  batch_axes=st.batch_axes, n_model=st.n_model)
    x = x + h
    x = x + _cross_attention(rmsnorm(x, bp["lnx"], cfg.norm_eps), enc_out,
                             bp["xattn"], cfg, st)
    y = dense_ffn(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
    return x + y, _zero_aux()


def _act_constraint(x, st: ModelSettings):
    """Layer-boundary activation sharding (Megatron-style sequence sharding
    over "model" keeps the scan carry 1/n_model as large — see DESIGN.md §5)."""
    if st.act_shard == "none" or st.n_model <= 1 or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    spec = [None, None, None]
    if st.n_batch > 1 and B % st.n_batch == 0:
        spec[0] = st.batch_axes if len(st.batch_axes) > 1 else st.batch_axes[0]
    if st.act_shard == "seq" and S % st.n_model == 0 and S >= st.n_model:
        spec[1] = "model"
    elif st.act_shard == "hidden" and D % st.n_model == 0:
        spec[2] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _scan_blocks(x, blocks, body, st: ModelSettings):
    def f(carry, bp):
        carry = _act_constraint(carry, st)
        out, aux = body(carry, bp)
        return out, aux

    if st.remat == "full":
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    elif st.remat == "dots":
        f = jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    x, auxs = jax.lax.scan(f, x, blocks)
    return x, jax.tree.map(jnp.mean, auxs)


def forward_hidden(params, tokens, cfg, st: ModelSettings, enc_inputs=None):
    """tokens (B,S) int32 -> (hidden (B,S,D), aux).  For encdec, enc_inputs
    is the stubbed frame-embedding tensor (B, frames, D)."""
    cdt = st.compute_dtype
    x = params["embed"][tokens].astype(cdt)
    fam = cfg.family
    if fam == "encdec":
        e = enc_inputs.astype(cdt) + sinusoidal_positions(
            enc_inputs.shape[1], cfg.d_model
        ).astype(cdt)
        e, _ = _scan_blocks(
            e, _cast_blocks(params["blocks"]["enc"], cdt),
            lambda a, bp: _enc_body(a, bp, cfg, st), st)
        enc_out = rmsnorm(e, params["blocks"]["enc_norm"].astype(cdt), cfg.norm_eps)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cdt)
        h, aux = _scan_blocks(
            x, _cast_blocks(params["blocks"]["dec"], cdt),
            lambda a, bp: _dec_body(a, enc_out, bp, cfg, st), st)
    else:
        body = {
            "dense": _decoder_body, "vlm": _decoder_body, "moe": _decoder_body,
            "ssm": _rwkv_body, "hybrid": _hybrid_period_body,
        }[fam]
        h, aux = _scan_blocks(x, _cast_blocks(params["blocks"], cdt),
                              lambda a, bp: body(a, bp, cfg, st), st)
    return rmsnorm(h, params["final_norm"].astype(cdt), cfg.norm_eps), aux


def _cast_blocks(blocks, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), blocks)


def _chunked_ce(h, labels, head, chunk):
    B, S, D = h.shape
    nc = max(1, S // chunk)
    c = S // nc
    hc = h.reshape(B, nc, c, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, c).swapaxes(0, 1)

    def stepf(tot, inp):
        hh, ll = inp
        logits = jnp.einsum("bsd,dv->bsv", hh, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(stepf, jnp.float32(0), (hc, lc))
    return tot / (B * S)


def _head(params, cfg, dtype):
    if cfg.tie_embeddings:
        return params["embed"].astype(dtype).T
    return params["lm_head"].astype(dtype)


def lm_loss(params, batch, cfg, st: ModelSettings = ModelSettings()):
    """batch: dict(tokens (B,S), labels (B,S) [, frames (B,F,D)])."""
    h, aux = forward_hidden(params, batch["tokens"], cfg, st,
                            enc_inputs=batch.get("frames"))
    ce = _chunked_ce(h, batch["labels"], _head(params, cfg, st.compute_dtype),
                     st.ce_chunk)
    loss = ce + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch: int, seq: int, dtype=jnp.bfloat16, mode="spec"):
    """Decode-state pytree (specs or zeros) for one serve step."""
    mk = (lambda shape, dt=dtype: jax.ShapeDtypeStruct(tuple(shape), dt)) \
        if mode == "spec" else (lambda shape, dt=dtype: jnp.zeros(shape, dt))
    L, D = cfg.n_layers, cfg.d_model
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"k": mk((L, batch, seq, Hkv, hd)), "v": mk((L, batch, seq, Hkv, hd))}
    if fam == "ssm":
        return {"wkv": mk((L, batch, cfg.n_heads, hd, hd), jnp.float32),
                "x_tm": mk((L, batch, 1, D)), "x_cm": mk((L, batch, 1, D))}
    if fam == "hybrid":
        P = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1
        Di = cfg.ssm_expand * D
        return {
            "ssm": mk((P, nm, batch, Di, cfg.d_state), jnp.float32),
            "conv": mk((P, nm, batch, cfg.conv_kernel - 1, Di)),
            "k": mk((P, batch, seq, Hkv, hd)), "v": mk((P, batch, seq, Hkv, hd)),
        }
    if fam == "encdec":
        F = cfg.enc_frames
        return {"k": mk((L, batch, seq, Hkv, hd)), "v": mk((L, batch, seq, Hkv, hd)),
                "xk": mk((L, batch, F, Hkv, hd)), "xv": mk((L, batch, F, Hkv, hd))}
    raise ValueError(fam)


def _decode_layer_dense(x, bp, cfg, kv, pos):
    h, kv2 = decode_attention(rmsnorm(x, bp["ln1"], cfg.norm_eps), bp["attn"],
                              cfg, kv, pos)
    x = x + h
    y, _ = _ffn_or_moe(rmsnorm(x, bp["ln2"], cfg.norm_eps), bp, cfg)
    return x + y, kv2


def _decode_layer_rwkv(x, bp, cfg, state, xtm, xcm):
    h, (s2, xtm2) = ssm.rwkv6_decode(rmsnorm(x, bp["ln1"], cfg.norm_eps),
                                     bp["tm"], cfg, state, xtm)
    x = x + h
    xn = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    y, xcm2 = ssm.rwkv6_channelmix(xn, bp["cm"], cfg, xcm)
    return x + y, s2, xtm2, xcm2


def decode_step(params, cache, token, pos, cfg, st: ModelSettings = ModelSettings()):
    """token (B,1) int32, pos scalar int32 -> (logits (B,1,V), new cache)."""
    cdt = st.compute_dtype
    x = params["embed"][token].astype(cdt)
    fam = cfg.family
    blocks = _cast_blocks(params["blocks"] if fam != "encdec"
                          else params["blocks"]["dec"], cdt)
    if fam in ("dense", "vlm", "moe"):
        def f(carry, inp):
            bp, kv = inp
            x2, kv2 = _decode_layer_dense(carry, bp, cfg, kv, pos)
            return x2, kv2
        x, kv_new = jax.lax.scan(f, x, (blocks, {"k": cache["k"], "v": cache["v"]}))
        new_cache = kv_new
    elif fam == "ssm":
        x = x + 0  # positions implicit in recurrence
        def f(carry, inp):
            bp, (s, xtm, xcm) = inp
            x2, s2, xtm2, xcm2 = _decode_layer_rwkv(carry, bp, cfg, s, xtm, xcm)
            return x2, (s2, xtm2, xcm2)
        x, (s_new, xtm_new, xcm_new) = jax.lax.scan(
            f, x, (blocks, (cache["wkv"], cache["x_tm"], cache["x_cm"])))
        new_cache = {"wkv": s_new, "x_tm": xtm_new, "x_cm": xcm_new}
    elif fam == "hybrid":
        def f(carry, inp):
            bp, (sst, cst, k, v) = inp
            x2 = carry
            P_at = cfg.attn_period
            attn_pos = P_at // 2
            mi = di = oi = 0
            s_out, c_out = [], []
            kv2 = {"k": k, "v": v}
            for i in range(P_at):
                if i == attn_pos:
                    h, kv2 = decode_attention(
                        rmsnorm(x2, bp["attn_ln"], cfg.norm_eps), bp["attn"],
                        cfg, kv2, pos)
                else:
                    lp = jax.tree.map(lambda a: a[mi], bp["mamba"])
                    h, (s2, c2) = ssm.mamba_decode(
                        rmsnorm(x2, bp["mamba_ln"][mi], cfg.norm_eps), lp, cfg,
                        sst[mi], cst[mi])
                    s_out.append(s2)
                    c_out.append(c2)
                    mi += 1
                x2 = x2 + h
                if i % cfg.moe_period == 1:
                    lp = jax.tree.map(lambda a: a[oi], bp["moe"])
                    y, _ = moe_ffn(rmsnorm(x2, bp["moe_ln"][oi], cfg.norm_eps), lp, cfg)
                    oi += 1
                else:
                    lp = jax.tree.map(lambda a: a[di], bp["mlp"])
                    y = dense_ffn(rmsnorm(x2, bp["mlp_ln"][di], cfg.norm_eps), lp, cfg)
                    di += 1
                x2 = x2 + y
            return x2, (jnp.stack(s_out), jnp.stack(c_out), kv2["k"], kv2["v"])
        x, (s_new, c_new, k_new, v_new) = jax.lax.scan(
            f, x, (blocks, (cache["ssm"], cache["conv"], cache["k"], cache["v"])))
        new_cache = {"ssm": s_new, "conv": c_new, "k": k_new, "v": v_new}
    elif fam == "encdec":
        x = x + sinusoidal_position_at(pos, cfg.d_model).astype(cdt)
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        def f(carry, inp):
            bp, (k, v, xk, xv) = inp
            x2, kv2 = None, None
            h, kv2 = decode_attention(rmsnorm(carry, bp["ln1"], cfg.norm_eps),
                                      bp["attn"], cfg, {"k": k, "v": v}, pos)
            x2 = carry + h
            # cross-attention against precomputed encoder KV
            xq = jnp.einsum("bsd,dh->bsh", rmsnorm(x2, bp["lnx"], cfg.norm_eps),
                            bp["xattn"]["wq"]).reshape(x2.shape[0], 1, Hq, hd)
            G = Hq // Hkv
            qg = xq.reshape(x2.shape[0], 1, Hkv, G, hd)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, xk).astype(jnp.float32) * hd ** -0.5
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(xv.dtype), xv)
            o = jnp.einsum("bsh,hd->bsd", o.reshape(x2.shape[0], 1, Hq * hd),
                           bp["xattn"]["wo"])
            x2 = x2 + o
            y = dense_ffn(rmsnorm(x2, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg)
            return x2 + y, kv2
        x, kv_new = jax.lax.scan(
            f, x, (blocks, (cache["k"], cache["v"], cache["xk"], cache["xv"])))
        new_cache = {**kv_new, "xk": cache["xk"], "xv": cache["xv"]}
    else:
        raise ValueError(fam)
    h = rmsnorm(x, params["final_norm"].astype(cdt), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _head(params, cfg, cdt))
    return logits.astype(jnp.float32), new_cache


def prefill(params, tokens, cfg, st: ModelSettings = ModelSettings(),
            enc_inputs=None):
    """Forward over the prompt; returns last-position logits.

    (Cache materialization for the serving path reuses forward_hidden
    activations; for the dry-run cells the lowered artifact is the full
    prompt forward, which dominates prefill cost.)"""
    h, _ = forward_hidden(params, tokens, cfg, st, enc_inputs=enc_inputs)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], _head(params, cfg, st.compute_dtype))
    return logits.astype(jnp.float32)
