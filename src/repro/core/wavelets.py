"""3D wavelet transforms "on the interval" (CubismZ substage 1).

Implements the paper's three wavelet types as separable, multi-level, block-
local lifting transforms:

* ``w4i``  — 4th-order interpolating wavelets (Donoho interpolating wavelets):
             odd samples are predicted by cubic Lagrange interpolation of the
             even (coarse) samples; the detail is the prediction residual.
* ``w4l``  — 4th-order *lifted* interpolating wavelets: ``w4i`` followed by an
             update step ``s_i += (d_{i-1} + d_i)/4`` that restores (approx.)
             mean preservation and improves coarse-level decay.
* ``w3ai`` — 3rd-order average-interpolating wavelets (the paper's best
             performer): the coarse signal is the pairwise *cell average*; fine
             cell averages are predicted by quadratic average-interpolation.

"On the interval" boundary handling: near block edges the prediction stencil
is shifted inside the block and the weights are recomputed for the shifted
evaluation point (one-sided Lagrange / average-interpolation).  The weights
for *every* (stencil, evaluation target) pair are derived from first
principles by solving the small Vandermonde-type system numerically at trace
time — no hand-derived boundary tables, so all boundary cases are exact by
construction.  Blocks therefore never need neighbour (halo) data — the
property that makes the scheme embarrassingly parallel.

All transforms are exactly invertible (up to fp rounding) for any block side
``n = 2^k >= 8``; multi-level Mallat layout ``[coarse | detail]`` recursing on
the leading corner.

Perfect-reconstruction contract: ``inverse3d(forward3d(x)) == x`` to fp
tolerance; tested (incl. hypothesis sweeps) in ``tests/test_wavelets.py``.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

__all__ = [
    "WAVELETS",
    "max_levels",
    "default_levels",
    "forward1d",
    "inverse1d",
    "forward3d",
    "inverse3d",
    "detail_mask",
    "coarse_side",
]

WAVELETS = ("w4i", "w4l", "w3ai")

_INTERP_TAPS = 4   # cubic Lagrange (4th-order interpolating)
_AVG_TAPS = 3      # quadratic average-interpolation (3rd order)


# ---------------------------------------------------------------------------
# Weight derivation (numpy, cached; exact boundary handling by construction)
# ---------------------------------------------------------------------------

def _lagrange_weights(points: np.ndarray, t: float) -> np.ndarray:
    """Weights w with p(t) = sum_j w_j f(points_j) for the interpolating poly."""
    pts = np.asarray(points, dtype=np.float64)
    w = np.ones_like(pts)
    for j in range(len(pts)):
        for k in range(len(pts)):
            if j != k:
                w[j] *= (t - pts[k]) / (pts[j] - pts[k])
    return w


def _avg_interp_weights(cells: np.ndarray, a: float, b: float) -> np.ndarray:
    """Weights w with avg(p,[a,b]) = sum_j w_j avg(p, [c_j, c_j+1]).

    ``p`` is the unique quadratic matching the given cell averages.  Solved via
    the monomial-moment system M[k, j] = avg_{cell j}(t^k), rhs_k = avg_{[a,b]}(t^k).
    """
    cells = np.asarray(cells, dtype=np.float64)
    k = np.arange(len(cells), dtype=np.float64)[:, None]          # basis degree
    lo, hi = cells[None, :], cells[None, :] + 1.0
    M = (hi ** (k + 1) - lo ** (k + 1)) / (k + 1)                  # cell width 1
    rhs = (b ** (k[:, 0] + 1) - a ** (k[:, 0] + 1)) / ((k[:, 0] + 1) * (b - a))
    return np.linalg.solve(M, rhs)


@functools.lru_cache(maxsize=None)
def _predict_table(kind: str, m: int) -> tuple[np.ndarray, np.ndarray]:
    """(idx, W): predicted odd value i = sum_j W[i, j] * s[idx[i, j]].

    ``m`` is the coarse length.  For interpolating wavelets the odd sample
    2i+1 sits at coarse coordinate i + 0.5; for average-interpolating
    wavelets we predict the average over the right half-cell [i+0.5, i+1).
    """
    taps = _INTERP_TAPS if kind in ("w4i", "w4l") else _AVG_TAPS
    if m < taps:
        raise ValueError(f"coarse length {m} < stencil {taps} for {kind}")
    idx = np.zeros((m, taps), dtype=np.int32)
    W = np.zeros((m, taps), dtype=np.float64)
    for i in range(m):
        start = int(np.clip(i - 1, 0, m - taps))
        idx[i] = np.arange(start, start + taps)
        if kind in ("w4i", "w4l"):
            W[i] = _lagrange_weights(idx[i].astype(np.float64), i + 0.5)
        else:  # w3ai: coarse cell j covers [j, j+1); predict avg over right half
            W[i] = _avg_interp_weights(idx[i].astype(np.float64), i + 0.5, i + 1.0)
    return idx, W


# ---------------------------------------------------------------------------
# 1D lifting steps along the last axis
# ---------------------------------------------------------------------------

def _predict(s, kind: str):
    m = s.shape[-1]
    idx, W = _predict_table(kind, m)
    return (s[..., idx] * jnp.asarray(W, dtype=s.dtype)).sum(-1)


def _lift_update(d):
    """s-update term (d_{i-1} + d_i)/4, one-sided at the left boundary."""
    dm1 = jnp.concatenate([d[..., :1], d[..., :-1]], axis=-1)  # d_{-1} := d_0
    return (dm1 + d) * jnp.asarray(0.25, d.dtype)


def _fwd_step_last(x, kind: str):
    e, o = x[..., 0::2], x[..., 1::2]
    if kind in ("w4i", "w4l"):
        s = e
        d = o - _predict(s, kind)
        if kind == "w4l":
            s = s + _lift_update(d)
    else:  # w3ai
        half = jnp.asarray(0.5, x.dtype)
        s = (e + o) * half
        d = o - _predict(s, kind)
    return jnp.concatenate([s, d], axis=-1)


def _inv_step_last(x, kind: str):
    m = x.shape[-1] // 2
    s, d = x[..., :m], x[..., m:]
    if kind in ("w4i", "w4l"):
        if kind == "w4l":
            s = s - _lift_update(d)
        o = d + _predict(s, kind)
        e = s
    else:  # w3ai
        o = d + _predict(s, kind)
        e = 2.0 * s - o
    return jnp.stack([e, o], axis=-1).reshape(*x.shape[:-1], 2 * m)


def _step(x, axis: int, kind: str, inverse: bool):
    x = jnp.moveaxis(x, axis, -1)
    x = (_inv_step_last if inverse else _fwd_step_last)(x, kind)
    return jnp.moveaxis(x, -1, axis)


def forward1d(x, kind: str = "w3ai", axis: int = -1):
    return _step(x, axis, kind, inverse=False)


def inverse1d(x, kind: str = "w3ai", axis: int = -1):
    return _step(x, axis, kind, inverse=True)


# ---------------------------------------------------------------------------
# Multi-level separable 3D transform over trailing (n, n, n) axes
# ---------------------------------------------------------------------------

def max_levels(n: int) -> int:
    """Deepest level count keeping the coarse side >= 4 (stencil support)."""
    lv = 0
    while n >= 8:
        n //= 2
        lv += 1
    return lv


def default_levels(n: int, levels: int | None) -> int:
    lv = max_levels(n) if levels is None else levels
    if lv < 1 or lv > max_levels(n):
        raise ValueError(f"levels={levels} invalid for side {n}")
    return lv


def coarse_side(n: int, levels: int | None = None) -> int:
    return n >> default_levels(n, levels)


def forward3d(x, kind: str = "w3ai", levels: int | None = None):
    """Multi-level separable 3D DWT over the trailing three axes."""
    n = x.shape[-1]
    levels = default_levels(n, levels)
    out = x
    for lvl in range(levels):
        c = n >> lvl
        sub = out[..., :c, :c, :c]
        for axis in (-3, -2, -1):
            sub = _step(sub, axis, kind, inverse=False)
        out = sub if c == n else out.at[..., :c, :c, :c].set(sub)
    return out


def inverse3d(x, kind: str = "w3ai", levels: int | None = None):
    n = x.shape[-1]
    levels = default_levels(n, levels)
    out = x
    for lvl in reversed(range(levels)):
        c = n >> lvl
        sub = out[..., :c, :c, :c]
        for axis in (-1, -2, -3):
            sub = _step(sub, axis, kind, inverse=True)
        out = out.at[..., :c, :c, :c].set(sub)
    return out


def detail_mask(n: int, levels: int | None = None) -> np.ndarray:
    """Boolean (n,n,n) mask: True where a coefficient is a *detail* coeff.

    The approximation corner ``[0:c, 0:c, 0:c]`` (c = n >> levels) is False —
    it is always stored at full precision and never thresholded.
    """
    c = coarse_side(n, levels)
    mask = np.ones((n, n, n), dtype=bool)
    mask[:c, :c, :c] = False
    return mask
