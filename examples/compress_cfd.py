"""Ex-situ compression of CFD output (the CubismZ tool use case):
compress all four QoIs to CZ containers, then random-access one block
through the chunk cache without decompressing the file.

Run:  PYTHONPATH=src python examples/compress_cfd.py
"""
import os

import numpy as np

from repro.core import CompressionSpec, container
from repro.fields import CloudConfig, cavitation_fields

out = "artifacts/example_fields"
os.makedirs(out, exist_ok=True)
fields = cavitation_fields(CloudConfig(n=64), t=9.4)
spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                       block_size=32, shuffle="byte")

for q, f in fields.items():
    path = os.path.join(out, f"{q}.cz")
    nbytes = container.write_field(path, f, spec)
    print(f"{q:4s}: {f.nbytes/2**20:.1f} MiB -> {nbytes/2**20:.2f} MiB "
          f"(CR {f.nbytes/nbytes:.1f}x) -> {path}")

# random block access via the decompression chunk cache (paper §2.3)
r = container.FieldReader(os.path.join(out, "p.cz"))
block = r.read_block(1, 0, 1)
print(f"block (1,0,1): shape {block.shape}, mean {block.mean():.3f}, "
      f"cache hits/misses = {r.cache_hits}/{r.cache_misses}")
r.close()
