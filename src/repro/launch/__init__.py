"""launch subsystem."""
