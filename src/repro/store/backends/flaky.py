"""FlakyStore: fault-injection wrapper for read/write-path resilience tests.

Wraps any :class:`Store` and raises an injected :class:`IOError` on
configured operations.  A dataset written through the inner store can be
read through a flaky view of it — proving that a mid-``read_box`` fetch
failure surfaces as a clean error and that an immediate retry succeeds
against intact caches — and, since the commit path is also injectable, that
a fault in the middle of an append or sidecar merge leaves the dataset
readable at its last committed state.

Knobs (all 1-based counts across the wrapper's lifetime, reassignable
between operations — ``flaky.fail_on_get = flaky.gets + 1`` arms the *next*
get):

* ``fail_on_get`` — fail the Nth ``get``/``get_many`` request;
* ``fail_on_put`` — fail the Nth write (``put`` and ``put_atomic`` share
  one counter, ``puts``, because a commit is a commit either way; the
  buffered ``open_write`` sink commits through ``put``, so streamed member
  writes are injectable too);
* ``fail_on_op`` — ``{"delete": 2, "list": 1, ...}``, a per-op arm for
  anything else (``exists`` is never faulted: it is the probe readers use
  to *recognize* state, not to change it);
* ``fail_every`` — repeat the failure periodically after the first;
  ``None`` (default) fails exactly once per armed counter.

:class:`InjectedFault` subclasses :class:`IOError`, so a
:class:`~repro.store.backends.retry.RetryStore` wrapped around a flaky
store treats the injected faults as transient — the deterministic harness
for retry/backoff tests.
"""
from __future__ import annotations

import threading

from .base import Store

__all__ = ["FlakyStore", "InjectedFault"]


class InjectedFault(IOError):
    """The configured fault, raised by :class:`FlakyStore`."""


class FlakyStore(Store):
    """Delegating store that raises on configured operation counts."""

    def __init__(self, inner: Store, fail_on_get: int | None = None,
                 fail_every: int | None = None,
                 fail_on_put: int | None = None,
                 fail_on_op: dict[str, int] | None = None):
        super().__init__()
        self.inner = inner
        self.fail_on_get = fail_on_get
        self.fail_on_put = fail_on_put
        self.fail_on_op = dict(fail_on_op or {})
        self.fail_every = fail_every
        self.gets = 0
        self.puts = 0
        self.op_calls: dict[str, int] = {}
        self.faults = 0
        self._count_guard = threading.Lock()

    def _armed(self, n: int, first: int | None) -> bool:
        if first is None or n < first:
            return False
        return n == first or bool(
            self.fail_every and (n - first) % self.fail_every == 0)

    def _maybe_fail(self, op: str) -> None:
        with self._count_guard:
            n_op = self.op_calls[op] = self.op_calls.get(op, 0) + 1
            checks = [(op, n_op, self.fail_on_op.get(op))]
            if op == "get":
                self.gets += 1
                checks.append(("get", self.gets, self.fail_on_get))
            elif op in ("put", "put_atomic"):
                self.puts += 1
                checks.append(("put", self.puts, self.fail_on_put))
            for what, n, first in checks:
                if self._armed(n, first):
                    self.faults += 1
                    raise InjectedFault(
                        f"injected fault on {what} #{n} (op={op})")

    def get(self, key, byte_range=None):
        self._maybe_fail("get")
        return self.inner.get(key, byte_range)

    def get_many(self, requests):
        reqs = list(requests)
        for _ in reqs:  # each request in the batch counts toward the arm
            self._maybe_fail("get")
        return self.inner.get_many(reqs)

    def put(self, key, data):
        self._maybe_fail("put")
        self.inner.put(key, data)

    def put_atomic(self, key, data):
        self._maybe_fail("put_atomic")
        self.inner.put_atomic(key, data)

    def list(self, prefix=""):
        self._maybe_fail("list")
        return self.inner.list(prefix)

    def delete(self, key):
        self._maybe_fail("delete")
        self.inner.delete(key)

    def exists(self, key):
        return self.inner.exists(key)

    # open_write intentionally NOT delegated: the base buffered sink commits
    # through self.put on clean close, which routes streamed member writes
    # through put-fault injection and guarantees no torn object is ever
    # visible when the injected fault fires mid-commit.

    def lock(self, name):
        return self.inner.lock(name)

    @property
    def url(self) -> str:
        return f"flaky+{self.inner.url}"
