"""MemoryStore: dict-backed byte store for tests and ephemeral in-situ runs.

Anonymous instances (``MemoryStore()``) are private to their creator.
*Named* instances — ``MemoryStore.named("x")``, or any ``mem://x`` URL —
live in a process-global registry, so two ``CZDataset("mem://x")`` handles
in one process share the same bytes: an in-situ writer thread can append
while a serve replica reads, with no filesystem at all.
"""
from __future__ import annotations

import threading

from .base import Store, StoreKeyError, check_key, check_range

__all__ = ["MemoryStore"]


class MemoryStore(Store):
    """In-memory byte store (thread-safe; objects are immutable bytes)."""

    scheme = "mem"

    #: process-global name -> instance registry behind ``mem://`` URLs.
    #: Class-scoped so subclasses (RangeStore) get their own namespace.
    _named: dict[str, "MemoryStore"] = {}
    _named_guard = threading.Lock()

    def __init__(self, name: str | None = None):
        super().__init__()
        self.name = name
        self._objects: dict[str, bytes] = {}
        self._guard = threading.Lock()

    @classmethod
    def named(cls, name: str) -> "MemoryStore":
        """The shared registry instance for ``{scheme}://{name}`` (created
        on first use)."""
        if not name:
            raise ValueError(
                f"{cls.scheme}:// URLs need a name ({cls.scheme}://myds) — "
                "an anonymous store could never be reopened")
        registry = cls.__dict__.get("_named")
        if registry is None:  # first named instance of this subclass
            registry = {}
            setattr(cls, "_named", registry)
        with MemoryStore._named_guard:
            store = registry.get(name)
            if store is None:
                store = registry[name] = cls(name)
        return store

    @classmethod
    def drop(cls, name: str) -> None:
        """Forget a named store (tests/benchmarks reclaiming memory)."""
        with MemoryStore._named_guard:
            cls.__dict__.get("_named", {}).pop(name, None)

    from_url = named

    # -- primitives ----------------------------------------------------------

    def get(self, key, byte_range=None):
        check_key(key)
        with self._guard:
            try:
                data = self._objects[key]
            except KeyError:
                raise StoreKeyError(key) from None
        if byte_range is None:
            return data
        start, end = byte_range
        start = check_range(key, start, len(data))
        return data[start:] if end is None else data[start:int(end)]

    def put(self, key, data):
        check_key(key)
        data = bytes(data)
        with self._guard:
            self._objects[key] = data

    def list(self, prefix=""):
        with self._guard:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key):
        check_key(key)
        with self._guard:
            if self._objects.pop(key, None) is None:
                raise StoreKeyError(key)

    def exists(self, key):
        with self._guard:
            return key in self._objects

    @property
    def url(self) -> str:
        name = self.name if self.name is not None else f"anon-{id(self):x}"
        return f"{self.scheme}://{name}"
