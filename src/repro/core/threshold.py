"""Threshold decimation of wavelet detail coefficients (substage 1 output).

The paper guarantees decimation error <= eps by zeroing detail coefficients
with magnitude below the tolerance.  The approximation corner (coarsest
level) is never thresholded.  ``topk_details`` is the fixed-shape variant
used for TPU-friendly in-situ paths (gradient compression), where a static
output size is required instead of a data-dependent significant count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import wavelets as wv

__all__ = ["threshold_details", "significant_mask", "topk_details"]


def _detail_mask_for(x, levels):
    n = x.shape[-1]
    return jnp.asarray(wv.detail_mask(n, levels))


def threshold_details(coeffs, eps: float, levels: int | None = None):
    """Zero detail coefficients with |c| < eps; keep the approximation corner."""
    dm = _detail_mask_for(coeffs, levels)
    keep = (~dm) | (jnp.abs(coeffs) >= eps)
    return jnp.where(keep, coeffs, jnp.zeros((), coeffs.dtype))


def significant_mask(coeffs, eps: float, levels: int | None = None):
    """Boolean mask of coefficients that survive decimation (details only)."""
    dm = _detail_mask_for(coeffs, levels)
    return dm & (jnp.abs(coeffs) >= eps)


def topk_details(coeffs, k: int, levels: int | None = None):
    """Keep the k largest-|.| detail coefficients per block (fixed shapes).

    coeffs: (..., n, n, n).  Returns (values (..., k), flat_indices (..., k),
    coarse (..., c, c, c)) — a static-size encoding suitable for use inside
    jit (e.g. error-feedback gradient compression over the pod axis).
    """
    n = coeffs.shape[-1]
    c = wv.coarse_side(n, levels)
    dm = jnp.asarray(wv.detail_mask(n, levels)).reshape(-1)
    flat = coeffs.reshape(*coeffs.shape[:-3], n * n * n)
    mag = jnp.where(dm, jnp.abs(flat), -jnp.inf)
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(flat, idx, axis=-1)
    coarse = coeffs[..., :c, :c, :c]
    return vals, idx.astype(jnp.int32), coarse


def scatter_topk(vals, idx, coarse, n: int):
    """Inverse of :func:`topk_details`: rebuild a dense coefficient cube."""

    def one(v, i, co):
        flat = jnp.zeros((n * n * n,), v.dtype).at[i].set(v)
        cube = flat.reshape(n, n, n)
        c = co.shape[-1]
        return cube.at[:c, :c, :c].set(co)

    batch = vals.shape[:-1]
    if not batch:
        return one(vals, idx, coarse)
    f = one
    for _ in batch:
        f = jax.vmap(f)
    return f(vals, idx, coarse)
