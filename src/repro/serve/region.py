"""Compressed-field region serving: ``(quantity, t, lo, hi)`` queries
against a CZDataset answered through a tiered decode cache.

Deliberately free of jax/model imports — serving compressed fields must not
pull in the LLM decode stack (:mod:`repro.serve.step`).

Three tiers answer a query, cheapest first:

1. **decoded-region LRU** (:class:`repro.serve.cache.RegionCache`) — the
   exact box was served before and is still resident: zero decode, zero
   assembly.
2. **chunk LRU** (the store's pooled :class:`FieldReader` caches) — the
   covering chunks are resident: block gather + box assembly only.
3. **decode** — cold chunks are inflated, with concurrent duplicate work
   coalesced by :class:`repro.serve.scheduler.ChunkScheduler` so each chunk
   is decoded once per miss however many requests are waiting on it.

:class:`FieldRegionServer` is transport-agnostic (in-process callers use it
directly; :mod:`repro.serve.http` puts a socket in front) and safe for
concurrent request threads.
"""
from __future__ import annotations

import contextlib
import threading
import time

from repro import obs
from repro.obs import context as _context
from repro.obs import trace
from repro.obs.sampling import TailSampler

from .cache import RegionCache
from .scheduler import ChunkScheduler, SingleFlight

__all__ = ["FieldRegionServer", "LatencyHistogram", "LATENCY_BUCKETS"]

#: Prometheus-style cumulative bucket bounds, seconds (+Inf is implicit).
#: Same bounds as :data:`repro.obs.DEFAULT_BUCKETS` — kept as a named
#: constant because the serve tier's ``/metrics`` shape predates ``obs``.
LATENCY_BUCKETS = obs.DEFAULT_BUCKETS


class LatencyHistogram(obs.Histogram):
    """The serve tier's request-latency histogram — an
    :class:`repro.obs.Histogram` pre-named for the ``/metrics`` exposition
    (``render_metrics`` registers the live instance, so scraped buckets are
    the ones ``observe`` filled — no copy, no drift).  ``snapshot()``
    (inherited) keeps the historical
    ``{"buckets": [(le, cum), ...], "sum": s, "count": n}`` shape."""

    def __init__(self, buckets=LATENCY_BUCKETS):
        super().__init__("cz_serve_request_seconds", "Region query latency.",
                         buckets=buckets)


class FieldRegionServer:
    """Serves ``(quantity, t, lo, hi)`` region queries from a CZDataset.

    The paper's §2.3 decompressor turned into a query server: the tiered
    cache + single-flight scheduler described in the module docstring, with
    request counters and a latency histogram for ``/metrics``.

    Parameters
    ----------
    dataset:
        A :class:`repro.store.CZDataset` **or** a dataset root — a local
        path, a store URL (``file://``, ``mem://``, ``http://``, any
        registered backend), or a :class:`~repro.store.backends.Store`
        instance (the serve CLI passes a policy-wrapped store this way);
        the serve tier is backend-agnostic.  A root is opened — and
        therefore closed — by this server; a dataset object is borrowed,
        and :meth:`close` leaves it untouched (the caller opened it, the
        caller closes it).
    cache_bytes:
        Byte budget for the decoded-region LRU (``0`` disables it; chunk
        caching below is unaffected).
    max_inflight:
        Cap on *concurrent region decodes* (admission control; ``None`` =
        unbounded).  Deliberately scoped to the decode path only: cache
        hits and flight joins never wait on it, so a burst of cold requests
        cannot serialize the zero-cost hot path behind decodes.
    sample:
        Tail-based trace sampling (on by default): every query runs inside
        a collecting request context and its trace is *kept* only on error
        or slow-tail latency — see :class:`repro.obs.sampling.TailSampler`.
        ``False`` turns the sampler off entirely (requests still get
        correlation IDs at the HTTP front).
    trace_budget_bytes:
        Byte budget for retained tail traces (oldest evicted first).
    trace_slow_ms:
        Fixed slow threshold in milliseconds; ``None`` (default) tracks the
        live p99 of this server's own latency histogram.
    prefetch:
        Chunks each reader fetches ahead of decode during a region query
        (``0`` = off).  Worth enabling over latency-bearing remote stores
        (``http://``); applies only to roots this server opens itself (a
        borrowed CZDataset keeps its own setting).
    """

    def __init__(self, dataset, cache_readers: int = 16,
                 cache_chunks: int = 32, cache_bytes: int = 64 << 20,
                 max_inflight: int | None = None, sample: bool = True,
                 trace_budget_bytes: int = 4 << 20,
                 trace_slow_ms: float | None = None,
                 prefetch: int = 0):
        from repro.store import CZDataset
        from repro.store.backends import Store

        # a path, URL, or bare Store is a *root* we open (and own) a
        # read-only dataset over; a CZDataset instance is borrowed as-is
        self._owns_dataset = isinstance(dataset, (str, bytes, Store)) or \
            hasattr(dataset, "__fspath__")
        if self._owns_dataset:
            root = dataset if isinstance(dataset, Store) else str(dataset)
            dataset = CZDataset(root, mode="r",
                                cache_readers=cache_readers,
                                cache_chunks=cache_chunks,
                                prefetch=prefetch)
        self.ds = dataset
        self.closed = False
        self.cache = RegionCache(cache_bytes)
        self.admission = (threading.BoundedSemaphore(int(max_inflight))
                          if max_inflight else contextlib.nullcontext())
        self.scheduler = ChunkScheduler(dataset)
        self._region_sf = SingleFlight()
        self._lock = threading.Lock()
        self.queries = 0
        self.bytes_served = 0
        self.latency = LatencyHistogram()
        slow_s = None if trace_slow_ms is None else float(trace_slow_ms) / 1e3
        self.sampler = (TailSampler(self.latency,
                                    budget_bytes=trace_budget_bytes,
                                    slow_s=slow_s)
                        if sample else None)

    # -- queries -----------------------------------------------------------

    def query(self, quantity: str, t: int, lo, hi, copy: bool = True):
        """Decode (or fetch from cache) the box ``[lo, hi)`` of one quantity
        at one timestep.

        ``copy=False`` returns the cache's shared **read-only** array —
        zero-copy for callers that only serialize it (the HTTP tier); the
        default hands back a private writable copy.
        """
        if self.closed:
            raise IOError("FieldRegionServer is closed")
        key = (str(quantity), int(t),
               tuple(int(v) for v in lo), tuple(int(v) for v in hi))
        # correlation scope: the HTTP front opens one per request (and its
        # ID wins); direct in-process callers get one here so the tail
        # sampler sees every query either way
        ctx = _context.current()
        own = (_context.request(collect=True)
               if ctx is None and self.sampler is not None
               else contextlib.nullcontext(ctx))
        with own as ctx:
            t0 = time.perf_counter()
            error = None
            try:
                with trace.span("serve.query", quantity=key[0], t=key[1]):
                    out = self.cache.get(key)
                    if out is None:
                        # coalesce identical in-flight regions, then
                        # chunk-level flights inside read_box take care of
                        # partial overlaps
                        out = self._region_sf.do(
                            key, lambda: self._decode_region(key))
            except BaseException as e:
                error = f"{type(e).__name__}: {e}"
                raise
            finally:
                # observe errors too — the tail sampler's slow threshold
                # and the kept-trace duration must agree with /metrics
                dt = time.perf_counter() - t0
                self.latency.observe(dt)
                if self.sampler is not None:
                    self.sampler.finish(ctx, dt, error=error)
        with self._lock:
            self.queries += 1
            self.bytes_served += out.nbytes
        return out.copy() if copy else out

    def _decode_region(self, key):
        quantity, t, lo, hi = key
        with self.admission:  # only actual decode work queues here
            out = self.scheduler.read_box(quantity, t, lo, hi)
        self.cache.put(key, out)  # freezes `out` read-only
        return out

    def manifest(self) -> dict:
        """The dataset summary served at ``/v1/manifest`` (one serializer
        shared with ``cz-compress inspect --json``)."""
        if self.closed:
            raise IOError("FieldRegionServer is closed")
        return self.ds.describe()

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Flat counter dict: store chunk-cache counters + region-cache,
        scheduler, and request-level counters."""
        s = self.ds.stats()
        lat = self.latency.snapshot()
        with self._lock:
            s.update({
                "queries": self.queries,
                "bytes_served": self.bytes_served,
                "mean_latency_ms": 1e3 * lat["sum"] / max(1, lat["count"]),
            })
        s.update({f"region_cache_{k}": v
                  for k, v in self.cache.stats().items()})
        s.update(self.scheduler.stats())
        s["region_flights_led"] = self._region_sf.led
        s["region_flights_joined"] = self._region_sf.joined
        if self.sampler is not None:
            s.update({f"trace_{k}": v
                      for k, v in self.sampler.stats().items()})
        return s

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Idempotent.  Closes the dataset only when this server opened it
        from a path — a borrowed :class:`CZDataset` stays open for its
        owner."""
        if self.closed:
            return
        self.closed = True
        if self._owns_dataset:
            self.ds.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
