"""repro.obs — unified observability: metrics, traces, events, sampling.

Five stdlib-only modules:

* :mod:`repro.obs.registry` — thread-safe ``Counter``/``Gauge``/
  ``Histogram`` with labels, a process-wide default ``REGISTRY``, and
  Prometheus text exposition (``render``; ``openmetrics=True`` emits an
  OpenMetrics document with histogram exemplars) / JSON snapshots
  (``snapshot``).
* :mod:`repro.obs.trace` — ``with span("encode", chunk=i):`` span API
  exporting Chrome trace-event JSON (Perfetto-viewable), disabled by
  default at near-zero cost, with cross-process merge for the cluster
  engine's per-rank traces.
* :mod:`repro.obs.context` — request-scoped correlation: a contextvars
  request ID (``X-CZ-Request-Id`` at the HTTP front) stamped onto every
  span and event a request touches, plus bounded per-request span
  collection.
* :mod:`repro.obs.events` — structured JSON-lines event log (level, ts,
  request_id, fields); the in-package replacement for ``print``
  diagnostics.
* :mod:`repro.obs.sampling` — always-on tail-based trace sampling: every
  serve request is traced into its request context, and completed traces
  are kept only on error or above the live latency-tail threshold, within
  a byte budget (``GET /debug/traces``).

Every tier (pipeline, container reader, store backends, cluster engine,
device kernels, serve) instruments through this package;
``cz-compress ... --trace`` and ``cz-compress stats`` surface it on the
CLI.
"""
from repro.obs.registry import (  # noqa: F401
    DEFAULT_BUCKETS,
    FAST_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Metric,
    Registry,
    counter,
    gauge,
    histogram,
    parse_prometheus,
    render,
    snapshot,
)
from repro.obs.trace import (  # noqa: F401
    TRACER,
    Tracer,
    merge_traces,
    span,
    traced,
    tracing,
)
from repro.obs import context  # noqa: F401
from repro.obs import events  # noqa: F401
from repro.obs import sampling  # noqa: F401
from repro.obs import trace  # noqa: F401
from repro.obs.context import RequestContext, new_request_id, request_id  # noqa: F401
from repro.obs.events import event  # noqa: F401
from repro.obs.sampling import TailSampler  # noqa: F401

__all__ = [
    "Counter", "Gauge", "Histogram", "Metric", "Registry", "REGISTRY",
    "DEFAULT_BUCKETS", "FAST_BUCKETS", "counter", "gauge", "histogram",
    "render", "snapshot", "parse_prometheus",
    "Tracer", "TRACER", "span", "traced", "tracing", "trace", "merge_traces",
    "context", "RequestContext", "new_request_id", "request_id",
    "events", "event", "sampling", "TailSampler",
]
