"""Shared benchmark helpers: datasets, sweeps, CSV/JSON output."""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.core import CompressionSpec, Pipeline
from repro.fields import CloudConfig, cavitation_fields

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "bench")

# Default grid for benchmark datasets: 96^3 keeps every benchmark CPU-cheap
# while leaving 3 wavelet levels at 32^3 blocks (27 blocks per field).
BENCH_N = 96


@functools.lru_cache(maxsize=8)
def dataset(t_label: str = "10k", n: int = BENCH_N):
    from repro.fields.cavitation import PAPER_TIMES

    t = PAPER_TIMES[t_label]
    return cavitation_fields(CloudConfig(n=n), t)


def sweep(field, specs: list[CompressionSpec]) -> list[dict]:
    rows = []
    for spec in specs:
        t0 = time.time()
        r = Pipeline(spec).analyze(field)
        r["time_s"] = time.time() - t0
        r["spec"] = spec.to_json()
        rows.append(r)
    return rows


def emit(name: str, us_per_call: float, derived) -> None:
    """The harness CSV convention: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def write_bench_record(name: str, params: dict, metrics) -> str:
    """Write the machine-readable per-bench record
    ``artifacts/bench/BENCH_<name>.json`` the harness emits for every run,
    so the perf trajectory is diffable across PRs.

    Schema: ``{"schema": 1, "name", "params", "metrics", "registry"}`` —
    ``metrics`` is whatever the bench module's ``run()`` returned (often
    None; the CSV on stdout remains the harness convention), ``registry``
    is the full :func:`repro.obs.snapshot` at completion, so every
    ``cz_*`` series the run touched (pipeline chunk timings, store op
    counts, reader fetch/decode split) rides along without per-bench
    plumbing.
    """
    from repro import obs

    record = {"schema": 1, "name": name, "params": dict(params),
              "metrics": metrics, "registry": obs.snapshot()}
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return path


def eps_sweep(lo=1e-4, hi=1e-1, n=6):
    return list(np.geomspace(lo, hi, n))
