"""Streaming two-substage compression pipeline over the scheme registry.

Data flow (paper Fig. 1, mirrors CubismZ):

  field -> blocks -> [substage 1: any registered Scheme; ``spec.device``
        routes it to the host reference math or the jit'd Pallas kernels]
        -> per-"thread" aggregation buffers (~4 MB of blocks)
        -> scheme byte layout (+ optional byte/bit shuffle)
        -> [substage 2: zlib | lzma | bz2 | ... on the host]
        -> chunk stream + JSON-able header

:class:`Pipeline` binds a validated :class:`CompressionSpec` to its
:class:`~repro.core.schemes.Scheme` and exposes both a materializing API
(``compress``/``decompress``) and a streaming one (``iter_chunks``) that
yields compressed chunks one aggregation buffer at a time — the CZ2
container writer consumes it without ever materializing the chunk list
(the paper's per-thread-buffer writer).  Note the stage-1 transform still
runs over the whole block batch on device before the first chunk is
emitted; chunked stage 1 is a ROADMAP item.

``CODEC_FORMAT`` versions the chunk byte layout; headers record it so old
payloads decode bit-exact after layout changes (``Scheme.decode_spec``).

Chunks are independent, so ``iter_chunks`` optionally encodes them on a
thread pool (``workers=`` on :class:`Pipeline` — the paper's per-thread
writers, truly concurrent): serialization + stage 2 run in parallel while a
single ordered drain yields chunks in deterministic order, so serial and
threaded runs produce byte-identical output.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import itertools
import json
import time
from typing import Any, Iterator

import numpy as np

from repro import obs
from repro.obs import trace

from . import blocks as blk
from . import lossless, metrics
from .schemes import DEVICES, Scheme, check_device, get_scheme
from .schemes import SCHEMES  # noqa: F401  (re-export)

__all__ = ["CODEC_FORMAT", "DTYPES", "DEVICES", "CompressionSpec",
           "CompressedField", "Pipeline"]

#: version of the per-chunk byte layout (v2: szx shuffles its outlier
#: stream; v3: the ``auto`` meta-scheme's chunks carry a winner prelude —
#: name + eps — ahead of the winner's payload)
CODEC_FORMAT = 3

#: dtypes a container can record; CZ1/headerless payloads default to float32
DTYPES = ("float32", "float64", "float16")

# -- per-chunk accounting (the paper's per-stage timing, as live series) -----
_ENC_CHUNKS = obs.counter("cz_pipeline_chunks_encoded_total",
                          "Chunks encoded (stage 1+2) by scheme.",
                          labelnames=("scheme",))
_DEC_CHUNKS = obs.counter("cz_pipeline_chunks_decoded_total",
                          "Chunks decoded by scheme.",
                          labelnames=("scheme",))
_RAW_BYTES = obs.counter("cz_pipeline_raw_bytes_total",
                         "Uncompressed bytes entering chunk encode.",
                         labelnames=("scheme",))
_ENC_BYTES = obs.counter("cz_pipeline_encoded_bytes_total",
                         "Compressed bytes leaving chunk encode.",
                         labelnames=("scheme",))
_RATIO = obs.gauge("cz_pipeline_ratio",
                   "Achieved compression ratio (cumulative raw/encoded).",
                   labelnames=("scheme",))
_ENC_SECONDS = obs.histogram("cz_pipeline_encode_seconds",
                             "Per-chunk encode wall time by scheme.",
                             buckets=obs.FAST_BUCKETS,
                             labelnames=("scheme",))
_DEC_SECONDS = obs.histogram("cz_pipeline_decode_seconds",
                             "Per-chunk decode wall time by scheme.",
                             buckets=obs.FAST_BUCKETS,
                             labelnames=("scheme",))


def _account_encode(scheme: str, ci: int, raw: int, enc: int,
                    t0_ns: int, t1_ns: int) -> None:
    _ENC_CHUNKS.inc(scheme=scheme)
    _RAW_BYTES.inc(raw, scheme=scheme)
    _ENC_BYTES.inc(enc, scheme=scheme)
    total_raw = _RAW_BYTES.value(scheme=scheme)
    total_enc = _ENC_BYTES.value(scheme=scheme)
    if total_enc:
        _RATIO.set(total_raw / total_enc, scheme=scheme)
    _ENC_SECONDS.observe((t1_ns - t0_ns) / 1e9, scheme=scheme)
    trace.record("encode", t0_ns, t1_ns, chunk=ci, scheme=scheme,
                 raw_bytes=raw, encoded_bytes=enc,
                 ratio=round(raw / enc, 3) if enc else None)


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    scheme: str = "wavelet"      # any name in repro.core.schemes.SCHEMES
    wavelet: str = "w3ai"        # w4i | w4l | w3ai
    eps: float = 1e-3            # absolute error tolerance (wavelet/zfpx/szx)
    block_size: int = 32
    levels: int | None = None    # wavelet levels (None = max for block size)
    shuffle: str = "byte"        # none | byte | bit
    zero_bits: int = 0           # Z4/Z8 bit zeroing of detail coefficients
    stage2: str = "zlib"         # see repro.core.lossless.METHODS
    buffer_bytes: int = 4 << 20  # per-thread aggregation buffer (paper: 4 MB)
    precision: int = 32          # fpzipx bits of precision (32 = lossless)
    dtype: str = "float32"       # field dtype tag (see DTYPES)
    device: str = "host"         # stage-1 routing: host | jax (see DEVICES)
    extra: dict = dataclasses.field(default_factory=dict)  # third-party knobs

    def __hash__(self):
        # the generated hash would choke on the mutable `extra` dict; keep
        # specs usable as dict/set keys and lru_cache arguments
        return hash(tuple(
            tuple(sorted(v.items())) if isinstance(v, dict) else v
            for v in dataclasses.astuple(self)
        ))

    def validate(self) -> "CompressionSpec":
        if self.shuffle not in ("none", "byte", "bit"):
            raise ValueError(f"unknown shuffle {self.shuffle}")
        if self.stage2 not in lossless.METHODS:
            raise ValueError(f"unknown stage2 {self.stage2}")
        if self.dtype not in DTYPES:
            raise ValueError(f"unknown dtype {self.dtype}; one of {DTYPES}")
        check_device(self.device)
        blk.check_block_size(self.block_size)
        get_scheme(self.scheme).validate(self)
        return self

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "CompressionSpec":
        return CompressionSpec(**d)


class CompressedField:
    """In-memory compressed representation: chunk list + JSON-able header."""

    def __init__(self, chunks: list[bytes], header: dict):
        self.chunks = chunks
        self.header = header

    @property
    def nbytes(self) -> int:
        return sum(len(c) for c in self.chunks) + len(json.dumps(self.header))

    @property
    def spec(self) -> CompressionSpec:
        return CompressionSpec.from_json(self.header["spec"])

    @property
    def format(self) -> int:
        """Chunk byte-layout version (headers before CZ2 carried none)."""
        return int(self.header.get("format", 1))


class Pipeline:
    """A validated spec bound to its registered scheme; the one compression
    path every public entry point (functions, container, CLI, ckpt) uses.

    ``workers > 1`` encodes aggregation buffers on a thread pool (ordered
    drain, byte-identical to the serial path); serialization and stage-2
    coding release the GIL in numpy/zlib, so this scales like the paper's
    per-thread writers.
    """

    def __init__(self, spec: CompressionSpec, workers: int = 1):
        self.spec = spec.validate()
        self.scheme: Scheme = get_scheme(spec.scheme)
        self.workers = max(1, int(workers))

    # -- layout ------------------------------------------------------------

    @property
    def blocks_per_chunk(self) -> int:
        raw_block = self.spec.np_dtype.itemsize * self.spec.block_size ** 3
        return max(1, self.spec.buffer_bytes // raw_block)

    def base_header(self) -> dict:
        """Self-describing header stub: scheme name + params are explicit so
        readers dispatch through the registry without guessing."""
        return {
            "format": CODEC_FORMAT,
            "scheme": self.spec.scheme,
            "scheme_params": self.scheme.params(self.spec),
            "dtype": self.spec.dtype,
            "spec": self.spec.to_json(),
        }

    # -- compression -------------------------------------------------------

    def iter_chunks(self, blocks_np: np.ndarray, workers: int | None = None,
                    executor: concurrent.futures.Executor | None = None,
                    records: list | None = None,
                    ) -> Iterator[tuple[bytes, int]]:
        """Yield ``(chunk_bytes, n_blocks)`` one aggregation buffer at a time.

        Substage 1 runs once over the whole batch on device (its output stays
        resident for the generator's lifetime); serialization and substage 2
        stream chunk-by-chunk, so a consumer writing to disk never holds more
        than one *compressed* chunk (plus the bounded in-flight window when
        ``workers > 1``).

        With ``workers > 1`` (or an external ``executor``, e.g. the store's
        :class:`~repro.store.ShardWriter` pool) chunk encoding is submitted to
        the pool a bounded window ahead while results are yielded strictly in
        order — the output byte stream is identical to the serial path.

        ``records`` (a caller-owned list) collects each chunk's
        :meth:`Scheme.chunk_record` in yield order — ``None`` entries for
        schemes that record nothing; the container writer turns a non-empty
        collection into the footer's ``chunk_schemes`` table.
        """
        spec = self.spec
        blocks_np = np.asarray(blocks_np)
        with trace.span("stage1", scheme=spec.scheme, device=spec.device,
                        nblocks=int(blocks_np.shape[0])):
            s1 = self.scheme.stage1(blocks_np, spec)
        bpc = self.blocks_per_chunk
        ranges = [(ci, lo, min(lo + bpc, blocks_np.shape[0]))
                  for ci, lo in enumerate(
                      range(0, blocks_np.shape[0], bpc))]
        block_bytes = spec.np_dtype.itemsize * spec.block_size ** 3

        def encode(ci: int, lo: int, hi: int) -> tuple[bytes, dict | None]:
            t0 = time.perf_counter_ns()
            payload = self.scheme.serialize(s1, lo, hi, spec)
            chunk = lossless.encode(payload, spec.stage2)
            rec = self.scheme.chunk_record(s1, lo, hi, spec)
            _account_encode(spec.scheme, ci, (hi - lo) * block_bytes,
                            len(chunk), t0, time.perf_counter_ns())
            return chunk, rec

        def emit(chunk: bytes, rec: dict | None, nblk: int):
            if records is not None:
                records.append(rec)
            return chunk, nblk

        nworkers = self.workers if workers is None else max(1, int(workers))
        if executor is None and nworkers <= 1:
            for ci, lo, hi in ranges:
                yield emit(*encode(ci, lo, hi), hi - lo)
            return

        own_pool = executor is None
        pool = executor or concurrent.futures.ThreadPoolExecutor(nworkers)
        try:
            # keep at most ~2x workers chunks in flight: parallelism without
            # materializing the whole compressed chunk list
            window = 2 * nworkers
            it = iter(ranges)
            pending: collections.deque = collections.deque(
                (r, pool.submit(encode, *r)) for r in itertools.islice(it, window))
            while pending:
                (_ci, lo, hi), fut = pending.popleft()
                nxt = next(it, None)
                if nxt is not None:
                    pending.append((nxt, pool.submit(encode, *nxt)))
                chunk, rec = fut.result()
                # the single ordered drain appends records in chunk order,
                # so threaded collection matches the serial path exactly
                yield emit(chunk, rec, hi - lo)
        finally:
            if own_pool:
                pool.shutdown(wait=True, cancel_futures=True)

    def compress_blocks(self, blocks_np: np.ndarray,
                        extra_header: dict | None = None) -> CompressedField:
        blocks_np = np.asarray(blocks_np)
        chunks, chunk_nblocks = [], []
        records: list = []
        for chunk, nblk in self.iter_chunks(blocks_np, records=records):
            chunks.append(chunk)
            chunk_nblocks.append(nblk)
        header = self.base_header()
        header.update({
            "nblocks": int(blocks_np.shape[0]),
            "chunk_nblocks": chunk_nblocks,
            "chunk_sizes": [len(c) for c in chunks],
            "raw_bytes": int(blocks_np.size * self.spec.np_dtype.itemsize),
        })
        if any(r is not None for r in records):
            header["chunk_schemes"] = records
        if extra_header:
            header.update(extra_header)
        return CompressedField(chunks, header)

    def compress_field(self, field: np.ndarray,
                       extra_header: dict | None = None) -> CompressedField:
        blocks_np = np.asarray(
            blk.blockify(np.asarray(field, self.spec.np_dtype),
                         self.spec.block_size))
        hdr = {"field_shape": list(field.shape)}
        if extra_header:
            hdr.update(extra_header)
        return self.compress_blocks(blocks_np, hdr)

    def compress(self, data: np.ndarray,
                 extra_header: dict | None = None) -> CompressedField:
        """Compress a 3D field or a (nblk, bs, bs, bs) block batch."""
        data = np.asarray(data)
        if data.ndim == 3:
            return self.compress_field(data, extra_header)
        if data.ndim == 4:
            return self.compress_blocks(data, extra_header)
        raise ValueError(f"expected 3D field or 4D block batch, got {data.shape}")

    # -- decompression -----------------------------------------------------

    def decompress_chunk(self, buf: bytes, nblk: int,
                         fmt: int = CODEC_FORMAT) -> np.ndarray:
        t0 = time.perf_counter_ns()
        spec = self.scheme.decode_spec(self.spec, fmt)
        payload = lossless.decode(buf, spec.stage2)
        blocks = self.scheme.deserialize(payload, nblk, spec)
        # lossy schemes compute in float32; the dtype tag restores the field
        # dtype (raw already deserializes in the tagged dtype — no-op there)
        out = blocks.astype(spec.np_dtype, copy=False)
        t1 = time.perf_counter_ns()
        _DEC_CHUNKS.inc(scheme=spec.scheme)
        _DEC_SECONDS.observe((t1 - t0) / 1e9, scheme=spec.scheme)
        trace.record("decode", t0, t1, scheme=spec.scheme, nblocks=nblk,
                     encoded_bytes=len(buf))
        return out

    def decompress_blocks(self, comp: CompressedField) -> np.ndarray:
        outs = [
            self.decompress_chunk(buf, nb, comp.format)
            for buf, nb in zip(comp.chunks, comp.header["chunk_nblocks"])
        ]
        return np.concatenate(outs, axis=0)

    def decompress(self, comp: CompressedField) -> np.ndarray:
        """Blocks back, or the reassembled field if the header recorded one."""
        blocks_np = self.decompress_blocks(comp)
        shape = comp.header.get("field_shape")
        if shape is None:
            return blocks_np
        return np.asarray(blk.unblockify(blocks_np, tuple(shape)))

    # -- analysis ----------------------------------------------------------

    def analyze(self, field: np.ndarray) -> dict[str, Any]:
        """Compress + decompress + measure (CR, PSNR, error bound)."""
        comp = self.compress_field(field)
        dec = self.decompress(comp)
        return {
            "cr": metrics.compression_ratio(comp.header["raw_bytes"], comp.nbytes),
            "psnr": metrics.psnr(field, dec),
            "max_err": float(np.max(np.abs(np.asarray(field) - dec))),
            "comp_bytes": comp.nbytes,
            "raw_bytes": comp.header["raw_bytes"],
            "spec": self.spec,
        }
