"""Model stack: attention, MoE, SSM mixers, family composition, registry."""
from .registry import (  # noqa: F401
    ModelSettings,
    cache_spec,
    count_params,
    decode_step,
    init_params,
    input_batch_specs,
    lm_loss,
    param_specs,
    prefill,
)
