"""Train-step builder: loss -> grads -> (optional compressed cross-pod
reduce) -> AdamW, jitted with explicit in/out shardings on the production
mesh.

The paper-faithful baseline uses plain data parallelism (GSPMD reduces
gradients over all batch axes).  With ``grad_compress`` set and a "pod" axis
present, gradients are computed per pod (shard_map manual over "pod", auto
over "data"/"model"), compressed with the CubismZ codec stack (top-k wavelet
details with error feedback), summed over the pod interconnect, and
decompressed — the §Perf collective-bytes optimization.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import ModelSettings, lm_loss, param_specs
from .optim import OptConfig, adamw_step, init_opt_state
from .sharding import batch_shardings, state_shardings

__all__ = ["build_train_step", "train_state_specs", "train_state_shardings"]


def train_state_specs(cfg, dtype=jnp.float32, grad_compress=None,
                      param_dtype=None):
    p = param_specs(cfg, param_dtype or dtype)
    mv = param_specs(cfg, dtype)
    state = {
        "params": p,
        "m": mv,
        "v": mv,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if grad_compress:
        state["residual"] = mv  # error-feedback memory
    return state


def train_state_shardings(cfg, mesh, grad_compress=None, mode: str = "fsdp",
                          param_dtype=None):
    return state_shardings(
        train_state_specs(cfg, grad_compress=grad_compress,
                          param_dtype=param_dtype),
        mesh, hybrid=(cfg.family == "hybrid"), mode=mode)


def init_train_state(cfg, key, grad_compress=None):
    from repro.models import init_params

    params = init_params(cfg, key)
    state = {"params": params, **init_opt_state(params)}
    if grad_compress:
        state["residual"] = jax.tree.map(jnp.zeros_like, params)
    return state


def build_train_step(cfg, mesh, *, settings: ModelSettings = ModelSettings(),
                     opt: OptConfig = OptConfig(), grad_compress: str | None = None,
                     donate: bool = True, micro_batches: int = 1,
                     sharding_mode: str = "fsdp", param_dtype=None):
    """Returns (jitted_fn, in_shardings, out_shardings).

    jitted_fn(state, batch) -> (state, metrics)
    """
    import dataclasses as _dc

    from repro.launch.mesh import batch_axes as _baxes

    baxes = _baxes(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    settings = _dc.replace(settings, batch_axes=baxes,
                           n_model=mesh.shape["model"], n_batch=nb)
    multi_pod = "pod" in mesh.axis_names

    def grads_of(params, batch):
        """(loss, metrics), grads — with optional microbatch accumulation
        (gradient accumulation keeps live activation memory ~1/micro_batches;
        the production fit-guarantee knob for the big train cells)."""
        if micro_batches == 1:
            return jax.value_and_grad(
                lambda p: lm_loss(p, batch, cfg, settings), has_aux=True)(params)

        mb_batch = jax.tree.map(
            lambda a: a.reshape(micro_batches, a.shape[0] // micro_batches,
                                *a.shape[1:]), batch)

        def one_micro(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(
                lambda p: lm_loss(p, mb, cfg, settings), has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
        (g_acc, loss_sum), _ = jax.lax.scan(one_micro, (g0, jnp.float32(0)),
                                            mb_batch)
        inv = 1.0 / micro_batches
        grads = jax.tree.map(lambda a: a * inv, g_acc)
        return (loss_sum * inv, {"ce": loss_sum * inv}), grads

    def train_step(state, batch):
        def loss_fn(p):
            return lm_loss(p, batch, cfg, settings)

        if grad_compress and multi_pod:
            from .grad_compress import pod_compressed_grads

            (loss, metrics), grads, residual, cmx = pod_compressed_grads(
                loss_fn, state["params"], state["residual"], batch, cfg,
                settings, mesh, method=grad_compress)
            metrics = {**metrics, **cmx}
        else:
            (loss, metrics), grads = grads_of(state["params"], batch)
            residual = state.get("residual")

        params, opt_state, om = adamw_step(state["params"], grads,
                                           {"m": state["m"], "v": state["v"],
                                            "step": state["step"]}, opt)
        new_state = {"params": params, **opt_state}
        if residual is not None:
            new_state["residual"] = residual
        return new_state, {"loss": loss, **metrics, **om}

    state_sh = train_state_shardings(cfg, mesh, grad_compress=grad_compress,
                                     mode=sharding_mode, param_dtype=param_dtype)

    def batch_sh(batch_specs):
        return batch_shardings(batch_specs, mesh)

    def jit_for(batch_specs):
        metrics_sh = None
        return jax.jit(
            train_step,
            in_shardings=(state_sh, batch_sh(batch_specs)),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,) if donate else (),
        )

    return train_step, jit_for, state_sh
