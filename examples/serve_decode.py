"""Serving, both kinds: (1) region queries against a compressed CZDataset
through the store's decode cache (FieldRegionServer), (2) batched LLM
prefill + greedy decode with a KV cache.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import tempfile

import numpy as np

from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields
from repro.serve import FieldRegionServer
from repro.store import CZDataset

# -- 1. compressed-field region serving -------------------------------------
root = os.path.join(tempfile.mkdtemp(), "ds")
with CZDataset(root, "a", spec=CompressionSpec(scheme="wavelet", eps=1e-3,
                                               block_size=16),
               workers=4) as ds:
    fields = cavitation_fields(CloudConfig(n=64), t=9.4)
    t = ds.append({"p": fields["p"], "rho": fields["rho"]}, time=9.4)

srv = FieldRegionServer(root)
rng = np.random.default_rng(0)
for _ in range(32):  # random 16^3 probes; hot chunks come from the LRU cache
    lo = rng.integers(0, 48, 3)
    srv.query("p", t, lo, lo + 16)
print(f"region server: {srv.stats()}")
srv.close()

# -- 2. LLM decode serving ---------------------------------------------------
from repro.launch.serve import main

main(["--arch", "smollm-135m", "--reduced", "--batch", "4",
      "--prompt-len", "8", "--max-new", "16"])
