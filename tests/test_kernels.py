"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus hypothesis parity sweeps over random shapes/levels/eps.  The property
tests skip cleanly on a bare interpreter (no hypothesis); any environment
installed via ``pip install -e ".[test]"`` — both CI jobs included — has
hypothesis and runs them for real."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 runs without hypothesis
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

pytestmark = pytest.mark.device


def blocks(b, n, seed=0, scale=50.0):
    rng = np.random.default_rng(seed)
    # smooth-ish blocks: random low-order polynomial + small noise
    g = np.mgrid[0:n, 0:n, 0:n].astype(np.float32) / n
    out = np.empty((b, n, n, n), np.float32)
    for i in range(b):
        c = rng.standard_normal(9).astype(np.float32)
        out[i] = scale * (
            c[0] + c[1] * g[0] + c[2] * g[1] + c[3] * g[2]
            + c[4] * g[0] * g[1] + c[5] * g[1] * g[2]
            + c[6] * g[0] ** 2 + c[7] * g[1] ** 2 + c[8] * g[2] ** 2
        ) + rng.standard_normal((n, n, n)).astype(np.float32) * 0.01 * scale
    return jnp.asarray(out)


@pytest.mark.parametrize("kind", ["w4i", "w4l", "w3ai"])
@pytest.mark.parametrize("b,n", [(1, 8), (4, 16), (3, 32), (8, 32)])
def test_wavelet_kernel_matches_ref(kind, b, n):
    x = blocks(b, n, seed=n + b)
    got = ops.wavelet_forward(x, kind=kind, interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=2e-3)
    back = ops.wavelet_inverse(got, kind=kind, interpret=True)
    scale = float(np.max(np.abs(np.asarray(x))))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-5, atol=1e-4 * scale)


@pytest.mark.parametrize("eps", [1e-4, 1e-2])
@pytest.mark.parametrize("b,n", [(2, 8), (4, 16), (5, 32)])
def test_zfpx_kernel_matches_ref(eps, b, n):
    x = blocks(b, n, seed=b * n)
    e_got, q_got = ops.zfpx_encode(x, eps=eps, interpret=True)
    e_want, q_want = ref.zfpx_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(e_got), np.asarray(e_want))
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    d_got = ops.zfpx_decode(e_got, q_got, eps=eps, n=n, interpret=True)
    d_want = ref.zfpx_decode_ref(e_want, q_want, eps=eps, n=n)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("eps", [1e-3, 1e-1])
@pytest.mark.parametrize("b,n", [(2, 8), (4, 16), (3, 32), (16, 16)])
def test_lorenzo_kernel_matches_ref(eps, b, n):
    x = blocks(b, n, seed=7 * b + n)
    r_got = ops.lorenzo_encode(x, eps=eps, interpret=True)
    r_want = ref.lorenzo_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(r_got), np.asarray(r_want))
    d_got = ops.lorenzo_decode(r_got, eps=eps, interpret=True)
    d_want = ref.lorenzo_decode_ref(r_want, eps=eps)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-6)
    assert float(jnp.max(jnp.abs(d_got - x))) <= eps * (1 + 1e-4) + 1e-5


def test_kernels_handle_non_divisible_batch():
    x = blocks(5, 16, seed=11)  # 5 % 4 != 0 -> tile fallback path
    got = ops.wavelet_forward(x, kind="w3ai", interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind="w3ai")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=2e-3)


def test_wavelet_kernel_dtype_promotion():
    x = blocks(2, 16).astype(jnp.float64) if False else blocks(2, 16)
    got = ops.wavelet_forward(x.astype(jnp.bfloat16), kind="w3ai", interpret=True)
    assert got.dtype == jnp.float32  # kernels compute in f32
    assert np.isfinite(np.asarray(got)).all()


# ---------------------------------------------------------------------------
# Odd / non-multiple-of-block grid sizes (the tile-fallback and non-2^k paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n", [(1, 6), (5, 10), (7, 12), (3, 20)])
def test_lorenzo_kernel_odd_sizes(b, n):
    """Lorenzo works at any block side, including odd and non-2^k."""
    x = blocks(b, n, seed=b * 31 + n)
    r_got = ops.lorenzo_encode(x, eps=1e-3, interpret=True)
    np.testing.assert_array_equal(np.asarray(r_got),
                                  np.asarray(ref.lorenzo_encode_ref(x, eps=1e-3)))
    d = ops.lorenzo_decode(r_got, eps=1e-3, interpret=True)
    assert float(jnp.max(jnp.abs(d - x))) <= 1e-3 * (1 + 1e-4) + 1e-5


@pytest.mark.parametrize("b,n", [(3, 12), (7, 20)])
def test_zfpx_kernel_non_pow2_sizes(b, n):
    """zfpx needs n % 4 == 0 only — non-power-of-two sides are exact too."""
    x = blocks(b, n, seed=b + 3 * n)
    e_got, q_got = ops.zfpx_encode(x, eps=1e-3, interpret=True)
    e_want, q_want = ref.zfpx_encode_ref(x, eps=1e-3)
    np.testing.assert_array_equal(np.asarray(e_got), np.asarray(e_want))
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    d_got = ops.zfpx_decode(e_got, q_got, eps=1e-3, n=n, interpret=True)
    d_want = ref.zfpx_decode_ref(e_want, q_want, eps=1e-3, n=n)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("levels", [1, 2])
@pytest.mark.parametrize("b", [1, 7])
def test_wavelet_kernel_explicit_levels(b, levels):
    x = blocks(b, 16, seed=b + levels)
    got = ops.wavelet_forward(x, kind="w3ai", levels=levels, interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind="w3ai", levels=levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-3)
    back = ops.wavelet_inverse(got, kind="w3ai", levels=levels, interpret=True)
    scale = float(np.max(np.abs(np.asarray(x))))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-5, atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# Hypothesis parity sweeps: kernels vs references on random shapes/levels/eps
# ---------------------------------------------------------------------------

def _rand_blocks(b, n, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.uniform(-scale, scale, (b, n, n, n)).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 8), n=st.sampled_from([4, 6, 8, 10, 12, 16]),
       eps=st.sampled_from([1e-4, 1e-3, 1e-1]), seed=st.integers(0, 2**16),
       scale=st.floats(1e-2, 1e3))
def test_lorenzo_parity_property(b, n, eps, seed, scale):
    """Kernel and host reference are *integer-exact* on any shape, and the
    reconstruction respects the eps bound."""
    x = _rand_blocks(b, n, seed, scale)
    r_got = ops.lorenzo_encode(x, eps=eps, interpret=True)
    r_want = ref.lorenzo_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(r_got), np.asarray(r_want))
    d = ops.lorenzo_decode(r_got, eps=eps, interpret=True)
    ulp = float(np.spacing(np.float32(scale)))
    assert float(jnp.max(jnp.abs(d - x))) <= eps * (1 + 1e-4) + ulp


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 6), n=st.sampled_from([4, 8, 12, 16]),
       eps=st.sampled_from([1e-4, 1e-2]), seed=st.integers(0, 2**16))
def test_zfpx_parity_property(b, n, eps, seed):
    x = _rand_blocks(b, n, seed, 50.0)
    e_got, q_got = ops.zfpx_encode(x, eps=eps, interpret=True)
    e_want, q_want = ref.zfpx_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(e_got), np.asarray(e_want))
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    d_got = ops.zfpx_decode(e_got, q_got, eps=eps, n=n, interpret=True)
    d_want = ref.zfpx_decode_ref(e_want, q_want, eps=eps, n=n)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want),
                               rtol=1e-6, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 5), n=st.sampled_from([8, 16, 32]),
       kind=st.sampled_from(["w4i", "w4l", "w3ai"]),
       levels=st.sampled_from([None, 1, 2]), seed=st.integers(0, 2**16))
def test_wavelet_parity_property(b, n, kind, levels, seed):
    x = _rand_blocks(b, n, seed, 50.0)
    got = ops.wavelet_forward(x, kind=kind, levels=levels, interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind=kind, levels=levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-3)
    back = ops.wavelet_inverse(got, kind=kind, levels=levels, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-5, atol=1e-4 * 50.0)


def test_kernel_metrics_split_compile_from_execute():
    """The device-tier instrumentation distinguishes the first call per
    signature (jit compile) from steady-state execution: compiles_total
    advances once per new signature, calls_total per call, and
    cz_kernel_seconds grows separate compile/execute series."""
    from repro import obs

    dev = __import__("jax").default_backend()
    kernel = "lorenzo_encode"
    lbl = {"kernel": kernel, "device": dev}
    compiles = obs.REGISTRY.get("cz_kernel_compiles_total")
    calls = obs.REGISTRY.get("cz_kernel_calls_total")
    seconds = obs.REGISTRY.get("cz_kernel_seconds")

    x = blocks(2, 8, seed=991)  # fresh shape: unseen by earlier tests
    c0, n0 = compiles.value(**lbl), calls.value(**lbl)
    ops.lorenzo_encode(x, eps=2e-3, interpret=True)
    assert compiles.value(**lbl) == c0 + 1
    assert calls.value(**lbl) == n0 + 1
    for _ in range(2):  # same signature: execute, no new compile
        ops.lorenzo_encode(x, eps=2e-3, interpret=True)
    assert compiles.value(**lbl) == c0 + 1
    assert calls.value(**lbl) == n0 + 3
    # a new eps is a new static value -> new jit cache entry -> compile
    ops.lorenzo_encode(x, eps=3e-3, interpret=True)
    assert compiles.value(**lbl) == c0 + 2

    comp = seconds.snapshot(**lbl, phase="compile")
    execd = seconds.snapshot(**lbl, phase="execute")
    assert comp["count"] >= 2 and execd["count"] >= 2


def test_kernel_sync_gated_on_observability(monkeypatch):
    """block_until_ready runs only when the timing is observable — first-
    call compiles, an enabled tracer, or a collecting request context —
    so steady-state uninstrumented calls keep async dispatch (the
    production path on accelerator backends).  SYNC forces either way."""
    import jax

    from repro import obs
    from repro.obs import context as obs_context

    assert not obs.TRACER.enabled
    syncs = {"n": 0}
    real = jax.block_until_ready

    def counting(x):
        syncs["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", counting)

    x = blocks(3, 8, seed=771)
    ops.lorenzo_encode(x, eps=5e-3, interpret=True)  # fresh sig: compile
    assert syncs["n"] == 1
    ops.lorenzo_encode(x, eps=5e-3, interpret=True)  # unobserved execute
    assert syncs["n"] == 1
    with obs_context.request(collect=True):  # tail collection active
        ops.lorenzo_encode(x, eps=5e-3, interpret=True)
    assert syncs["n"] == 2
    obs.trace.enable()
    try:
        ops.lorenzo_encode(x, eps=5e-3, interpret=True)  # tracer active
    finally:
        obs.trace.disable()
        obs.trace.reset()
    assert syncs["n"] == 3
    monkeypatch.setattr(ops, "SYNC", False)  # hard off wins over collection
    with obs_context.request(collect=True):
        ops.lorenzo_encode(x, eps=5e-3, interpret=True)
    assert syncs["n"] == 3
    monkeypatch.setattr(ops, "SYNC", True)  # hard on syncs unobserved calls
    ops.lorenzo_encode(x, eps=5e-3, interpret=True)
    assert syncs["n"] == 4
