"""Pluggable codec API tests: registry round-trips, custom-scheme plug-in,
streaming writer, and CZ1 back-compat (bit-exact legacy read)."""
import json
import os
import struct
import zlib

import numpy as np
import pytest

from repro.core import (
    CODEC_FORMAT,
    CompressionSpec,
    Pipeline,
    SCHEMES,
    compress_field,
    container,
    decompress_field,
)
from repro.core import blocks as blk
from repro.core import lossless
from repro.core.schemes import (
    Scheme,
    get_scheme,
    register_scheme,
    shuffle_bytes,
    unregister_scheme,
    unshuffle_bytes,
)


def smooth_field(n=32, seed=0):
    rng = np.random.default_rng(seed)
    g = np.mgrid[0:n, 0:n, 0:n].astype(np.float32)
    f = np.full((n, n, n), 40.0, np.float32)
    for _ in range(4):
        c = rng.uniform(6, n - 6, 3)
        d = np.sqrt(((g - c[:, None, None, None]) ** 2).sum(0))
        f += -25.0 / (1 + np.exp((d - 5.0) * 1.5))
    return f


FIELD = smooth_field()


def _ulp(x):
    return float(np.spacing(np.float32(np.max(np.abs(x)))))


# ---------------------------------------------------------------------------
# Round-trip: every registered scheme x shuffle mode x stage-2 backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stage2", ["zlib", "bz2", "none"])
@pytest.mark.parametrize("shuffle", ["none", "byte", "bit"])
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_pipeline_roundtrip_matrix(scheme, shuffle, stage2):
    spec = CompressionSpec(scheme=scheme, shuffle=shuffle, stage2=stage2,
                           eps=1e-3, block_size=16, buffer_bytes=1 << 16)
    pipe = Pipeline(spec)
    comp = pipe.compress(FIELD)
    assert len(comp.chunks) > 1  # small buffer forces multiple chunks
    assert comp.header["scheme"] == scheme
    assert "scheme_params" in comp.header
    dec = pipe.decompress(comp)
    assert dec.shape == FIELD.shape
    if scheme in ("raw", "fpzipx"):
        np.testing.assert_array_equal(dec, FIELD)
    elif scheme == "szx":
        assert np.max(np.abs(dec - FIELD)) <= spec.eps * (1 + 1e-4) + _ulp(FIELD)
    else:
        assert np.max(np.abs(dec - FIELD)) < 1.0


def test_pipeline_accepts_blocks_and_fields():
    spec = CompressionSpec(scheme="raw", block_size=16)
    pipe = Pipeline(spec)
    blocks = np.asarray(blk.blockify(FIELD, 16))
    out_blocks = pipe.decompress(pipe.compress(blocks))
    np.testing.assert_array_equal(out_blocks, blocks)
    out_field = pipe.decompress(pipe.compress(FIELD))
    np.testing.assert_array_equal(out_field, FIELD)


def test_unknown_scheme_rejected():
    with pytest.raises(ValueError, match="unknown scheme"):
        CompressionSpec(scheme="does-not-exist").validate()
    with pytest.raises(ValueError, match="unknown scheme"):
        get_scheme("does-not-exist")


def test_schemes_is_live_registry_view():
    assert "wavelet" in SCHEMES
    assert set(SCHEMES) >= {"wavelet", "zfpx", "szx", "fpzipx", "raw"}
    assert isinstance(SCHEMES["wavelet"], Scheme)


# ---------------------------------------------------------------------------
# Custom scheme plugs in without touching core
# ---------------------------------------------------------------------------

class NegateScheme(Scheme):
    """Toy third-party scheme: stores the negated field (lossless)."""

    name = "negate"

    def params(self, spec):
        return {"sign": -1, **super().params(spec)}

    def stage1(self, blocks_np, spec):
        return {"neg": -np.asarray(blocks_np, np.float32)}

    def serialize(self, s1, lo, hi, spec):
        return shuffle_bytes(s1["neg"][lo:hi].tobytes(), spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        vals = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, 4), np.float32)
        return -vals.reshape(nblk, n, n, n)


def test_custom_scheme_via_pipeline_and_container(tmp_path):
    register_scheme(NegateScheme)
    try:
        spec = CompressionSpec(scheme="negate", block_size=16, shuffle="byte",
                               buffer_bytes=1 << 16)
        pipe = Pipeline(spec)
        comp = pipe.compress(FIELD)
        assert comp.header["scheme"] == "negate"
        assert comp.header["scheme_params"]["sign"] == -1
        np.testing.assert_array_equal(pipe.decompress(comp), FIELD)

        # ...and straight through the CZ2 container + both readers
        path = os.path.join(tmp_path, "neg.cz")
        container.write_field(path, FIELD, spec)
        np.testing.assert_array_equal(container.read_field(path), FIELD)
        r = container.FieldReader(path)
        np.testing.assert_array_equal(r.read_block(0, 0, 0), FIELD[:16, :16, :16])
        r.close()

        # seed-era wrapper functions route through the registry too
        np.testing.assert_array_equal(
            decompress_field(compress_field(FIELD, spec)), FIELD)
    finally:
        unregister_scheme("negate")
    with pytest.raises(ValueError):
        CompressionSpec(scheme="negate").validate()


# ---------------------------------------------------------------------------
# Streaming writer
# ---------------------------------------------------------------------------

def test_iter_chunks_is_lazy_generator():
    import inspect

    spec = CompressionSpec(scheme="raw", block_size=16, buffer_bytes=1 << 16)
    blocks = np.asarray(blk.blockify(FIELD, 16))
    it = Pipeline(spec).iter_chunks(blocks)
    assert inspect.isgenerator(it)
    chunk, nblk = next(it)
    assert isinstance(chunk, bytes) and nblk >= 1


def test_write_compressed_streams_and_matches_materialized(tmp_path):
    spec = CompressionSpec(scheme="wavelet", block_size=16, buffer_bytes=1 << 16)
    p_stream = os.path.join(tmp_path, "stream.cz")
    p_mater = os.path.join(tmp_path, "mater.cz")
    container.write_compressed(p_stream, FIELD, spec)       # streaming path
    container.write_compressed(p_mater, Pipeline(spec).compress(FIELD))
    a, b = container.read_field(p_stream), container.read_field(p_mater)
    np.testing.assert_array_equal(a, b)
    with open(p_stream, "rb") as f:
        assert f.read(4) == container.MAGIC  # CZ2


def test_write_compressed_block_batch_roundtrip(tmp_path):
    """A container written from a raw block batch (no field_shape) reads back
    as blocks; FieldReader refuses it with a clear error."""
    path = os.path.join(tmp_path, "blocks.cz")
    blocks = np.asarray(blk.blockify(FIELD, 16))
    container.write_compressed(path, blocks,
                               CompressionSpec(scheme="raw", block_size=16))
    np.testing.assert_array_equal(container.read_field(path), blocks)
    with pytest.raises(ValueError, match="block batch"):
        container.FieldReader(path)


def test_spec_hashable_with_extra():
    assert hash(CompressionSpec()) == hash(CompressionSpec())
    assert hash(CompressionSpec(extra={"k": 1})) != hash(CompressionSpec())


def test_cz2_header_records_scheme_and_format(tmp_path):
    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, FIELD, CompressionSpec(scheme="zfpx",
                                                       block_size=16))
    r = container.FieldReader(path)
    assert r.header["format"] == CODEC_FORMAT
    assert r.header["scheme"] == "zfpx"
    assert r.header["scheme_params"] == {"eps": 1e-3, "device": "host"}
    r.close()


# ---------------------------------------------------------------------------
# Dtype tags (satellite): float64/float16 round-trip; CZ1 defaults to float32
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float64", "float16"])
def test_raw_dtype_round_trips_bit_exact(tmp_path, dtype):
    f = FIELD.astype(dtype)
    spec = CompressionSpec(scheme="raw", block_size=16, dtype=dtype,
                           buffer_bytes=1 << 16)
    pipe = Pipeline(spec)
    comp = pipe.compress(f)
    assert comp.header["dtype"] == dtype
    dec = pipe.decompress(comp)
    assert dec.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(dec, f)

    path = os.path.join(tmp_path, "f.cz")
    container.write_field(path, f, spec)
    out = container.read_field(path)
    assert out.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(out, f)


def test_lossy_scheme_casts_back_to_tagged_dtype():
    spec = CompressionSpec(scheme="wavelet", eps=1e-3, block_size=16,
                           dtype="float64")
    pipe = Pipeline(spec)
    dec = pipe.decompress(pipe.compress(FIELD.astype(np.float64)))
    assert dec.dtype == np.float64
    assert np.max(np.abs(dec - FIELD)) < 1.0


def test_dtype_validation():
    with pytest.raises(ValueError, match="unknown dtype"):
        CompressionSpec(dtype="int32").validate()
    with pytest.raises(ValueError, match="float32"):
        CompressionSpec(scheme="fpzipx", dtype="float64").validate()
    # headers written before the dtype tag default to float32
    legacy = CompressionSpec().to_json()
    del legacy["dtype"]
    assert CompressionSpec.from_json(legacy).dtype == "float32"


# ---------------------------------------------------------------------------
# Parallel chunk workers (satellite): ordered drain, byte-identical output
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["wavelet", "szx", "raw"])
def test_workers_produce_byte_identical_chunks(scheme):
    spec = CompressionSpec(scheme=scheme, eps=1e-3, block_size=16,
                           buffer_bytes=1 << 16)
    blocks = np.asarray(blk.blockify(FIELD, 16))
    serial = list(Pipeline(spec).iter_chunks(blocks))
    for workers in (2, 8):
        assert list(Pipeline(spec, workers=workers).iter_chunks(blocks)) == serial


def test_write_field_workers_byte_identical(tmp_path):
    spec = CompressionSpec(scheme="wavelet", block_size=16,
                           buffer_bytes=1 << 16)
    p1 = os.path.join(tmp_path, "w1.cz")
    p4 = os.path.join(tmp_path, "w4.cz")
    container.write_field(p1, FIELD, spec, workers=1)
    container.write_field(p4, FIELD, spec, workers=4)
    with open(p1, "rb") as a, open(p4, "rb") as b:
        assert a.read() == b.read()


def test_iter_chunks_parallel_is_still_lazy():
    import inspect

    spec = CompressionSpec(scheme="raw", block_size=16, buffer_bytes=1 << 16)
    blocks = np.asarray(blk.blockify(FIELD, 16))
    it = Pipeline(spec, workers=4).iter_chunks(blocks)
    assert inspect.isgenerator(it)
    chunk, nblk = next(it)
    assert isinstance(chunk, bytes) and nblk >= 1
    it.close()  # early close must not deadlock the pool


# ---------------------------------------------------------------------------
# CZ1 back-compat: files written by the seed code still read back bit-exact
# ---------------------------------------------------------------------------

def _write_cz1_legacy(path, field, spec, chunks, nblks):
    """Replicates the seed container writer byte layout (header-first CZ1)."""
    blocks = np.asarray(blk.blockify(np.asarray(field, np.float32),
                                     spec.block_size))
    header = {
        "spec": spec.to_json(),
        "nblocks": int(blocks.shape[0]),
        "chunk_nblocks": nblks,
        "chunk_sizes": [len(c) for c in chunks],
        "raw_bytes": int(blocks.size * 4),
        "field_shape": list(field.shape),
        "chunk_crc32": [zlib.crc32(c) & 0xFFFFFFFF for c in chunks],
    }
    hbytes = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(b"CZ1\0")
        f.write(struct.pack("<Q", len(hbytes)))
        f.write(hbytes)
        for c in chunks:
            f.write(c)


def _legacy_chunks(field, spec, legacy_szx=False):
    """Chunks exactly as the seed codec produced them (v1 byte layout)."""
    spec = spec.validate()
    blocks = np.asarray(blk.blockify(np.asarray(field, np.float32),
                                     spec.block_size))
    sch = get_scheme(spec.scheme)
    s1 = sch.stage1(blocks, spec)
    bpc = max(1, spec.buffer_bytes // (4 * spec.block_size ** 3))
    chunks, nblks = [], []
    for lo in range(0, blocks.shape[0], bpc):
        hi = min(lo + bpc, blocks.shape[0])
        if legacy_szx:
            # v1 szx ignored spec.shuffle: i8 stream + *unshuffled* outliers
            r = s1["res"][lo:hi].reshape(-1)
            small = np.abs(r) <= 127
            stream = np.where(small, r, -128).astype(np.int8)
            outliers = r[~small].astype(np.int32)
            payload = (np.uint32(outliers.size).tobytes() + stream.tobytes()
                       + outliers.tobytes())
        else:
            payload = sch.serialize(s1, lo, hi, spec)
        chunks.append(lossless.encode(payload, spec.stage2))
        nblks.append(hi - lo)
    return chunks, nblks


def test_cz1_raw_reads_back_bit_exact(tmp_path):
    spec = CompressionSpec(scheme="raw", block_size=16, buffer_bytes=1 << 16)
    path = os.path.join(tmp_path, "legacy.cz")
    chunks, nblks = _legacy_chunks(FIELD, spec)
    _write_cz1_legacy(path, FIELD, spec, chunks, nblks)
    np.testing.assert_array_equal(container.read_field(path), FIELD)
    r = container.FieldReader(path)
    assert r.format == 1
    np.testing.assert_array_equal(r.read_all(), FIELD)
    r.close()


def test_cz1_szx_unshuffled_outliers_decode(tmp_path):
    """v1 szx wrote outliers unshuffled even with shuffle='byte' in the spec;
    the scheme's decode_spec shim must keep those files readable."""
    spec = CompressionSpec(scheme="szx", eps=1e-3, shuffle="byte",
                           block_size=16, buffer_bytes=1 << 16)
    path = os.path.join(tmp_path, "legacy_szx.cz")
    chunks, nblks = _legacy_chunks(FIELD, spec, legacy_szx=True)
    _write_cz1_legacy(path, FIELD, spec, chunks, nblks)
    out = container.read_field(path)
    assert np.max(np.abs(out - FIELD)) <= spec.eps * (1 + 1e-4) + _ulp(FIELD)


def test_cz2_szx_shuffles_outliers():
    """Format 2 applies spec.shuffle to the szx outlier stream (satellite fix):
    same stage-1 data must serialize differently for byte vs none shuffle."""
    spec_b = CompressionSpec(scheme="szx", eps=1e-4, shuffle="byte",
                             block_size=16, stage2="none")
    spec_n = CompressionSpec(scheme="szx", eps=1e-4, shuffle="none",
                             block_size=16, stage2="none")
    sch = get_scheme("szx")
    s1 = sch.stage1(np.asarray(blk.blockify(FIELD, 16)), spec_b)
    n_out = int(np.frombuffer(sch.serialize(s1, 0, 2, spec_n)[:4], np.uint32)[0])
    assert n_out > 0, "field must produce szx outliers for this test"
    assert sch.serialize(s1, 0, 2, spec_b) != sch.serialize(s1, 0, 2, spec_n)
    # and both layouts round-trip under their own spec
    for spec in (spec_b, spec_n):
        pipe = Pipeline(spec)
        dec = pipe.decompress(pipe.compress(FIELD))
        assert np.max(np.abs(dec - FIELD)) <= spec.eps * (1 + 1e-4) + _ulp(FIELD)
