"""MoE dispatch invariants (hypothesis property tests)."""
import numpy as np
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip cleanly on a bare interpreter
    from _hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig
from repro.models.moe import moe_ffn
from repro.models.transformer import _moe_leaves
from repro.models.common import Maker


def make_cfg(E, K, g=32):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=E, top_k=K, moe_group=g)


@settings(max_examples=12, deadline=None)
@given(E=st.sampled_from([4, 8]), K=st.sampled_from([1, 2]),
       seed=st.integers(0, 1000))
def test_moe_invariants(E, K, seed):
    cfg = make_cfg(E, K)
    mk = Maker("init", key=jax.random.PRNGKey(seed), dtype=jnp.float32)
    p = _moe_leaves(mk, cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    out, aux = moe_ffn(x, p, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert 0.0 <= float(aux["drop_fraction"]) <= 1.0
    assert float(aux["load_balance"]) >= 0.9  # >= 1 at perfect balance * E^2/K norm
    assert np.isfinite(float(aux["router_z"]))


def test_moe_capacity_drops_when_unbalanced():
    """Force every token to one expert -> most assignments drop."""
    cfg = make_cfg(E=8, K=1)
    mk = Maker("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    p = _moe_leaves(mk, cfg)
    # router weights that always pick expert 0
    router = np.zeros((16, 8), np.float32)
    router[:, 0] = 10.0
    p = dict(p)
    p["router"] = jnp.asarray(router)
    x = jnp.ones((2, 32, 16), jnp.float32)
    out, aux = moe_ffn(x, p, cfg)
    assert float(aux["drop_fraction"]) > 0.5


def test_moe_grad_flows_to_experts():
    cfg = make_cfg(E=4, K=2)
    mk = Maker("init", key=jax.random.PRNGKey(1), dtype=jnp.float32)
    p = _moe_leaves(mk, cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 32, 16)),
                    jnp.float32)

    def loss(p):
        out, aux = moe_ffn(x, p, cfg)
        return (out ** 2).sum() + 0.01 * aux["load_balance"]

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["we1"]))) > 0
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
