"""Dataset manifest: one JSON object, committed atomically.

The manifest is the *only* mutable object in a CZDataset.  Member objects
are immutable once written; a timestep exists iff the manifest references
it, so the commit protocol is write-members -> ``Store.put_atomic`` of the
manifest.  On a file backend that is the historical tmp + fsync + rename +
directory-fsync sequence; on an object store a single PUT is already
atomic.  A crash between member write and manifest commit leaves orphaned
member objects but never a dataset that references missing or partial
data.

Every function here takes ``root`` as either a local path / store URL or a
:class:`~repro.store.backends.Store` instance — one code path for every
backend.

Rank sidecars (``manifest.rank{r}.json``) extend the same protocol to
multi-writer runs: each rank commits its own sidecar atomically, with no
contention on ``manifest.json``, and a coordinator later folds them into
the main manifest (``repro.cluster.multiwriter.merge_manifests``).  A
sidecar entry is *live* — :meth:`CZDataset.gc` must not collect its member
— until the merge commits it and deletes the sidecar.
"""
from __future__ import annotations

import json
import re

from .backends import Store, StoreKeyError, open_store

__all__ = ["MANIFEST_NAME", "MANIFEST_FORMAT", "QUANTITY_RE", "ManifestError",
           "new_manifest", "read_manifest", "write_manifest",
           "RANK_MANIFEST_RE", "rank_manifest_name", "list_rank_manifests",
           "new_rank_manifest", "read_rank_manifest", "write_rank_manifest"]

MANIFEST_NAME = "manifest.json"
MANIFEST_FORMAT = 1

#: legal quantity names (also member key prefixes); the lookahead rejects
#: all-dot names ('.', '..') that would escape the dataset root
QUANTITY_RE = re.compile(r"^(?!\.+$)[A-Za-z0-9_.\-]+$")

RANK_MANIFEST_RE = re.compile(r"^manifest\.rank(\d+)\.json$")


class ManifestError(IOError):
    """The dataset manifest is missing, unreadable, or structurally invalid."""


def _store(root) -> Store:
    return root if isinstance(root, Store) else open_store(root)


def new_manifest(spec_json: dict) -> dict:
    return {
        "magic": "CZDS",
        "format": MANIFEST_FORMAT,
        "version": 0,          # bumped on every commit
        "next_t": 0,           # next timestep index to assign
        "spec": spec_json,     # dataset-default CompressionSpec
        "quantities": {},      # name -> {shape, dtype, timesteps: [...]}
    }


def _check(m: dict, where: str) -> dict:
    if not isinstance(m, dict) or m.get("magic") != "CZDS":
        raise ManifestError(
            f"{where}/{MANIFEST_NAME} is not a CZDataset manifest (bad magic)")
    if int(m.get("format", 0)) > MANIFEST_FORMAT:
        raise ManifestError(
            f"manifest format {m['format']} is newer than supported "
            f"({MANIFEST_FORMAT}) — upgrade repro to read {where}")
    for key in ("version", "next_t", "spec", "quantities"):
        if key not in m:
            raise ManifestError(f"manifest in {where} is missing {key!r}")
    for q, ent in m["quantities"].items():
        for key in ("shape", "dtype", "timesteps"):
            if key not in ent:
                raise ManifestError(
                    f"manifest entry for quantity {q!r} is missing {key!r}")
    return m


def _load_json(store: Store, key: str, what: str) -> dict:
    data = store.get(key)  # StoreKeyError propagates to the caller
    try:
        return json.loads(data)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ManifestError(f"corrupt {what} {store.url}/{key}: {e}") from None


def read_manifest(root) -> dict:
    store = _store(root)
    try:
        m = _load_json(store, MANIFEST_NAME, "manifest")
    except StoreKeyError:
        raise ManifestError(
            f"no {MANIFEST_NAME} in {store.url} — not a CZDataset "
            "(or the first commit never completed)") from None
    return _check(m, store.url)


def _atomic_json(store: Store, name: str, obj: dict) -> None:
    """``put_atomic`` of an indented-JSON object — the commit primitive
    shared by the main manifest and the per-rank sidecars."""
    store.put_atomic(name, json.dumps(obj, indent=1).encode())


def write_manifest(root, manifest: dict) -> None:
    """Atomic commit through ``Store.put_atomic`` (file backends: tmp write
    + fsync + rename + directory fsync; object stores: one PUT).  Member
    objects are made durable by :class:`~repro.store.ShardWriter` before
    this is called."""
    _atomic_json(_store(root), MANIFEST_NAME, manifest)


# -- per-rank sidecars -------------------------------------------------------

def rank_manifest_name(rank: int) -> str:
    return f"manifest.rank{int(rank)}.json"


def list_rank_manifests(root) -> list[int]:
    """Ranks with a committed sidecar in ``root``, ascending."""
    ranks = []
    for key in _store(root).list("manifest.rank"):
        m = RANK_MANIFEST_RE.match(key)
        if m:
            ranks.append(int(m.group(1)))
    return sorted(ranks)


def new_rank_manifest(rank: int) -> dict:
    return {"magic": "CZRK", "format": MANIFEST_FORMAT,
            "rank": int(rank), "entries": []}


def read_rank_manifest(root, rank: int) -> dict:
    store = _store(root)
    name = rank_manifest_name(rank)
    try:
        side = _load_json(store, name, "rank sidecar")
    except StoreKeyError:
        # historical contract: a missing sidecar is FileNotFoundError, on
        # every backend
        raise FileNotFoundError(f"{store.url}/{name}") from None
    if not isinstance(side, dict) or side.get("magic") != "CZRK":
        raise ManifestError(f"{name} in {store.url} is not a rank sidecar "
                            "(bad magic)")
    if int(side.get("rank", -1)) != int(rank):
        raise ManifestError(
            f"{name} claims rank {side.get('rank')}, expected {rank}")
    for e in side.get("entries", []):
        for key in ("quantity", "t", "time", "file", "bytes", "raw_bytes",
                    "shape", "dtype"):
            if key not in e:
                raise ManifestError(f"sidecar entry in {name} missing {key!r}")
    return side


def write_rank_manifest(root, side: dict) -> None:
    """Atomic sidecar commit — a rank's private, contention-free analogue of
    :func:`write_manifest`."""
    _atomic_json(_store(root), rank_manifest_name(side["rank"]), side)
