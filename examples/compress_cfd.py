"""Ex-situ compression of CFD output (the CubismZ tool use case):
compress all four QoIs into CZ2 containers — the writer streams chunks from
``Pipeline.iter_chunks``, so the compressed chunk list is never held in
memory — then random-access one block through the chunk cache without
decompressing the file.

Run:  PYTHONPATH=src python examples/compress_cfd.py
"""
import os

from repro.core import CompressionSpec, container
from repro.fields import CloudConfig, cavitation_fields

out = "artifacts/example_fields"
os.makedirs(out, exist_ok=True)
fields = cavitation_fields(CloudConfig(n=64), t=9.4)
spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                       block_size=32, shuffle="byte")

for q, f in fields.items():
    path = os.path.join(out, f"{q}.cz")
    # streaming write: field -> Pipeline.iter_chunks -> disk, chunk by chunk
    nbytes = container.write_field(path, f, spec)
    print(f"{q:4s}: {f.nbytes/2**20:.1f} MiB -> {nbytes/2**20:.2f} MiB "
          f"(CR {f.nbytes/nbytes:.1f}x) -> {path}")

# random block access via the decompression chunk cache (paper §2.3);
# the reader dispatches on the scheme recorded in the CZ2 header
r = container.FieldReader(os.path.join(out, "p.cz"))
block = r.read_block(1, 0, 1)
print(f"block (1,0,1): shape {block.shape}, mean {block.mean():.3f}, "
      f"scheme {r.header['scheme']!r} (format {r.format}), "
      f"cache hits/misses = {r.cache_hits}/{r.cache_misses}")
r.close()
