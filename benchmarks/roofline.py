"""§Roofline — three-term roofline per (arch x shape) from the dry-run.

Terms (seconds per step, per chip; single-pod 16x16 mesh):

  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (50 GB/s per ICI link; we
               charge one link — conservative single-direction model)

All three inputs are **loop-aware** (benchmarks/../repro/launch/hlo_analysis
multiplies while-loop bodies by their trip counts; stock cost_analysis()
counts scan bodies once and under-reports a 64-layer model ~40x — see
EXPERIMENTS.md §Dry-run).

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (decode); the
ratio MODEL_FLOPS / HLO_FLOPs exposes redundant compute (masked-causal
waste, remat recompute, attention replicated when head counts don't shard).

roofline_fraction = ideal_useful_compute_time / max(term) — the score: how
close the lowered step is to a perfectly-efficient, useful-compute-bound
execution on this hardware.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts")


def _advice(dom: str, row: dict) -> str:
    if dom == "compute":
        if row["useful_ratio"] < 0.5:
            return ("cut redundant FLOPs: triangular causal attention, less "
                    "remat, shard attention over seq (context parallelism)")
        return "compute-bound at high usefulness: increase arithmetic intensity or accept"
    if dom == "memory":
        return ("raise arithmetic intensity: larger per-step tiles, fuse "
                "elementwise chains, keep weights resident (reduce regathers)")
    return ("shrink collective bytes: 2-axis FSDP regathers dominate — "
            "overlap all-gathers with compute, or compress payloads "
            "(gradient compression / bf16 collectives)")


def load_cells(mesh: str = "single", tag: str = ""):
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", f"*{suffix}"))):
        base = os.path.basename(p)
        if not tag and base.count("__") != 2:
            continue
        d = json.load(open(p))
        if not d.get("applicable"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "skipped": d.get("skip_reason", "")})
            continue
        if "error" in d:
            continue
        la = d["loop_aware"]
        n_dev = d["n_devices"]
        hbm = la.get("hbm_bytes_fused_per_device", la["hbm_bytes_per_device"])
        t_c = la["flops_per_device"] / PEAK_FLOPS
        t_m = hbm / HBM_BW
        t_l = la["collective_bytes_per_device"] / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_l}
        dom = max(terms, key=terms.get)
        mf_dev = d["model_flops"] / n_dev
        useful = mf_dev / max(la["flops_per_device"], 1e-9)
        t_star = max(terms.values())
        if d["kind"] == "decode":
            # decode is legitimately bandwidth-bound: score vs the minimal
            # traffic floor (params once + cache once per step, bf16)
            ideal_bytes = (2.0 * d["params_active"] / n_dev
                           + _cache_bytes(d) / n_dev)
            frac = (ideal_bytes / HBM_BW) / max(t_star, 1e-12)
        else:
            frac = (mf_dev / PEAK_FLOPS) / max(t_star, 1e-12)
        row = {
            "arch": d["arch"], "shape": d["shape"], "mesh": mesh,
            "kind": d["kind"],
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
            "dominant": dom, "useful_ratio": useful,
            "roofline_fraction": frac,
            "model_flops_per_dev": mf_dev,
            "hlo_flops_per_dev": la["flops_per_device"],
            "hbm_bytes_per_dev": hbm,
            "hbm_bytes_unfused_per_dev": la["hbm_bytes_per_device"],
            "attn_score_bytes_per_dev": la.get("attn_score_bytes_per_device", 0),
            "coll_bytes_per_dev": la["collective_bytes_per_device"],
            "mem_gib": (d["memory"]["argument_bytes"] + d["memory"]["temp_bytes"]
                        + d["memory"]["output_bytes"]) / 2**30,
        }
        row["advice"] = _advice(dom, row)
        rows.append(row)
    return rows


def _cache_bytes(d) -> float:
    """Global KV/state cache bytes for a decode cell (bf16/f32 mixed)."""
    from repro.configs import ARCHS, SHAPES
    from repro.models import cache_spec

    cfg = ARCHS[d["arch"]]
    shape = SHAPES[d["shape"]]
    import jax

    specs = cache_spec(cfg, shape.global_batch, shape.seq_len, mode="spec")
    total = 0
    for leaf in jax.tree.leaves(specs):
        n = 1
        for x in leaf.shape:
            n *= x
        total += n * leaf.dtype.itemsize
    return float(total)


def render(rows, title="Roofline (single-pod 16x16, per chip)"):
    out = [f"### {title}", ""]
    out.append("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
               "| useful (MODEL/HLO) | roofline_frac | mem GiB |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_gib']:.1f} |")
    return "\n".join(out)


def load_variants():
    """Tagged hillclimb cells (arch__shape__single__tag.json)."""
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", "*__single__*.json"))):
        tag = os.path.basename(p).split("__")[-1][:-5]
        d = json.load(open(p))
        if "error" in d or not d.get("applicable"):
            continue
        la = d["loop_aware"]
        hbm = la.get("hbm_bytes_fused_per_device", la["hbm_bytes_per_device"])
        terms = {"compute": la["flops_per_device"] / PEAK_FLOPS,
                 "memory": hbm / HBM_BW,
                 "collective": la["collective_bytes_per_device"] / LINK_BW}
        mf = d["model_flops"] / d["n_devices"]
        rows.append({"arch": d["arch"], "shape": d["shape"], "tag": tag,
                     **{f"{k}_s": v for k, v in terms.items()},
                     "roofline_fraction": (mf / PEAK_FLOPS) / max(terms.values(), key=abs)
                     if max(terms.values()) > 0 else 0.0})
        rows[-1]["roofline_fraction"] = (mf / PEAK_FLOPS) / max(terms.values())
    return rows


def render_variants(rows):
    out = ["", "### §Perf variant cells (tagged artifacts)", "",
           "| arch | shape | variant | compute_s | memory_s | collective_s | roofline_frac |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['arch']} | {r['shape']} | {r['tag']} | "
                   f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
                   f"{r['collective_s']:.3e} | {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    rows = load_cells("single")
    md = render(rows) + render_variants(load_variants())
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "roofline.md"), "w") as f:
        f.write(md + "\n")
    print(md)
    real = [r for r in rows if "skipped" not in r]
    if real:
        worst = min(real, key=lambda r: r["roofline_fraction"])
        collb = max(real, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:  {collb['arch']} x {collb['shape']} "
              f"(coll/comp = {collb['collective_s']/max(collb['compute_s'],1e-12):.2f})")
    with open(os.path.join(ART, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)


if __name__ == "__main__":
    main()
