"""Back-compat codec facade over the scheme registry and Pipeline.

The two-substage pipeline itself lives in :mod:`repro.core.pipeline`; the
per-scheme device transforms and byte layouts live in
:mod:`repro.core.schemes` (one self-registering module per scheme).  This
module keeps the original seed-era entry points — ``compress_field``,
``decompress_field``, ``compress_blocks``, ``decompress_blocks``,
``analyze_field``, ``CompressionSpec`` — as thin wrappers so existing call
sites keep working unchanged.  New code should use :class:`Pipeline`.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from .pipeline import (  # noqa: F401  (re-exports)
    CODEC_FORMAT,
    CompressedField,
    CompressionSpec,
    Pipeline,
)
from .schemes import SCHEMES  # noqa: F401  (live registry view)

__all__ = ["CompressionSpec", "CompressedField", "Pipeline", "CODEC_FORMAT",
           "compress_field", "decompress_field", "compress_blocks",
           "decompress_blocks", "analyze_field", "SCHEMES"]


def compress_blocks(blocks_np: np.ndarray, spec: CompressionSpec,
                    extra_header: dict | None = None) -> CompressedField:
    return Pipeline(spec).compress_blocks(blocks_np, extra_header)


def decompress_blocks(comp: CompressedField) -> np.ndarray:
    return Pipeline(comp.spec).decompress_blocks(comp)


def compress_field(field: np.ndarray, spec: CompressionSpec) -> CompressedField:
    return Pipeline(spec).compress_field(field)


def decompress_field(comp: CompressedField) -> np.ndarray:
    return Pipeline(comp.spec).decompress(comp)


def analyze_field(field: np.ndarray, spec: CompressionSpec) -> dict[str, Any]:
    """Compress + decompress + measure (CR, PSNR, error bound) in one call."""
    return Pipeline(spec).analyze(field)
