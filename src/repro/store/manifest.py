"""Dataset manifest: one JSON file, committed atomically.

The manifest is the *only* mutable object in a CZDataset.  Member files are
immutable once written; a timestep exists iff the manifest references it, so
the commit protocol is write-members -> write ``manifest.json.tmp`` -> fsync
-> ``os.replace``.  A crash between member write and manifest commit leaves
orphaned member files but never a dataset that references missing or partial
data.
"""
from __future__ import annotations

import json
import os

__all__ = ["MANIFEST_NAME", "MANIFEST_FORMAT", "ManifestError",
           "new_manifest", "read_manifest", "write_manifest"]

MANIFEST_NAME = "manifest.json"
MANIFEST_FORMAT = 1


class ManifestError(IOError):
    """The dataset manifest is missing, unreadable, or structurally invalid."""


def new_manifest(spec_json: dict) -> dict:
    return {
        "magic": "CZDS",
        "format": MANIFEST_FORMAT,
        "version": 0,          # bumped on every commit
        "next_t": 0,           # next timestep index to assign
        "spec": spec_json,     # dataset-default CompressionSpec
        "quantities": {},      # name -> {shape, dtype, timesteps: [...]}
    }


def _check(m: dict, root: str) -> dict:
    if not isinstance(m, dict) or m.get("magic") != "CZDS":
        raise ManifestError(
            f"{os.path.join(root, MANIFEST_NAME)} is not a CZDataset manifest "
            "(bad magic)")
    if int(m.get("format", 0)) > MANIFEST_FORMAT:
        raise ManifestError(
            f"manifest format {m['format']} is newer than supported "
            f"({MANIFEST_FORMAT}) — upgrade repro to read {root}")
    for key in ("version", "next_t", "spec", "quantities"):
        if key not in m:
            raise ManifestError(f"manifest in {root} is missing {key!r}")
    for q, ent in m["quantities"].items():
        for key in ("shape", "dtype", "timesteps"):
            if key not in ent:
                raise ManifestError(
                    f"manifest entry for quantity {q!r} is missing {key!r}")
    return m


def read_manifest(root: str) -> dict:
    path = os.path.join(root, MANIFEST_NAME)
    try:
        with open(path) as f:
            m = json.load(f)
    except FileNotFoundError:
        raise ManifestError(f"no {MANIFEST_NAME} in {root} — not a CZDataset "
                            "(or the first commit never completed)") from None
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ManifestError(f"corrupt manifest {path}: {e}") from None
    return _check(m, root)


def write_manifest(root: str, manifest: dict) -> None:
    """Atomic commit: tmp write + fsync + rename over the old manifest, then
    fsync the directory so the rename itself is durable.  (Member files are
    fsynced by :class:`~repro.store.ShardWriter` before this is called.)"""
    path = os.path.join(root, MANIFEST_NAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(root, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
