"""repro.cluster coverage: rank-count invariance of the shared-file engine
(bit-identical to the serial writer for every registered scheme), block-
aligned domain decomposition round-trips at odd grid sizes, per-rank
manifest sidecars with crash-mid-merge recovery, and gc on a torn dataset.
"""
import json
import multiprocessing
import os

import numpy as np
import pytest

from repro.core import CompressionSpec, SCHEMES, container
from repro.cluster import (
    ParallelCompressor,
    RankWriter,
    Subdomain,
    chunk_spans,
    decompose,
    dims_for,
    gather,
    merge_manifests,
    scatter,
)
from repro.cluster import multiwriter as mw
from repro.store import CZDataset, DtypeCoercionWarning, ManifestError

from test_pipeline_api import smooth_field

BS = 16
FIELD = smooth_field(32, seed=3)
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 14)


def _spec(scheme: str) -> CompressionSpec:
    # 16 KiB buffers -> 1 block per chunk at 16^3 float32: enough chunks
    # that every rank count below gets a non-trivial span
    return CompressionSpec(scheme=scheme, eps=1e-3, block_size=BS,
                           buffer_bytes=1 << 14)


@pytest.fixture(scope="module")
def engine():
    """One shared 4-rank pool for the whole module — worker spawn (a fresh
    interpreter + jax import per rank) is paid once, not per test."""
    with ParallelCompressor(4) as pc:
        yield pc


# ---------------------------------------------------------------------------
# Acceptance: rank-count invariance for every registered scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_rank_invariance_byte_identical(engine, scheme, tmp_path):
    spec = _spec(scheme)
    serial = os.path.join(tmp_path, "serial.cz")
    n_serial = container.write_field(serial, FIELD, spec)
    with open(serial, "rb") as f:
        ref = f.read()
    for ranks in (1, 2, 4):
        path = os.path.join(tmp_path, f"r{ranks}.cz")
        n = engine.compress(path, FIELD, spec, ranks=ranks)
        assert n == n_serial
        with open(path, "rb") as f:
            assert f.read() == ref, \
                f"{scheme} ranks={ranks} differs from the serial writer"
    # and the shared file reads back like any other container
    dec = container.read_field(os.path.join(tmp_path, "r4.cz"))
    assert dec.shape == FIELD.shape


def test_rank_invariance_auto_mixed_schemes(engine, tmp_path):
    """Acceptance: the ``auto`` meta-scheme keeps the engine's byte-identity
    guarantee even when its per-chunk decisions actually mix schemes — the
    tuner's choice is a pure function of chunk content, never of rank."""
    # regimes aligned with the 16^3 block raster so different chunks
    # genuinely favor different schemes (constant octant -> raw wins at
    # rel targets, noise octant -> lorenzo, smooth elsewhere -> szx)
    rng = np.random.default_rng(7)
    field = np.asarray(FIELD, np.float32).copy()
    field[:16, :16, :16] = 0.125
    field[16:, 16:, 16:] = rng.normal(0, 0.4, (16, 16, 16)).astype(np.float32)
    spec = CompressionSpec(scheme="auto", eps=1e-3, block_size=BS,
                           buffer_bytes=1 << 14,
                           extra={"target": "rel=1e-4"})
    serial = os.path.join(tmp_path, "serial.cz")
    n_serial = container.write_field(serial, field, spec)
    with open(serial, "rb") as f:
        ref = f.read()
    for ranks in (1, 2, 4):
        path = os.path.join(tmp_path, f"r{ranks}.cz")
        n = engine.compress(path, field, spec, ranks=ranks)
        assert n == n_serial
        with open(path, "rb") as f:
            assert f.read() == ref, \
                f"auto ranks={ranks} differs from the serial writer"
    d = container.describe(os.path.join(tmp_path, "r4.cz"))
    assert len(d["schemes"]) >= 2, f"expected a scheme mix, got {d['schemes']}"
    assert sum(d["schemes"].values()) == len(d["chunks"])
    dec = container.read_field(os.path.join(tmp_path, "r4.cz"))
    rngv = float(field.max() - field.min())
    assert np.max(np.abs(field - dec)) <= 1e-4 * rngv * (1 + 1e-6)


def test_engine_more_ranks_than_chunks(engine, tmp_path):
    """Ranks beyond the chunk count contribute zero bytes, not corruption."""
    spec = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 22)
    serial = os.path.join(tmp_path, "s.cz")
    par = os.path.join(tmp_path, "p.cz")
    container.write_field(serial, FIELD, spec)
    engine.compress(par, FIELD, spec, ranks=4)
    with open(serial, "rb") as a, open(par, "rb") as b:
        assert a.read() == b.read()


def test_engine_extra_header_and_plan(engine, tmp_path):
    spec = _spec("raw")
    path = os.path.join(tmp_path, "h.cz")
    engine.compress(path, FIELD, spec, extra_header={"quantity": "p"},
                    ranks=2, fsync=True)
    with container.FieldReader(path) as r:
        assert r.header["quantity"] == "p"
    plan = engine.plan(FIELD.shape, spec, ranks=4)
    assert [p["rank"] for p in plan] == [0, 1, 2, 3]
    assert sum(p["nblocks"] for p in plan) == 8  # 32^3 / 16^3
    assert plan[0]["blocks"][0] == 0


def test_engine_rejects_bad_ranks(engine):
    with pytest.raises(ValueError, match="ranks"):
        engine.compress("/tmp/x.cz", FIELD, SPEC, ranks=8)
    with pytest.raises(ValueError, match="ranks"):
        ParallelCompressor(0)


@pytest.mark.device
@pytest.mark.parametrize("scheme", ["lorenzo", "wavelet"])
def test_rank_invariance_holds_for_device_specs(engine, scheme, tmp_path):
    """Acceptance: device='jax' specs keep the engine's core guarantee —
    the shared file is byte-identical to the serial writer at every rank
    count (workers route stage 1 through the same jitted kernels)."""
    spec = CompressionSpec(scheme=scheme, device="jax", eps=1e-3,
                           block_size=BS, buffer_bytes=1 << 14)
    serial = os.path.join(tmp_path, "serial.cz")
    container.write_field(serial, FIELD, spec)
    with open(serial, "rb") as f:
        ref = f.read()
    for ranks in (1, 2, 4):
        path = os.path.join(tmp_path, f"r{ranks}.cz")
        engine.compress(path, FIELD, spec, ranks=ranks)
        with open(path, "rb") as f:
            assert f.read() == ref, \
                f"{scheme} device=jax ranks={ranks} differs from serial"
    # ...and the device-written shared file decodes on host
    dec = container.read_field(os.path.join(tmp_path, "r4.cz"), device="host")
    assert dec.shape == FIELD.shape


def test_engine_worker_failure_leaves_no_debris(engine, tmp_path):
    """A rank hitting an encode error must not leak part files or a
    headerless stub output."""
    # szx rejects an eps this small for FIELD's magnitude — inside stage1,
    # i.e. in the workers, after spec.validate() passed in the parent
    bad = CompressionSpec(scheme="szx", eps=1e-12, block_size=BS,
                          buffer_bytes=1 << 14)
    path = os.path.join(tmp_path, "fail.cz")
    with pytest.raises(ValueError, match="too small"):
        engine.compress(path, FIELD, bad, ranks=2)
    assert os.listdir(tmp_path) == []


# ---------------------------------------------------------------------------
# Domain decomposition round-trips (odd grid sizes, all layouts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["slab", "pencil", "brick"])
@pytest.mark.parametrize("shape,ranks", [
    ((96, 64, 32), 5),   # unequal axes, rank count that divides nothing
    ((96, 64, 32), 6),
    ((32, 96, 64), 2),
    ((64, 64, 64), 1),
])
def test_decompose_scatter_gather_round_trip(layout, shape, ranks):
    subs = decompose(shape, ranks, BS, layout)
    assert len(subs) == ranks
    assert [s.rank for s in subs] == list(range(ranks))
    # block-aligned, disjoint, covering
    for s in subs:
        assert all(v % BS == 0 for v in s.lo + s.hi)
        assert all(a < b for a, b in zip(s.lo, s.hi))
    assert sum(s.nvoxels for s in subs) == int(np.prod(shape))

    field = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    parts = scatter(field, subs)
    for part, s in zip(parts, subs):
        assert part.shape == s.shape
    np.testing.assert_array_equal(gather(parts, subs), field)
    np.testing.assert_array_equal(gather(parts, subs, shape), field)


def test_decompose_rejects_oversplit():
    with pytest.raises(ValueError, match="only 2 blocks"):
        decompose((32, 32, 32), 3, BS, "slab")
    with pytest.raises(ValueError, match="unknown layout"):
        decompose((32, 32, 32), 2, BS, "diagonal")


def test_decompose_matches_factors_to_axis_block_counts():
    """A short leading axis must not reject a feasible rank count: the
    big rank-grid factor goes to the axis with the most block layers."""
    subs = decompose((32, 96, 64), 6, BS, "pencil")  # x has only 2 layers
    assert len(subs) == 6
    assert sum(s.nvoxels for s in subs) == 32 * 96 * 64
    field = np.arange(32 * 96 * 64, dtype=np.float32).reshape(32, 96, 64)
    np.testing.assert_array_equal(gather(scatter(field, subs), subs), field)


def test_dims_for_balanced():
    assert dims_for(8, 3) == (2, 2, 2)
    assert dims_for(12, 3) == (3, 2, 2)
    assert dims_for(6, 2) == (3, 2)
    assert dims_for(5, 2) == (5, 1)
    assert dims_for(1, 3) == (1, 1, 1)


def test_chunk_spans_cover_and_balance():
    for nchunks, ranks in [(8, 4), (7, 3), (2, 4), (0, 2), (5, 1)]:
        spans = chunk_spans(nchunks, ranks)
        assert len(spans) == ranks
        assert spans[0][0] == 0 and spans[-1][1] == nchunks
        for (_, a), (b, _) in zip(spans, spans[1:]):
            assert a == b  # contiguous
        lens = [hi - lo for lo, hi in spans]
        assert max(lens) - min(lens) <= 1  # balanced to within one chunk


def test_gather_shape_mismatch():
    subs = [Subdomain(0, (0, 0, 0), (16, 32, 32)),
            Subdomain(1, (16, 0, 0), (32, 32, 32))]
    with pytest.raises(ValueError, match="rank 1"):
        gather([np.zeros((16, 32, 32), np.float32),
                np.zeros((16, 16, 32), np.float32)], subs)


# ---------------------------------------------------------------------------
# Multi-writer: per-rank sidecars + atomic merge
# ---------------------------------------------------------------------------

def _make_dataset(root):
    with CZDataset(root, "a", spec=SPEC):
        pass  # coordinator creates the dataset (manifest + committed spec)


def test_rank_writers_merge_into_one_manifest(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    fields = {0: {"p": FIELD}, 1: {"rho": FIELD + 1}}
    for rank, fs in fields.items():
        with RankWriter(root, rank) as w:
            for t in range(2):
                w.append({q: f + np.float32(t) for q, f in fs.items()},
                         t=t, time=9.4 + t)
            assert w.pending == 2

    # sidecar commits are invisible until the merge
    with CZDataset(root) as ds:
        assert ds.quantities == []
    assert merge_manifests(root) == 4
    with CZDataset(root) as ds:
        assert ds.quantities == ["p", "rho"]
        assert ds.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds.read_field("rho", 1),
                                      (FIELD + 1) + np.float32(1))
        assert ds.version == 1
        # next append continues past the merged timesteps
    with CZDataset(root, "a") as ds:
        assert ds.append({"p": FIELD, "rho": FIELD}) == 2

    # sidecars are retired; a re-run merges nothing and stays idempotent
    assert merge_manifests(root) == 0


def test_merge_crash_midway_leaves_dataset_readable(tmp_path, monkeypatch):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with CZDataset(root, "a") as ds:
        ds.append({"p": FIELD})  # one committed timestep pre-crash
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD + 1}, t=1)

    def boom(*a, **k):
        raise RuntimeError("simulated crash before the manifest commit")

    monkeypatch.setattr(mw, "write_manifest", boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        merge_manifests(root)
    monkeypatch.undo()

    # the dataset still reads at its last committed state...
    with CZDataset(root) as ds:
        assert ds.timesteps("p") == [0]
    # ...the sidecar survived, and a re-run completes the merge
    assert merge_manifests(root) == 1
    with CZDataset(root) as ds:
        assert ds.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds.read_field("p", 1), FIELD + 1)


def test_merge_conflict_and_missing_member_raise(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w0, RankWriter(root, 1) as w1:
        w0.append({"p": FIELD}, t=0)
        w1.append({"p": FIELD + 1}, t=0)  # different member, same (q, t)
    with pytest.raises(ManifestError, match="merge conflict"):
        merge_manifests(root)
    # nothing was committed by the failed merge
    with CZDataset(root) as ds:
        assert ds.quantities == []

    os.unlink(os.path.join(root, "manifest.rank1.json"))
    os.unlink(os.path.join(root, "p", "t000000.r0.cz"))  # torn member
    with pytest.raises(ManifestError, match="missing member"):
        merge_manifests(root)


def test_rank_writer_refuses_member_overwrite(tmp_path):
    """Members are immutable: a restarted rank replaying an already-merged
    timestep must error out, not tear the committed member in place."""
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)
    merge_manifests(root)
    with RankWriter(root, 0) as w:  # fresh sidecar after the merge
        with pytest.raises(IOError, match="already exists"):
            w.append({"p": FIELD + 1}, t=0)
    with CZDataset(root) as ds:  # the committed member is untouched
        np.testing.assert_array_equal(ds.read_field("p", 0), FIELD)


def test_merge_keeps_entries_committed_during_merge(tmp_path, monkeypatch):
    """A rank may commit new sidecar entries between the merge's read and
    its sidecar retirement — those entries must survive, not be unlinked."""
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)

    real = mw.read_rank_manifest
    state = {"calls": 0}

    def racy(r, rank):
        state["calls"] += 1
        side = real(r, rank)
        if state["calls"] == 1:  # rank commits t=1 right after the scan read
            with RankWriter(root, 0) as w2:
                w2.append({"p": FIELD + 1}, t=1)
        return side

    monkeypatch.setattr(mw, "read_rank_manifest", racy)
    assert merge_manifests(root) == 1  # merged t=0 only
    monkeypatch.undo()

    side = real(root, 0)  # sidecar survived, holding exactly the new entry
    assert [e["t"] for e in side["entries"]] == [1]
    assert merge_manifests(root) == 1  # and the late entry merges cleanly
    with CZDataset(root) as ds:
        assert ds.timesteps("p") == [0, 1]


def test_append_on_stale_handle_preserves_merged_entries(tmp_path):
    """An append-mode handle opened before a merge must not clobber the
    merge's commits with its stale in-memory manifest."""
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    ds = CZDataset(root, "a")  # opened before the rank entries exist
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)
    assert merge_manifests(root) == 1
    assert ds.append({"p": FIELD + 1}) == 1  # past the merged timestep
    ds.close()
    with CZDataset(root) as ds2:
        assert ds2.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds2.read_field("p", 0), FIELD)


def test_long_lived_writer_does_not_resurrect_merged_entries(tmp_path):
    """A writer that stays open across merges must commit only its unmerged
    entries — not replay its whole history into a fresh sidecar."""
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)
        assert merge_manifests(root) == 1  # retires the sidecar
        assert w.pending == 0
        w.append({"p": FIELD + 1}, t=1)
        assert w.pending == 1
        assert [e["t"] for e in mw.read_rank_manifest(root, 0)["entries"]] \
            == [1]
        assert merge_manifests(root) == 1
        assert w.pending == 0
    with CZDataset(root) as ds:
        assert ds.timesteps("p") == [0, 1]


def test_sidecar_entry_missing_key_is_manifest_error(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)
    side_path = os.path.join(root, "manifest.rank0.json")
    side = json.load(open(side_path))
    del side["entries"][0]["time"]
    json.dump(side, open(side_path, "w"))
    with pytest.raises(ManifestError, match="missing 'time'"):
        merge_manifests(root)


def test_rank_writer_rejects_bad_appends(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD}, t=0)
        with pytest.raises(ValueError, match="already appended"):
            w.append({"p": FIELD}, t=0)
        for evil in ("../evil", "..", "."):  # path escapes from the root
            with pytest.raises(ValueError, match="invalid quantity"):
                w.append({evil: FIELD}, t=1)
        with pytest.raises(ValueError, match="at least one"):
            w.append({}, t=1)
    with pytest.raises(ManifestError):
        RankWriter(os.path.join(tmp_path, "nowhere"), 0)  # dataset must exist


def test_merge_rejects_dtype_drift(tmp_path):
    """A rank appending a different dtype for a committed quantity must fail
    the merge, not silently corrupt the quantity-level dtype tag."""
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with CZDataset(root, "a") as ds:
        ds.append({"p": FIELD})  # commits p as float32
        with pytest.raises(ValueError, match="dtype"):
            ds.append({"p": FIELD.astype(np.float64)})  # direct path too
    with RankWriter(root, 0) as w:
        w.append({"p": FIELD.astype(np.float64)}, t=1)
    with pytest.raises(ManifestError, match="dtype"):
        merge_manifests(root)


# ---------------------------------------------------------------------------
# gc on a torn dataset
# ---------------------------------------------------------------------------

def test_gc_reclaims_orphans_but_keeps_sidecar_members(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with CZDataset(root, "a") as ds:
        ds.append({"p": FIELD})
    # a torn append: member on disk, crash before the manifest commit
    torn = os.path.join(root, "p", "t000099.cz")
    with open(torn, "wb") as f:
        f.write(b"CZ2\0garbage")
    # stale commit/engine leftovers
    with open(os.path.join(root, "manifest.json.tmp"), "w") as f:
        f.write("{")
    os.makedirs(os.path.join(root, "rho"))
    with open(os.path.join(root, "rho", "t000000.cz.rank0.part"), "wb") as f:
        f.write(b"\0" * 8)
    # a pending (sidecar-committed, unmerged) member: LIVE, must survive gc
    with RankWriter(root, 1) as w:
        w.append({"p": FIELD + 1}, t=1)

    with CZDataset(root) as ds:
        listed = ds.gc(dry_run=True)
        assert sorted(listed) == ["manifest.json.tmp", "p/t000099.cz",
                                  "rho/t000000.cz.rank0.part"]
        with pytest.raises(IOError, match="read-only"):
            ds.gc()

    with CZDataset(root, "a") as ds:
        assert ds.gc() == listed
        assert ds.gc(dry_run=True) == []  # idempotent: nothing left
    assert not os.path.exists(torn)
    assert not os.path.exists(os.path.join(root, "rho"))  # pruned empty dir

    # the torn dataset reads, and the pending member still merges cleanly
    assert merge_manifests(root) == 1
    with CZDataset(root) as ds:
        assert ds.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds.read_field("p", 1), FIELD + 1)


# ---------------------------------------------------------------------------
# Satellites: coercion warning + append-time stats
# ---------------------------------------------------------------------------

def test_spec_for_coercion_warns_not_silent(tmp_path):
    root = os.path.join(tmp_path, "ds")
    spec = CompressionSpec(scheme="fpzipx", block_size=BS)
    with CZDataset(root, "a", spec=spec) as ds:
        with pytest.warns(DtypeCoercionWarning, match="fpzipx.*cannot encode"):
            ds.append({"p": FIELD.astype(np.float64)})
        with pytest.warns(DtypeCoercionWarning, match="not a supported"):
            ds.append({"p": (FIELD * 100).astype(np.int32)})
    with CZDataset(root) as ds:
        assert ds.dtype("p") == np.float32


def test_append_stats_recorded_and_inspectable(tmp_path, capsys):
    from repro.launch.compress import inspect_main

    root = os.path.join(tmp_path, "ds")
    spec = CompressionSpec(scheme="wavelet", eps=1e-3, block_size=BS)
    with CZDataset(root, "a", spec=spec, stats=True) as ds:
        ds.append({"p": FIELD})
    with CZDataset(root) as ds:
        ts = ds.timestep_info("p", 0)
        assert ts["psnr"] > 40.0
        assert 0.0 < ts["max_err"] < 1e-2
    assert inspect_main(["--stats", root]) == 0
    out = capsys.readouterr().out
    assert "PSNR" in out and "p" in out

    # bit-exact members record psnr=None (JSON has no Infinity); the table
    # renders that as 'exact', not a misleading numeric 'inf'
    root2 = os.path.join(tmp_path, "ds2")
    with CZDataset(root2, "a", spec=SPEC, stats=True) as ds:
        ds.append({"p": FIELD})
        assert ds.timestep_info("p", 0)["psnr"] is None
        assert ds.timestep_info("p", 0)["max_err"] == 0.0
    assert inspect_main(["--stats", root2]) == 0
    out2 = capsys.readouterr().out
    assert "exact" in out2
    assert "inf" not in out2


def test_rank_writer_stats(tmp_path):
    root = os.path.join(tmp_path, "ds")
    _make_dataset(root)
    with RankWriter(root, 0, stats=True) as w:
        w.append({"p": FIELD}, t=0)
    merge_manifests(root)
    with CZDataset(root) as ds:
        assert ds.timestep_info("p", 0)["psnr"] is None  # raw is lossless


# guard against a start-method regression: the engine must work under spawn
# (fresh interpreters), which is what a jax-initialized parent requires
def test_engine_default_start_method():
    assert ParallelCompressor(2)._start == "spawn"
    assert "spawn" in multiprocessing.get_all_start_methods()
