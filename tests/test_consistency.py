"""Decode-vs-forward consistency: stepping the decoder token-by-token with a
cache must reproduce the teacher-forced forward logits at every position —
the strongest functional check of KV-cache / SSM-state semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import ModelSettings, cache_spec, decode_step, init_params
from repro.models.transformer import _head, forward_hidden

ST = ModelSettings(q_chunk=8, kv_chunk=8, ce_chunk=16, remat="none",
                   compute_dtype=jnp.float32)


def forward_logits(params, tokens, cfg, frames=None):
    h, _ = forward_hidden(params, tokens, cfg, ST, enc_inputs=frames)
    return jnp.einsum("bsd,dv->bsv", h, _head(params, cfg, jnp.float32))


@pytest.mark.parametrize("name", ["smollm-135m", "qwen3-32b", "olmoe-1b-7b",
                                  "rwkv6-7b", "jamba-v0.1-52b", "whisper-small"])
def test_decode_matches_forward(name):
    import dataclasses

    # capacity dropping legitimately differs between a 32-token train group
    # and a 1-token decode step (GShard semantics); eliminate drops so the
    # cache-semantics comparison is exact.
    cfg = dataclasses.replace(reduced(ARCHS[name]), capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    frames = None
    if cfg.family == "encdec":
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32)

    want = forward_logits(params, tokens, cfg, frames)   # (B,S,V)

    cache = cache_spec(cfg, B, S, dtype=jnp.float32, mode="zeros")
    if cfg.family == "encdec":
        # precompute cross-attention KV from the encoder output
        from repro.models.common import rmsnorm, sinusoidal_positions
        from repro.models.transformer import _cast_blocks, _enc_body, _scan_blocks

        e = frames + sinusoidal_positions(cfg.enc_frames, cfg.d_model)
        e, _ = _scan_blocks(e, _cast_blocks(params["blocks"]["enc"], jnp.float32),
                            lambda a, bp: _enc_body(a, bp, cfg, ST), ST)
        enc_out = rmsnorm(e, params["blocks"]["enc_norm"], cfg.norm_eps)
        Hkv, hd = cfg.n_kv_heads, cfg.hd
        xk = jnp.stack([
            jnp.einsum("bfd,dh->bfh", enc_out,
                       params["blocks"]["dec"]["xattn"]["wk"][i]).reshape(
                B, cfg.enc_frames, Hkv, hd)
            for i in range(cfg.n_layers)])
        xv = jnp.stack([
            jnp.einsum("bfd,dh->bfh", enc_out,
                       params["blocks"]["dec"]["xattn"]["wv"][i]).reshape(
                B, cfg.enc_frames, Hkv, hd)
            for i in range(cfg.n_layers)])
        cache = {**cache, "xk": xk, "xv": xv}

    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, ST))
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)

    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3 * scale, rtol=1e-3,
                               err_msg=f"{name} decode != forward")
