"""Structured JSON-lines event log, request-correlated.

The diagnostics channel for everything inside ``src/repro`` that is not a
metric or a span: one :func:`event` call emits one JSON object with a
timestamp, a level, the event name, the active request ID (from
:mod:`repro.obs.context`, when inside a request scope), and any keyword
fields — never a bare ``print``.  The CI lint enforces the flip side: no
``print(`` diagnostics outside the CLI modules.

Two destinations, both optional and both owned by the process-wide
:data:`LOG`:

* a bounded in-memory ring (always on; :func:`tail` reads it back —
  tests and the ``/debug`` endpoints use this), and
* a JSON-lines sink — a file path or a stream — enabled via
  :func:`configure` (``cz-compress serve --events OUT.jsonl`` on the CLI).

Levels are the usual ``debug < info < warn < error``; events below the
configured threshold are dropped at the call site.

Stdlib only — importable before numpy/jax.
"""
from __future__ import annotations

import collections
import json
import threading
import time

from repro.obs import context as _context

__all__ = ["EventLog", "LOG", "LEVELS", "event", "configure", "tail"]

#: level names in severity order (numeric thresholds for filtering).
LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}


def _level_num(level: str) -> int:
    try:
        return LEVELS[level]
    except KeyError:
        raise ValueError(f"unknown event level {level!r}; "
                         f"one of {sorted(LEVELS)}") from None


class EventLog:
    """One event sink: bounded ring + optional JSON-lines stream."""

    def __init__(self, ring: int = 512, level: str = "info"):
        self._lock = threading.Lock()
        self._ring: collections.deque[dict] = collections.deque(maxlen=ring)
        self._min = _level_num(level)
        self._level = level
        self._stream = None
        self._owns_stream = False
        self.emitted = 0
        self.suppressed = 0

    # -- configuration -------------------------------------------------------

    def configure(self, path: str | None = None, stream=None,
                  level: str | None = None, ring: int | None = None) -> None:
        """Point the log at a JSON-lines sink and/or adjust filtering.

        ``path`` opens (appends to) a file this log then owns; ``stream``
        is any writable text object the *caller* owns.  Passing neither
        leaves the sink unchanged; ``path=None, stream=None`` with an
        explicit prior sink keeps it (use :meth:`close` to drop it).
        """
        with self._lock:
            if level is not None:
                self._min = _level_num(level)
                self._level = level
            if ring is not None:
                self._ring = collections.deque(self._ring, maxlen=int(ring))
            if path is not None and stream is not None:
                raise ValueError("configure takes path or stream, not both")
            if path is not None or stream is not None:
                self._close_stream()
                if path is not None:
                    self._stream = open(path, "a", encoding="utf-8")
                    self._owns_stream = True
                else:
                    self._stream = stream
                    self._owns_stream = False

    def _close_stream(self) -> None:
        if self._stream is not None and self._owns_stream:
            self._stream.close()
        self._stream = None
        self._owns_stream = False

    def close(self) -> None:
        """Drop (and close, if owned) the JSON-lines sink; ring survives."""
        with self._lock:
            self._close_stream()

    @property
    def level(self) -> str:
        return self._level

    # -- emission ------------------------------------------------------------

    def event(self, name: str, level: str = "info", **fields) -> dict | None:
        """Emit one structured event; returns the record (or None if the
        level filter dropped it).  ``request_id`` is stamped automatically
        from the active request scope."""
        if _level_num(level) < self._min:
            with self._lock:
                self.suppressed += 1
            return None
        rec: dict = {"ts": round(time.time(), 6), "level": level,
                     "event": str(name)}
        rid = _context.request_id()
        if rid is not None:
            rec["request_id"] = rid
        for k, v in fields.items():
            rec[k] = v
        with self._lock:
            self._ring.append(rec)
            self.emitted += 1
            if self._stream is not None:
                try:
                    self._stream.write(json.dumps(rec, default=str) + "\n")
                    self._stream.flush()
                except (OSError, ValueError):
                    # a torn sink (disk full, closed stream) must not take
                    # the serving thread down with it
                    self._close_stream()
        return rec

    # -- readback ------------------------------------------------------------

    def tail(self, n: int = 50) -> list[dict]:
        """The most recent ``n`` events, oldest first (copies)."""
        with self._lock:
            items = list(self._ring)
        return [dict(r) for r in items[-int(n):]]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


#: the process-wide event log (module-level helpers target it).
LOG = EventLog()


def event(name: str, level: str = "info", **fields) -> dict | None:
    """``events.event("http.request", code=200, ...)`` against :data:`LOG`."""
    return LOG.event(name, level=level, **fields)


def configure(path: str | None = None, stream=None, level: str | None = None,
              ring: int | None = None) -> None:
    LOG.configure(path=path, stream=stream, level=level, ring=ring)


def tail(n: int = 50) -> list[dict]:
    return LOG.tail(n)
