"""Fig. 3 — CR and PSNR over the collapse timeline for the three wavelets.

Expected reproductions: W3ai >= W4/W4l in CR at fixed eps; CR dips when the
collapse shocks propagate (t ~ 7-9 us); alpha2 CR rises pre-collapse."""
from __future__ import annotations

import time

from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields

from .common import BENCH_N, emit, save_json, sweep


def run(quick: bool = True):
    times = [2.0, 5.0, 7.0, 8.0, 9.4] if quick else [1, 2, 3, 4, 5, 6, 7, 7.5, 8, 8.5, 9.4, 10.5]
    qois = ["p", "a2"] if quick else ["p", "rho", "E", "a2"]
    rows = []
    t0 = time.time()
    for t in times:
        fields = cavitation_fields(CloudConfig(n=BENCH_N), t)
        for q in qois:
            for wav in ("w4i", "w4l", "w3ai"):
                spec = CompressionSpec(scheme="wavelet", wavelet=wav, eps=1e-3)
                r = sweep(fields[q], [spec])[0]
                rows.append({"t_us": t, "qoi": q, "wavelet": wav,
                             "cr": r["cr"], "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig3_wavelet_time", rows)
    # summary: W3ai CR advantage at the final snapshot
    last = [r for r in rows if r["t_us"] == times[-1] and r["qoi"] == "p"]
    by = {r["wavelet"]: r["cr"] for r in last}
    emit("fig3_w3ai_cr_p_final", dt * 1e6 / max(len(rows), 1), f"{by.get('w3ai', 0):.2f}")
    emit("fig3_w3ai_vs_w4i", dt * 1e6 / max(len(rows), 1),
         f"{by.get('w3ai', 1) / max(by.get('w4i', 1), 1e-9):.3f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
