"""rwkv6-7b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads of dim 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    ssm_kind="rwkv6",
    notes="linear recurrence; decode state is O(1) -> long_500k runs",
)
