"""``repro.cluster`` — the rank-parallel compression tier.

The layer between the codec pipeline (``repro.core``) and the dataset store
(``repro.store``): it is what turns one-process compression into the paper's
cluster workflow, where every MPI rank compresses its block-structured share
of the grid concurrently and the results land in shared, single-file-per-
quantity output with negligible coordination.

Three modules:

* :mod:`~repro.cluster.decompose` — block-aligned 3D domain decomposition
  (slab / pencil / brick rank grids, ``MPI_Dims_create``-style balancing,
  scatter/gather) plus the 1-D chunk-span partition the engine writes with;
* :mod:`~repro.cluster.engine` — :class:`ParallelCompressor`: N worker
  processes encode their spans through ``Pipeline.iter_chunks``, an
  ``MPI_Exscan``-style exclusive scan (``repro.dist.offsets``) places each
  rank's bytes, and the assembled shared CZ2 file is bit-identical to the
  serial writer for any rank count;
* :mod:`~repro.cluster.multiwriter` — :class:`RankWriter` sidecar manifests
  (``manifest.rank{r}.json``) for contention-free in-situ append, and the
  atomic, idempotent :func:`merge_manifests` that folds them into the
  CZDataset manifest.
"""
from .decompose import (  # noqa: F401
    LAYOUTS,
    Subdomain,
    chunk_spans,
    decompose,
    dims_for,
    gather,
    scatter,
)
from .engine import ParallelCompressor  # noqa: F401
from .multiwriter import RankWriter, merge_manifests  # noqa: F401

__all__ = ["Subdomain", "LAYOUTS", "decompose", "dims_for", "scatter",
           "gather", "chunk_spans", "ParallelCompressor", "RankWriter",
           "merge_manifests"]
