"""Pallas TPU kernel: fused dual-quantization + 3D Lorenzo (szx encode/decode).

Encode fuses compensated 2eps-grid quantization with the three axis-wise
finite differences; decode fuses three inclusive prefix sums (lowered as
associative scans on TPU) with dequantization.  Each grid step owns a tile
of whole blocks in VMEM; the diffs/cumsums are static-shape ops along the
trailing axes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lorenzo_encode_pallas", "lorenzo_decode_pallas"]

DEFAULT_TILE_BLOCKS = 4


def _enc_kernel(x_ref, o_ref, *, eps: float):
    x = x_ref[...]
    inv = 1.0 / (2.0 * eps)
    q = jnp.round(x * inv)
    q = (q + jnp.round((x - q * (2.0 * eps)) * inv)).astype(jnp.int32)
    for ax in (-3, -2, -1):
        qm = jnp.moveaxis(q, ax, -1)
        pad = jnp.zeros_like(qm[..., :1])
        qm = jnp.diff(qm, axis=-1, prepend=pad)
        q = jnp.moveaxis(qm, -1, ax)
    o_ref[...] = q


def _dec_kernel(r_ref, o_ref, *, eps: float):
    r = r_ref[...]
    for ax in (-1, -2, -3):
        r = jnp.cumsum(r, axis=ax, dtype=r.dtype)
    o_ref[...] = r.astype(jnp.float32) * (2.0 * eps)


def _call(x, kern, out_dtype, eps, tile_blocks, interpret):
    b, n = x.shape[0], x.shape[-1]
    tb = min(tile_blocks, b)
    if b % tb:
        tb = 1
    return pl.pallas_call(
        functools.partial(kern, eps=eps),
        grid=(b // tb,),
        in_specs=[pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, out_dtype),
        interpret=interpret,
    )(x)


def lorenzo_encode_pallas(blocks, eps: float = 1e-3,
                          tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    return _call(jnp.asarray(blocks, jnp.float32), _enc_kernel, jnp.int32,
                 eps, tile_blocks, interpret)


def lorenzo_decode_pallas(residuals, eps: float = 1e-3,
                          tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    return _call(jnp.asarray(residuals, jnp.int32), _dec_kernel, jnp.float32,
                 eps, tile_blocks, interpret)
