"""Remote read stack (ISSUE 9 acceptance): the ``http(s)://`` backend over
a range-capable loopback static server, the RetryStore policy layer, and
the reader-side chunk prefetcher — plus their interplay with the serve
tier's single-flight scheduler.

Four layers:

* **HttpStore contract** — ranged gets, 404/416 mapping, read-only
  enforcement, client-side slicing against a Range-ignoring server;
* **end-to-end** — a dataset exported over loopback HTTP answers
  ``read_box``/serve-tier region queries bit-identical to a local read;
* **retry policy** — transient faults on get *and* put recover
  transparently with intact caches and correct ``cz_store_retries_total``;
  permanent errors and deadline exhaustion do not retry;
* **prefetch** — identical results, identical request counts (the PR 6
  amplification baseline), prefetched bytes actually consumed, eviction
  refetches instead of crashing, and exactly one fetch per chunk under
  concurrent duplicate requests.
"""
import functools
import os
import threading
from http.server import SimpleHTTPRequestHandler, ThreadingHTTPServer

import numpy as np
import pytest

from repro import obs
from repro.core import CompressionSpec
from repro.obs import events as _events
from repro.serve import Client, RegionHTTPServer, SingleFlight
from repro.serve.scheduler import ChunkScheduler
from repro.store import CZDataset
from repro.store.backends import (
    FileStore,
    FlakyStore,
    HttpStore,
    InjectedFault,
    MemoryStore,
    RangeStore,
    RetryStore,
    StaticFileServer,
    StoreDeadlineError,
    StoreKeyError,
    StoreRangeError,
    open_store,
)

from test_pipeline_api import smooth_field

N = 32
BS = 16
# 16 KiB buffers -> one 16^3 float32 block per chunk: 8 chunks per member
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 14)
FIELDS = {"p": smooth_field(N, seed=3), "rho": smooth_field(N, seed=4)}


def _counter(name, **labels):
    m = obs.REGISTRY.get(name)
    return 0.0 if m is None else m.value(**labels)


def _fill(store_or_root) -> None:
    with CZDataset(store_or_root, "a", spec=SPEC) as ds:
        for k in range(2):
            ds.append({q: f + np.float32(k) for q, f in FIELDS.items()},
                      time=0.5 * k)


@pytest.fixture(scope="module")
def ds_dir(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("remote") / "ds")
    _fill(root)
    return root


@pytest.fixture(scope="module")
def static_srv(ds_dir):
    with StaticFileServer(ds_dir) as srv:
        yield srv


# ---------------------------------------------------------------------------
# HttpStore contract
# ---------------------------------------------------------------------------

def test_http_store_contract(tmp_path):
    os.makedirs(tmp_path / "a")
    (tmp_path / "a" / "b.bin").write_bytes(b"0123456789")
    (tmp_path / "a" / "empty.bin").write_bytes(b"")
    with StaticFileServer(tmp_path) as srv, HttpStore(srv.url) as st:
        assert st.get("a/b.bin") == b"0123456789"
        assert st.get("a/b.bin", (2, 5)) == b"234"
        assert st.get("a/b.bin", (4, None)) == b"456789"
        assert st.get("a/b.bin", (0, 0)) == b""
        assert st.get("a/b.bin", (8, 100)) == b"89"   # short read at EOF
        assert st.get("a/empty.bin") == b""
        assert st.get("a/empty.bin", (0, 8)) == b""
        for rng in ((10, None), (10, 14), (100, None), (5, 5)):
            if rng[0] < 10:
                continue
            with pytest.raises(StoreRangeError):
                st.get("a/b.bin", rng)
        assert st.get("a/b.bin", (5, 5)) == b""       # empty span in range
        with pytest.raises(StoreRangeError):
            st.get("a/empty.bin", (1, None))
        with pytest.raises(StoreKeyError):
            st.get("a/nope.bin")
        with pytest.raises(StoreKeyError):
            st.get("a/nope.bin", (0, 4))
        assert st.exists("a/b.bin") and not st.exists("a/nope.bin")
        # pipelined batch preserves order and per-request semantics
        assert st.get_many([("a/b.bin", (0, 2)), ("a/b.bin", (8, None)),
                            ("a/b.bin", None)]) == \
            [b"01", b"89", b"0123456789"]
        s = st.stats()
        assert s["get_requests"] >= 8 and s["range_requests"] >= 5


def test_http_store_is_read_only(static_srv):
    st = HttpStore(static_srv.url)
    with pytest.raises(IOError, match="read-only"):
        st.put("x.bin", b"nope")
    with pytest.raises(IOError, match="read-only"):
        st.delete("manifest.json")
    with pytest.raises(IOError, match="enumerate"):
        st.list("")
    st.close()


def test_http_store_slices_when_server_ignores_range(ds_dir):
    """stdlib ``http.server`` answers 200-with-everything to a ranged GET;
    the store must slice client-side and stay correct (at amplified
    transfer cost)."""
    handler = functools.partial(SimpleHTTPRequestHandler, directory=ds_dir)
    srv = ThreadingHTTPServer(("127.0.0.1", 0), handler)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    try:
        host, port = srv.server_address[:2]
        with HttpStore(f"http://{host}:{port}") as st:
            raw = (FileStore(ds_dir).get("manifest.json"))
            assert st.get("manifest.json", (2, 10)) == raw[2:10]
            assert st.get("manifest.json", (4, None)) == raw[4:]
            with pytest.raises(StoreRangeError):
                st.get("manifest.json", (len(raw) + 5, None))
            # and a whole dataset still reads bit-exact through it
            with CZDataset(st) as ds:
                np.testing.assert_array_equal(ds.read_field("p", 0),
                                              FIELDS["p"])
    finally:
        srv.shutdown()
        thread.join(timeout=5)
        srv.server_close()


def test_static_server_sends_real_ranges(static_srv):
    """The loopback server itself must answer 206 with exact slices —
    otherwise every 'ranged' assertion in this file is vacuous."""
    import urllib.request

    req = urllib.request.Request(f"{static_srv.url}/manifest.json",
                                 headers={"Range": "bytes=2-5"})
    with urllib.request.urlopen(req) as r:
        assert r.status == 206
        body = r.read()
    assert body == FileStore(static_srv.root).get("manifest.json")[2:6]
    assert len(body) == 4


# ---------------------------------------------------------------------------
# end-to-end over loopback HTTP
# ---------------------------------------------------------------------------

def test_http_dataset_reads_bit_identical_and_ranged(ds_dir, static_srv):
    st = HttpStore(static_srv.url)
    stored = sum(os.path.getsize(os.path.join(dp, f))
                 for dp, _, fs in os.walk(ds_dir) for f in fs)
    with CZDataset(st, cache_chunks=4) as ds:
        assert ds.quantities == ["p", "rho"]
        np.testing.assert_array_equal(ds.read_field("p", 0), FIELDS["p"])
        before = st.stats()
        np.testing.assert_array_equal(
            ds.read_box("rho", 1, (3, 4, 5), (BS, BS, BS)),
            (FIELDS["rho"] + np.float32(1))[3:BS, 4:BS, 5:BS])
        delta = st.stats()["bytes_fetched"] - before["bytes_fetched"]
        # the box touched 1 of 8 chunks of one member: byte-ranged, not
        # whole-member (let alone whole-dataset) transfer
        assert 0 < delta < stored / 4


def test_http_serve_e2e_bit_identical(ds_dir, static_srv):
    """The acceptance path: ``cz-compress serve http://<loopback>/`` —
    a URL root resolved through open_store — answers region queries
    bit-identical to a local read_box."""
    with CZDataset(ds_dir) as local:
        want_box = local.read_box("p", 1, (3, 2, 1), (30, 20, 10))
        want_full = local.read_field("rho", 0)
    # exactly what serve_main builds when --retries/--timeout are given
    store = open_store(static_srv.url, retries=2, timeout=10.0)
    assert isinstance(store, RetryStore)
    with RegionHTTPServer(store, port=0, prefetch=2).start() as srv:
        with Client(srv.url) as client:
            got = client.region("p", 1, (3, 2, 1), (30, 20, 10))
            np.testing.assert_array_equal(got, want_box)
            np.testing.assert_array_equal(
                client.region("rho", 0, (0, 0, 0), (N, N, N)), want_full)
            assert client.healthz()


def test_inspect_accepts_http_url(ds_dir, static_srv, capsys):
    from repro.launch.compress import inspect_main

    assert inspect_main([static_srv.url]) == 0
    out = capsys.readouterr().out
    assert "p" in out and "rho" in out


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def _retry_store(flaky, **kw):
    kw.setdefault("backoff", 0.001)
    kw.setdefault("jitter", 0.0)
    sleeps = []
    rs = RetryStore(flaky, sleep=sleeps.append, **kw)
    return rs, sleeps


def test_retry_recovers_get_transparently_with_metrics():
    flaky = FlakyStore(MemoryStore())
    _fill(flaky)
    rs, sleeps = _retry_store(flaky)
    before = _counter("cz_store_retries_total", backend="flakystore",
                      op="get")
    with CZDataset(rs, cache_chunks=8) as ds:
        warm = ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))
        flaky.fail_on_get = flaky.gets + 1      # arm the next cold fetch
        got = ds.read_box("p", 0, (BS, 0, 0), (N, BS, BS))  # no exception
        np.testing.assert_array_equal(got, FIELDS["p"][BS:N, :BS, :BS])
        assert flaky.faults == 1 and len(sleeps) == 1
        # caches stayed intact across the absorbed fault
        gets = flaky.gets
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS)), warm)
        assert flaky.gets == gets
    after = _counter("cz_store_retries_total", backend="flakystore",
                     op="get")
    assert after - before == 1
    evs = [e for e in _events.tail(50) if e["event"] == "store.retry"]
    assert evs and evs[-1]["op"] == "get"
    assert "InjectedFault" in evs[-1]["error"]


def test_retry_recovers_put_path():
    """Acceptance: injected transient faults on the *write* path recover
    via RetryStore — one armed fault per member commit and per manifest
    commit, and the append still lands."""
    flaky = FlakyStore(MemoryStore(), fail_on_put=1, fail_every=2)
    rs, sleeps = _retry_store(flaky, retries=3)
    before = (_counter("cz_store_retries_total", backend="flakystore",
                       op="put"),
              _counter("cz_store_retries_total", backend="flakystore",
                       op="put_atomic"))
    _fill(rs)  # every other commit faults once; all are absorbed
    with CZDataset(rs) as ds:
        assert ds.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds.read_field("p", 1),
                                      FIELDS["p"] + np.float32(1))
    assert flaky.faults >= 2 and len(sleeps) == flaky.faults
    after = (_counter("cz_store_retries_total", backend="flakystore",
                      op="put"),
             _counter("cz_store_retries_total", backend="flakystore",
                      op="put_atomic"))
    assert sum(after) - sum(before) == flaky.faults


def test_retry_exhaustion_reraises_with_backoff_schedule():
    flaky = FlakyStore(MemoryStore(), fail_on_get=1, fail_every=1)
    flaky.put("k", b"v")
    rs, sleeps = _retry_store(flaky, retries=3, backoff=0.01,
                              max_backoff=0.04)
    with pytest.raises(InjectedFault):
        rs.get("k")
    assert flaky.gets == 4                       # 1 try + 3 retries
    assert sleeps == [0.01, 0.02, 0.04]          # doubling, capped


def test_retry_deadline_exceeded():
    flaky = FlakyStore(MemoryStore(), fail_on_get=1, fail_every=1)
    flaky.put("k", b"v")
    rs, sleeps = _retry_store(flaky, retries=5, backoff=10.0, deadline=0.5)
    before = _counter("cz_store_deadline_exceeded_total",
                      backend="flakystore", op="get")
    with pytest.raises(StoreDeadlineError, match="deadline"):
        rs.get("k")
    assert sleeps == []                          # abandoned before sleeping
    after = _counter("cz_store_deadline_exceeded_total",
                     backend="flakystore", op="get")
    assert after - before == 1


def test_retry_never_retries_permanent_errors():
    mem = MemoryStore()
    mem.put("k", b"0123456789")
    rs, sleeps = _retry_store(FlakyStore(mem), retries=5)
    with pytest.raises(StoreKeyError):
        rs.get("nope")
    with pytest.raises(StoreRangeError):
        rs.get("k", (100, None))
    with pytest.raises(StoreKeyError):
        rs.delete("nope")
    assert sleeps == []


def test_open_store_retry_wrapping(tmp_path, static_srv):
    # remote backends are wrapped by default; the policy can be tuned or
    # disabled; local backends opt in explicitly
    st = open_store(static_srv.url)
    assert isinstance(st, RetryStore) and isinstance(st.inner, HttpStore)
    assert st.remote and st.retries == 2
    st.close()
    bare = open_store(static_srv.url, retries=0)
    assert isinstance(bare, HttpStore)
    bare.close()
    tuned = open_store(static_srv.url, retries=5, timeout=3.0)
    assert isinstance(tuned, RetryStore)
    assert tuned.retries == 5 and tuned.deadline == 3.0
    assert tuned.inner.timeout == 3.0
    tuned.close()
    local = open_store(str(tmp_path / "d"), retries=3)
    assert isinstance(local, RetryStore)
    assert isinstance(local.inner, FileStore)
    assert isinstance(open_store(str(tmp_path / "d")), FileStore)


# ---------------------------------------------------------------------------
# serve.Client: every GET path survives a server restart
# ---------------------------------------------------------------------------

def test_client_survives_server_restart_on_all_get_paths(ds_dir):
    srv = RegionHTTPServer(ds_dir, port=0).start()
    port = srv.server_address[1]
    client = Client(srv.url)
    try:
        np.testing.assert_array_equal(
            client.region("p", 0, (0, 0, 0), (8, 8, 8)),
            FIELDS["p"][:8, :8, :8])
        assert "cz_serve_queries_total" in client.metrics()
        # restart the server on the same port: the client's pooled
        # keep-alive socket is now stale on *every* path
        srv.close()
        srv = RegionHTTPServer(ds_dir, port=port).start()
        for fetch in (client.healthz,
                      client.manifest,
                      client.metrics,
                      lambda: client.region("p", 0, (0, 0, 0), (8, 8, 8)),
                      client.traces):
            srv.close()
            srv = RegionHTTPServer(ds_dir, port=port).start()
            fetch()  # must transparently retry once on a fresh connection
    finally:
        client.close()
        srv.close()


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------

def _range_dataset(prefetch=0, **kw):
    st = RangeStore()
    _fill(st)
    return st, CZDataset(st, prefetch=prefetch, **kw)


def test_prefetch_bit_identical_and_request_parity():
    """The PR 6 regression harness: prefetch may reorder fetches but must
    not change results, request counts, or fetched-byte totals."""
    counts = {}
    for pf in (0, 4):
        st, ds = _range_dataset(prefetch=pf, cache_chunks=4)
        with ds:
            before = st.stats()
            np.testing.assert_array_equal(
                ds.read_box("p", 0, (0, 0, 0), (N, N, N)), FIELDS["p"])
            np.testing.assert_array_equal(
                ds.read_box("rho", 1, (3, 4, 5), (19, 20, 21)),
                (FIELDS["rho"] + np.float32(1))[3:19, 4:20, 5:21])
            s = st.stats()
            counts[pf] = (s["get_requests"] - before["get_requests"],
                          s["bytes_fetched"] - before["bytes_fetched"])
    assert counts[4] == counts[0], \
        f"prefetch changed request/byte amplification: {counts}"


def test_prefetch_bytes_actually_used():
    issued0 = _counter("cz_reader_prefetch_chunks_total", result="issued")
    used0 = _counter("cz_reader_prefetch_chunks_total", result="used")
    st, ds = _range_dataset(prefetch=2, cache_chunks=8)
    with ds:
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (0, 0, 0), (N, N, N)), FIELDS["p"])
    issued = _counter("cz_reader_prefetch_chunks_total",
                      result="issued") - issued0
    used = _counter("cz_reader_prefetch_chunks_total", result="used") - used0
    # 8 covering chunks: the first is fetched directly, the rest ride ahead
    assert issued >= 6
    assert used == issued  # every scheduled chunk was consumed, none wasted


def test_prefetch_evicted_chunks_are_refetched_not_crashed():
    st, ds = _range_dataset(prefetch=1)  # max_buffered = 2
    with ds:
        reader = ds.reader("p", 0)
        pf = reader._prefetcher
        evicted0 = _counter("cz_reader_prefetch_chunks_total",
                            result="evicted")
        # flood the prefetcher far past its buffer bound
        pf.schedule(range(reader.nchunks))
        assert _counter("cz_reader_prefetch_chunks_total",
                        result="evicted") - evicted0 >= \
            reader.nchunks - pf.max_buffered
        # evicted chunks simply refetch on demand; results stay exact
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (0, 0, 0), (N, N, N)), FIELDS["p"])


def test_prefetch_failure_falls_back_to_direct_get():
    flaky = FlakyStore(MemoryStore())
    _fill(flaky)
    with CZDataset(flaky, prefetch=2) as ds:
        reader = ds.reader("p", 0)
        flaky.fail_on_get = flaky.gets + 1       # poison the prefetch batch
        reader._prefetcher.schedule([0])
        failed0 = _counter("cz_reader_prefetch_chunks_total",
                           result="failed")
        # the chunk decodes anyway: take() reports the failure and
        # fetch_chunk falls back to a direct (now unarmed) get
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS)),
            FIELDS["p"][:BS, :BS, :BS])
        assert _counter("cz_reader_prefetch_chunks_total",
                        result="failed") - failed0 == 1


def test_prefetch_skip_predicate_vetoes_inflight_chunks():
    """The SingleFlight coordination contract, unit level: a chunk whose
    decode flight is airborne is never scheduled for prefetch."""
    st, ds = _range_dataset(prefetch=2)
    with ds:
        reader = ds.reader("p", 0)
        sf = SingleFlight()
        release = threading.Event()
        flying = threading.Event()

        def slow_decode():
            flying.set()
            release.wait(5)
            return reader.fetch_chunk(1)[0]

        t = threading.Thread(
            target=lambda: sf.do((reader.path, 1), slow_decode))
        t.start()
        flying.wait(5)
        skip = lambda ci: sf.in_flight((reader.path, ci))
        issued = reader._prefetcher.schedule([1, 2], skip=skip)
        assert issued == 1                      # chunk 1 vetoed, chunk 2 ok
        assert sf.in_flight((reader.path, 1))
        release.set()
        t.join(5)
        assert not sf.in_flight((reader.path, 1))


def test_concurrent_duplicate_requests_one_fetch_per_chunk():
    """Prefetch + SingleFlight end-to-end: many threads demanding the same
    box issue exactly one byte-range fetch per covering chunk — prefetch
    never duplicates a fetch a flight already owns, and vice versa."""
    st, ds = _range_dataset(prefetch=2, cache_chunks=32)
    with ds:
        sched = ChunkScheduler(ds)
        reader = ds.reader("p", 0)               # header fetched here
        nchunks = len(reader.box_chunks((0, 0, 0), (N, N, N)))
        before = st.stats()["get_requests"]
        errs = []

        def query():
            try:
                np.testing.assert_array_equal(
                    sched.read_box("p", 0, (0, 0, 0), (N, N, N)),
                    FIELDS["p"])
            except Exception as e:  # surfaced after join
                errs.append(e)

        threads = [threading.Thread(target=query) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not errs
        assert st.stats()["get_requests"] - before == nchunks
        # flights beyond nchunks resolved from cache without fetching;
        # concurrent duplicates parked on flights instead of re-decoding
        assert sched.flights_led >= nchunks
        assert sched.flights_joined > 0


def test_prefetch_over_http_end_to_end(ds_dir):
    with StaticFileServer(ds_dir) as srv, HttpStore(srv.url) as st:
        with CZDataset(st, prefetch=4, cache_chunks=4) as ds:
            np.testing.assert_array_equal(
                ds.read_box("p", 1, (0, 0, 0), (N, N, N)),
                FIELDS["p"] + np.float32(1))
        reqs = st.stats()
        assert reqs["range_requests"] >= 8       # still ranged, not amplified
