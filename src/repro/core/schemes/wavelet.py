"""Wavelet + threshold scheme (the paper's flagship compressor).

Stage 1: 3D wavelet transform per block, significance mask at |c| >= eps,
optional Z4/Z8 low-bit zeroing of detail coefficients.  Byte layout per
chunk: per-block detail counts (u32), packed significance bitmask, then the
coarse corner + significant details as one shuffled float32 stream.

``spec.device="jax"`` routes the forward/inverse transforms through the
batched Pallas kernels (``repro.kernels.ops.wavelet_*`` — whole block batch
in one jitted call); byte layout is unchanged, so device- and host-written
containers interdecode within the declared error bound (the kernel differs
from the host transform only by fp rounding).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .. import shuffle as shuf
from .. import threshold, wavelets
from . import Scheme, register_scheme, route, shuffle_bytes, unshuffle_bytes


@register_scheme
class WaveletScheme(Scheme):
    name = "wavelet"
    device_capable = True

    #: conformance contract: |x - xhat| <= BOUND_FACTOR * eps.  Thresholding
    #: at |c| < eps amplifies through the synthesis stencils across levels;
    #: the factor covers the paper's wavelets at any block size plus the fp
    #: difference between host and Pallas transforms at moderate amplitudes.
    BOUND_FACTOR = 100.0

    def validate(self, spec) -> None:
        if spec.wavelet not in wavelets.WAVELETS:
            raise ValueError(f"unknown wavelet {spec.wavelet}")

    def params(self, spec) -> dict:
        return {"wavelet": spec.wavelet, "eps": spec.eps,
                "levels": spec.levels, "zero_bits": spec.zero_bits,
                **super().params(spec)}

    def error_bound(self, spec) -> float:
        return self.BOUND_FACTOR * spec.eps

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        n = spec.block_size
        fwd = route(spec, wavelets.forward3d, "wavelet_forward")
        coeffs = fwd(x, kind=spec.wavelet, levels=spec.levels)
        mask = threshold.significant_mask(coeffs, spec.eps, spec.levels)
        c = wavelets.coarse_side(n, spec.levels)
        return {
            "mask": np.asarray(mask),
            "coeffs": np.asarray(coeffs),
            "coarse": np.asarray(coeffs[..., :c, :c, :c]),
        }

    def serialize(self, s1, lo, hi, spec) -> bytes:
        mask = s1["mask"][lo:hi]
        coeffs = s1["coeffs"][lo:hi]
        coarse = s1["coarse"][lo:hi].astype(np.float32)
        details = coeffs[mask].astype(np.float32)
        if spec.zero_bits:
            details = shuf.zero_low_bits_np(details, spec.zero_bits)
        counts = mask.reshape(mask.shape[0], -1).sum(-1).astype(np.uint32)
        values = np.concatenate([coarse.reshape(-1), details])
        return (
            counts.tobytes()
            + np.packbits(mask.reshape(-1)).tobytes()
            + shuffle_bytes(values.tobytes(), spec.shuffle, 4)
        )

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        c = wavelets.coarse_side(n, spec.levels)
        off = 4 * nblk  # skip per-block counts (redundant with the mask)
        mask_bytes = nblk * n * n * n // 8
        mask = np.unpackbits(np.frombuffer(payload[off : off + mask_bytes], np.uint8))
        mask = mask[: nblk * n * n * n].astype(bool).reshape(nblk, n, n, n)
        off += mask_bytes
        values = np.frombuffer(
            unshuffle_bytes(payload[off:], spec.shuffle, 4), np.float32
        )
        ncoarse = nblk * c * c * c
        coarse = values[:ncoarse].reshape(nblk, c, c, c)
        details = values[ncoarse:]
        coeffs = np.zeros((nblk, n, n, n), np.float32)
        coeffs[mask] = details
        coeffs[:, :c, :c, :c] = coarse
        inv = route(spec, wavelets.inverse3d, "wavelet_inverse")
        return np.asarray(inv(jnp.asarray(coeffs), kind=spec.wavelet,
                              levels=spec.levels))
