"""HTTP region-serving load benchmark (ISSUE 5 acceptance).

N client threads hammer a loopback :class:`RegionHTTPServer` with a
zipf-hot region mix (a few regions take most of the traffic — the analyst
returning to the same vortex core) and report p50/p99 request latency,
throughput, and where the queries were answered: decoded-region LRU vs
chunk LRU vs cold decode.

The run is two-phase: the identical load is driven once with tail-based
trace sampling **disabled** and once **enabled** (the production default),
so the record quantifies the sampling overhead at the median
(``sampling_overhead_pct``) and verifies the ``/debug/traces`` contract —
only error/slow-tail requests retained, within the byte budget, every
retained trace carrying the request ID its response echoed in
``X-CZ-Request-Id``.

The dataset lives in a ``mem://`` store — no scratch directory, and the
serve tier is exercised end-to-end over a non-file backend (URL root ->
CZDataset -> byte-ranged reads).
"""
from __future__ import annotations

import threading
import time
from http.client import HTTPConnection

import numpy as np

from repro.core import CompressionSpec
from repro.serve import Client, RegionHTTPServer
from repro.store import CZDataset, MemoryStore

from .common import dataset, emit, save_json


def _zipf_weights(k: int, a: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, k + 1) ** a
    return w / w.sum()


def _drive(srv, qois, lows, box, n_threads, n_req, weights):
    """One load phase against a started server: a cold pass over every
    candidate region, then the zipf-hot timed phase.  Returns
    ``(cold_ms, lat_ms, wall_s)``."""
    n_regions = len(lows)
    cold = []
    with Client(srv.url) as c:
        for q in qois:
            for lo in lows:
                t1 = time.perf_counter()
                c.region(q, 0, lo, lo + box)
                cold.append(time.perf_counter() - t1)

    lats: list[list[float]] = [[] for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads)

    def worker(i: int) -> None:
        c = Client(srv.url)
        trng = np.random.default_rng(100 + i)
        barrier.wait()
        for k in range(n_req):
            lo = lows[trng.choice(n_regions, p=weights)]
            t1 = time.perf_counter()
            c.region(qois[k % len(qois)], 0, lo, lo + box)
            lats[i].append(time.perf_counter() - t1)
        c.close()

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    cold_ms = np.asarray(cold) * 1e3
    lat_ms = np.concatenate([np.asarray(ts) for ts in lats]) * 1e3
    return cold_ms, lat_ms, wall


def _error_request(srv, rid: str) -> str | None:
    """One deliberately failing request with a client-chosen request ID;
    returns the ID the response echoed back."""
    host, port = srv.server_address[:2]
    conn = HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", "/v1/region/no_such_quantity/0"
                            "?lo=0,0,0&hi=8,8,8",
                     headers={"X-CZ-Request-Id": rid})
        r = conn.getresponse()
        r.read()
        return r.getheader("X-CZ-Request-Id")
    finally:
        conn.close()


def _traces_readout(srv, err_rid: str, echoed: str | None) -> dict:
    """The /debug/traces contract, checked live and recorded."""
    with Client(srv.url) as c:
        doc = c.traces()
    traces, stats = doc["traces"], doc["stats"]
    kept_ids = [t["request_id"] for t in traces]
    return {
        "retained": len(traces),
        "reasons": sorted({t["reason"] for t in traces}),
        "bytes": stats["bytes"],
        "budget_bytes": stats["budget_bytes"],
        "within_budget": stats["bytes"] <= stats["budget_bytes"],
        "threshold_ms": stats["threshold_s"] * 1e3,
        "sampled": stats["sampled"],
        "all_have_request_id": all(kept_ids),
        "only_error_or_slow": all(t["reason"] in ("error", "slow")
                                  for t in traces),
        "error_id_echoed": echoed == err_rid,
        "error_trace_kept": err_rid in kept_ids,
    }


def run(quick: bool = True, prefetch: int = 0):
    n_threads = 4 if quick else 8
    n_req = 60 if quick else 400         # per thread
    box = 24
    n_regions = 24 if quick else 96      # candidate pool, zipf-weighted
    qois = ["p"] if quick else ["p", "rho"]

    fields = {q: f for q, f in dataset("10k").items() if q in qois}
    n = next(iter(fields.values())).shape[0]
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                           block_size=16, buffer_bytes=1 << 18)
    root = "mem://bench_serve"
    with CZDataset(root, "a", spec=spec, workers=4) as ds:
        ds.append(fields, time=0.0)

    rng = np.random.default_rng(7)
    lows = rng.integers(0, n - box, (n_regions, 3))
    weights = _zipf_weights(n_regions)
    srv_kw = dict(port=0, cache_bytes=32 << 20, cache_chunks=32,
                  max_inflight=n_threads, prefetch=prefetch)

    # phase 1: sampling disabled — the overhead baseline
    with RegionHTTPServer(root, sample=False, **srv_kw) as srv:
        srv.start()
        _, base_ms, _ = _drive(srv, qois, lows, box, n_threads, n_req,
                               weights)

    # phase 2: sampling enabled (the production default) — same load, plus
    # one deliberate error request so /debug/traces has a kept-on-error
    # entry whose response header we can check against the retained trace
    with RegionHTTPServer(root, sample=True, **srv_kw) as srv:
        srv.start()
        cold_ms, lat_ms, wall = _drive(srv, qois, lows, box, n_threads,
                                       n_req, weights)
        err_rid = "bench-err-0001"
        echoed = _error_request(srv, err_rid)
        debug = _traces_readout(srv, err_rid, echoed)
        stats = srv.region.stats()

    p50, p99 = np.percentile(lat_ms, [50, 99])
    base_p50 = float(np.percentile(base_ms, 50))
    overhead_pct = 100.0 * (float(p50) - base_p50) / base_p50
    total = n_threads * n_req
    rps = total / wall
    region_hr = stats["region_cache_hit_rate"] or 0.0
    chunk_hr = stats["cache_hit_rate"] or 0.0
    amplification = stats["bytes_decoded"] / max(1, stats["bytes_served"])

    results = {
        "n": n, "box": box, "threads": n_threads, "requests": total,
        "prefetch": prefetch,
        "n_regions": n_regions, "wall_s": wall, "rps": rps,
        "p50_ms": float(p50), "p99_ms": float(p99),
        "p50_nosample_ms": base_p50,
        "sampling_overhead_pct": overhead_pct,
        "cold_p50_ms": float(np.percentile(cold_ms, 50)),
        "cold_p99_ms": float(np.percentile(cold_ms, 99)),
        "region_cache_hit_rate": region_hr,
        "chunk_cache_hit_rate": chunk_hr,
        "decode_amplification": amplification,
        "debug_traces": debug,
        "server_stats": stats,
    }
    emit("serve_p50", p50 * 1e3, f"{rps:.0f}rps")
    emit("serve_p99", p99 * 1e3, f"{total}req_x{n_threads}thr")
    emit("serve_cold_p50", float(np.percentile(cold_ms, 50)) * 1e3,
         f"{len(cold_ms)}regions")
    emit("serve_hit_rate", region_hr * 1e6,
         f"region{region_hr:.2f}_chunk{chunk_hr:.2f}")
    emit("serve_sampling_overhead", overhead_pct * 1e3,
         f"p50_{p50:.2f}ms_vs_{base_p50:.2f}ms")
    emit("serve_traces_kept", debug["retained"],
         f"{debug['bytes']}B_of_{debug['budget_bytes']}B")
    MemoryStore.drop("bench_serve")
    path = save_json("serve", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (also the default under benchmarks.run)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="reader-side chunk prefetch depth (0 disables)")
    args = ap.parse_args()
    run(quick=not args.full, prefetch=args.prefetch)
