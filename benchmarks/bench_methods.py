"""Fig. 7/8 — PSNR vs CR for wavelets / zfpx / szx / fpzipx across QoIs,
timesteps and resolutions.

Expected reproductions: no single method dominates; zfpx strongest on a2;
wavelets competitive in the visualization band; higher resolution improves
the wavelet CR more than the others."""
from __future__ import annotations

import time

from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields

from .common import dataset, emit, eps_sweep, save_json, sweep


def _specs_for(scheme: str, eps_list):
    if scheme == "wavelet":
        return [CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=e)
                for e in eps_list]
    if scheme in ("zfpx", "szx"):
        return [CompressionSpec(scheme=scheme, eps=e) for e in eps_list]
    # fpzipx sweeps bits of precision instead of eps
    return [CompressionSpec(scheme="fpzipx", precision=p)
            for p in (28, 24, 20, 16, 12, 8)[: len(eps_list)]]


def run(quick: bool = True):
    eps_list = eps_sweep(n=4 if quick else 7)
    qois = ["p", "a2"] if quick else ["p", "rho", "E", "a2"]
    t_labels = ["10k"] if quick else ["5k", "10k"]
    rows = []
    t0 = time.time()
    for tl in t_labels:
        fields = dataset(tl)
        for q in qois:
            for scheme in ("wavelet", "zfpx", "szx", "fpzipx"):
                for spec, r in zip(_specs_for(scheme, eps_list),
                                   sweep(fields[q], _specs_for(scheme, eps_list))):
                    rows.append({"t": tl, "qoi": q, "scheme": scheme,
                                 "eps": spec.eps, "precision": spec.precision,
                                 "cr": r["cr"], "psnr": r["psnr"]})
    # Fig. 8: resolution effect (wavelets gain with resolution)
    res_rows = []
    if not quick:
        for n in (64, 128, 192):
            f = cavitation_fields(CloudConfig(n=n), 9.4)["p"]
            for scheme in ("wavelet", "zfpx", "szx"):
                spec = _specs_for(scheme, [1e-3])[0]
                r = sweep(f, [spec])[0]
                res_rows.append({"n": n, "scheme": scheme, "cr": r["cr"],
                                 "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig7_methods", rows)
    if res_rows:
        save_json("fig8_resolution", res_rows)

    # no-single-winner check + zfpx wins a2
    winners = set()
    for q in qois:
        sub = [r for r in rows if r["qoi"] == q and r["t"] == t_labels[-1]]
        best = max(sub, key=lambda r: r["cr"] if r["psnr"] > 40 else -1)
        winners.add(best["scheme"])
    emit("fig7_distinct_winners", dt * 1e6 / max(len(rows), 1), len(winners))
    a2 = [r for r in rows if r["qoi"] == "a2" and r["t"] == t_labels[-1]]
    besta2 = max(a2, key=lambda r: r["cr"] if r["psnr"] > 40 else -1)
    emit("fig7_best_on_a2", dt * 1e6 / max(len(rows), 1), besta2["scheme"])
    return rows


if __name__ == "__main__":
    run(quick=False)
