"""Compressed distributed checkpointing (the paper's I/O design, applied to
training state).

Layout mirrors CubismZ: **one file per quantity** ("params", "m", "v", ...),
each the concatenation of per-shard compressed buffers whose offsets come
from an exclusive prefix-sum over compressed sizes (``repro.dist.offsets`` —
the MPI_Exscan analogue; here shards are written by one process but the
offset计算 is the same collective structure a multi-host fleet would run).

Codec: lossless ``fpzipx`` + byte-shuffle + ZLIB by default (the paper's
restart-snapshot scheme, 2.6-4.3x there); optionally lossy wavelet/szx for
optimizer moments.  Every quantity file carries per-shard CRC32; the commit
is atomic (write to ``step_XXXX.tmp``, fsync, rename); ``latest`` resolves
to the newest *complete* checkpoint, so a crash mid-write never corrupts
restart.  Restore reshards to any device count (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import numpy as np
import jax

from repro.core import CompressedField, CompressionSpec, Pipeline
from repro.dist.offsets import exclusive_offsets_np

__all__ = ["Checkpointer", "FieldSnapshotter", "save_checkpoint",
           "load_checkpoint", "latest_step"]

_BS = 16                      # codec block side for flattened tensors
_BLOCK = _BS ** 3


def _leaf_key(path) -> str:
    return "/".join(getattr(k, "key", str(k)) for k in path)


def _to_blocks(arr: np.ndarray) -> tuple[np.ndarray, int]:
    """Flatten to (nb, 16,16,16) float32 blocks (zero-padded); returns pad."""
    flat = np.asarray(arr, np.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    return flat.reshape(-1, _BS, _BS, _BS), pad


def _compress_leaf(arr: np.ndarray, pipe: Pipeline, n_shards: int):
    """Returns (list of shard bytes, meta).  Shards emulate per-host writers."""
    if arr.dtype not in (np.float32, np.dtype("float32")):
        raw = arr.tobytes()
        buf = zlib.compress(raw, 1)
        return [buf], {"codec": "raw+zlib", "dtype": str(arr.dtype)}
    blocks, pad = _to_blocks(arr)
    nb = blocks.shape[0]
    per = max(1, nb // n_shards)
    shards = []
    for lo in range(0, nb, per):
        comp = pipe.compress_blocks(blocks[lo : lo + per])
        payload = json.dumps(comp.header).encode() + b"\0" + b"".join(comp.chunks)
        shards.append(payload)
    return shards, {"codec": pipe.spec.scheme, "pad": pad, "dtype": "float32"}


def _decompress_leaf(shard_bufs: list[bytes], meta: dict, shape, dtype):
    if meta["codec"] == "raw+zlib":
        raw = zlib.decompress(shard_bufs[0])
        return np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(shape).copy()
    blocks = []
    for buf in shard_bufs:
        hdr, rest = buf.split(b"\0", 1)
        header = json.loads(hdr)
        chunks, off = [], 0
        for sz in header["chunk_sizes"]:
            chunks.append(rest[off : off + sz])
            off += sz
        comp = CompressedField(chunks, header)
        # registry-driven decode; header["format"] keeps pre-v2 shards readable
        blocks.append(Pipeline(comp.spec).decompress_blocks(comp))
    flat = np.concatenate(blocks).reshape(-1)
    if meta.get("pad"):
        flat = flat[: -meta["pad"]] if meta["pad"] else flat
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].astype(np.dtype(dtype)).reshape(shape)


def save_checkpoint(ckpt_dir: str, state, step: int, *,
                    spec: CompressionSpec | None = None, n_shards: int = 8) -> dict:
    """Write one compressed checkpoint; returns manifest (incl. CR stats)."""
    spec = spec or CompressionSpec(scheme="fpzipx", precision=32,
                                   block_size=_BS, shuffle="byte")
    pipe = Pipeline(spec)
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    quantities: dict[str, list] = {}
    for path, leaf in leaves:
        key = _leaf_key(path)
        qty = key.split("/", 1)[0]
        quantities.setdefault(qty, []).append((key, np.asarray(leaf)))

    manifest = {"step": step, "spec": spec.to_json(), "quantities": {},
                "raw_bytes": 0, "compressed_bytes": 0}
    for qty, items in quantities.items():
        entries = []
        bufs = []
        for key, arr in items:
            shards, meta = _compress_leaf(arr, pipe, n_shards)
            sizes = [len(s) for s in shards]
            # exclusive prefix-sum offsets (the paper's parallel-write scheme)
            base = sum(len(b) for b in bufs)
            offsets = (exclusive_offsets_np(sizes) + base).tolist()
            entries.append({
                "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "meta": meta, "offsets": offsets, "sizes": sizes,
                "crc32": [zlib.crc32(s) & 0xFFFFFFFF for s in shards],
            })
            bufs.extend(shards)
            manifest["raw_bytes"] += arr.nbytes
            manifest["compressed_bytes"] += sum(sizes)
        with open(os.path.join(tmp, f"{qty}.czq"), "wb") as f:
            for b in bufs:
                f.write(b)
            f.flush()
            os.fsync(f.fileno())
        manifest["quantities"][qty] = entries
    manifest["cr"] = manifest["raw_bytes"] / max(1, manifest["compressed_bytes"])
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return manifest


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int | None = None):
    """Returns (flat dict key->np.ndarray, manifest). Elastic: the caller
    device_puts with whatever sharding/mesh the *new* fleet uses."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for qty, entries in manifest["quantities"].items():
        with open(os.path.join(d, f"{qty}.czq"), "rb") as f:
            blob = f.read()
        for e in entries:
            shards = []
            for off, sz, crc in zip(e["offsets"], e["sizes"], e["crc32"]):
                buf = blob[off : off + sz]
                if (zlib.crc32(buf) & 0xFFFFFFFF) != crc:
                    raise IOError(f"CRC mismatch in {qty}:{e['key']} shard")
                shards.append(buf)
            out[e["key"]] = _decompress_leaf(shards, e["meta"], tuple(e["shape"]),
                                             e["dtype"])
    return out, manifest


def restore_tree(template, flat: dict):
    """Rebuild a pytree matching ``template`` from the flat key->array dict."""
    def one(path, leaf):
        arr = flat[_leaf_key(path)]
        return arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr

    return jax.tree_util.tree_map_with_path(one, template)


class FieldSnapshotter:
    """Dataset-backed snapshot path for *field* state (simulation restart).

    Training pytrees go through :class:`Checkpointer`; 3D solver state (the
    paper's in-situ restart snapshots) goes into one append-mode
    :class:`repro.store.CZDataset` — every snapshot is a committed timestep
    of all quantities, so restart data gets the store's atomic manifest,
    random-access region reads, and concurrent shard encoding for free.
    """

    def __init__(self, ds_dir: str, every: int = 1,
                 spec: CompressionSpec | None = None, workers: int = 1):
        from repro.store import CZDataset

        self.every = every
        self.ds = CZDataset(ds_dir, mode="a",
                            spec=spec or CompressionSpec(scheme="fpzipx",
                                                         shuffle="byte"),
                            workers=workers)
        self._steps: dict[int, int] = {  # sim step -> dataset timestep
            int(ts["time"]): ts["t"]
            for q in self.ds.quantities
            for ts in self.ds.timestep_info(q)
            if ts["time"] is not None
        }

    def maybe_snapshot(self, fields: dict[str, np.ndarray], step: int,
                       force: bool = False) -> int | None:
        """Append one snapshot every ``every`` steps; returns its timestep.

        The simulation step is recorded as the timestep's ``time`` tag, so
        :meth:`restore` can resolve "latest" or an exact step after reopen.
        """
        if not force and (self.every <= 0 or step % self.every != 0):
            return None
        fields = {q: np.asarray(jax.device_get(f)) for q, f in fields.items()}
        t = self.ds.append(fields, time=float(step))
        self._steps[step] = t
        return t

    def restore(self, step: int | None = None):
        """Returns (fields dict, step) for ``step`` (default: latest); or
        (None, None) on an empty dataset."""
        if not self._steps:
            return None, None
        step = max(self._steps) if step is None else step
        t = self._steps[step]
        fields = {q: self.ds.read_field(q, t) for q in self.ds.quantities
                  if t in self.ds.timesteps(q)}
        return fields, step

    def close(self):
        self.ds.close()


class Checkpointer:
    """Periodic checkpoint manager with retention and resume support."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3,
                 spec: CompressionSpec | None = None):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.spec = spec
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, state, step: int, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return None
        manifest = save_checkpoint(self.dir, jax.device_get(state), step,
                                   spec=self.spec)
        self._gc()
        return manifest

    def _gc(self):
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def resume(self, template):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        flat, manifest = load_checkpoint(self.dir, step)
        return restore_tree(template, flat), step
