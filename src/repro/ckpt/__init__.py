"""Compressed distributed checkpointing (paper's parallel-I/O design)."""
from .checkpoint import (  # noqa: F401
    Checkpointer,
    FieldSnapshotter,
    latest_step,
    load_checkpoint,
    restore_tree,
    save_checkpoint,
)
