"""CZ container: single file per quantity, chunked, random-access decompress.

Mirrors CubismZ's output format design: one shared file per quantity with a
metadata header, followed by independently-decompressible chunks (the
per-thread aggregation buffers).  The reader keeps an LRU cache of recently
decompressed chunks so neighbouring block fetches hit the cache instead of
re-inflating (paper §2.3 "Data decompression").
"""
from __future__ import annotations

import collections
import json
import struct
import zlib

import numpy as np

from . import blocks as blk
from .codec import CompressedField, CompressionSpec, compress_field, _deserialize_chunk

__all__ = ["write_field", "read_field", "FieldReader", "MAGIC"]

MAGIC = b"CZ1\0"


def write_compressed(path: str, comp: CompressedField) -> int:
    """Write a CompressedField; returns total bytes written."""
    header = dict(comp.header)
    header["chunk_crc32"] = [zlib.crc32(c) & 0xFFFFFFFF for c in comp.chunks]
    hbytes = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(hbytes)))
        f.write(hbytes)
        for c in comp.chunks:
            f.write(c)
    return len(MAGIC) + 8 + len(hbytes) + sum(len(c) for c in comp.chunks)


def write_field(path: str, field: np.ndarray, spec: CompressionSpec) -> int:
    return write_compressed(path, compress_field(field, spec))


def _read_header(f) -> tuple[dict, int]:
    if f.read(4) != MAGIC:
        raise ValueError("not a CZ container")
    (hlen,) = struct.unpack("<Q", f.read(8))
    header = json.loads(f.read(hlen))
    return header, 12 + hlen


def read_field(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        header, off = _read_header(f)
        chunks = [f.read(sz) for sz in header["chunk_sizes"]]
    for c, crc in zip(chunks, header["chunk_crc32"]):
        if (zlib.crc32(c) & 0xFFFFFFFF) != crc:
            raise IOError("chunk CRC mismatch — corrupt container")
    comp = CompressedField(chunks, header)
    from .codec import decompress_field

    return decompress_field(comp)


class FieldReader:
    """Random block access with an LRU chunk cache (paper's decompressor)."""

    def __init__(self, path: str, cache_chunks: int = 8):
        self._f = open(path, "rb")
        self.header, data_start = _read_header(self._f)
        self.spec = CompressionSpec.from_json(self.header["spec"])
        sizes = self.header["chunk_sizes"]
        self._chunk_off = np.concatenate([[0], np.cumsum(sizes)])[:-1] + data_start
        self._chunk_nblk = self.header["chunk_nblocks"]
        self._blk0 = np.concatenate([[0], np.cumsum(self._chunk_nblk)])
        self.shape = tuple(self.header["field_shape"])
        self.nb = blk.num_blocks(self.shape, self.spec.block_size)
        self._cache: collections.OrderedDict[int, np.ndarray] = collections.OrderedDict()
        self._cache_chunks = cache_chunks
        self.cache_hits = 0
        self.cache_misses = 0

    def close(self):
        self._f.close()

    def _chunk(self, ci: int) -> np.ndarray:
        if ci in self._cache:
            self._cache.move_to_end(ci)
            self.cache_hits += 1
            return self._cache[ci]
        self.cache_misses += 1
        self._f.seek(self._chunk_off[ci])
        buf = self._f.read(self.header["chunk_sizes"][ci])
        out = _deserialize_chunk(buf, self._chunk_nblk[ci], self.spec)
        self._cache[ci] = out
        while len(self._cache) > self._cache_chunks:
            self._cache.popitem(last=False)
        return out

    def read_block(self, bx: int, by: int, bz: int) -> np.ndarray:
        """Decompress and return one (bs, bs, bs) block."""
        _, by_n, bz_n = self.nb
        flat = (bx * by_n + by) * bz_n + bz
        ci = int(np.searchsorted(self._blk0, flat, side="right")) - 1
        return self._chunk(ci)[flat - self._blk0[ci]]

    def read_all(self) -> np.ndarray:
        blocks = np.concatenate([self._chunk(i) for i in range(len(self._chunk_nblk))])
        return np.asarray(blk.unblockify(blocks, self.shape))
