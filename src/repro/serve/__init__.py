"""serve subsystem: compressed-field region serving and jitted LLM decode.

Two independent stacks share this package:

* **field serving** (jax-free import path): :class:`FieldRegionServer`
  (tiered decode cache + single-flight scheduler, ``serve.region`` /
  ``serve.cache`` / ``serve.scheduler``) and its HTTP front
  (:class:`RegionHTTPServer` + :class:`Client`, ``serve.http`` — stdlib
  ``http.server``, started via ``cz-compress serve``);
* **LLM decode** (``serve.step``): jitted prefill/decode steps — imported
  explicitly, never from here, so serving compressed fields stays free of
  the jax/model stack.
"""
from .cache import RegionCache  # noqa: F401
from .http import Client, RegionHTTPServer  # noqa: F401
from .region import FieldRegionServer, LatencyHistogram  # noqa: F401
from .scheduler import ChunkScheduler, SingleFlight  # noqa: F401

__all__ = ["FieldRegionServer", "RegionHTTPServer", "Client", "RegionCache",
           "ChunkScheduler", "SingleFlight", "LatencyHistogram"]
