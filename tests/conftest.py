"""Make ``pytest -q`` work from a clean checkout: put ``src`` on sys.path
(equivalent to ``PYTHONPATH=src`` or an editable install)."""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
