"""ZFP-style fixed-accuracy scheme: 4^3 cells, block-floating-point + lifting.

Byte layout per chunk: per-cell exponents (i8) followed by the shuffled
quantized-coefficient stream (i32).

``spec.device="jax"`` routes encode/decode through the fused Pallas kernels
(``repro.kernels.ops.zfpx_*``).  The kernel's integer streams are bit-equal
to the host reference, so device- and host-written containers are mutually
bit-exact to decode.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .. import zfpx as _zfp
from . import Scheme, register_scheme, route, shuffle_bytes, unshuffle_bytes


@register_scheme
class ZfpxScheme(Scheme):
    name = "zfpx"
    device_capable = True

    #: conformance contract: the eps-derived bit-plane truncation keeps the
    #: per-cell quantization error within a small multiple of eps (block
    #: floating point + lifting gain), verified by the conformance suite.
    BOUND_FACTOR = 16.0

    def validate(self, spec) -> None:
        if spec.block_size % 4:
            raise ValueError("zfpx needs block_size % 4 == 0")

    def params(self, spec) -> dict:
        return {"eps": spec.eps, **super().params(spec)}

    def error_bound(self, spec) -> float:
        return self.BOUND_FACTOR * spec.eps

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        emax, q = route(spec, _zfp.encode, "zfpx_encode")(x, eps=spec.eps)
        return {"emax": np.asarray(emax), "q": np.asarray(q)}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        emax = np.clip(s1["emax"][lo:hi], -127, 127).astype(np.int8)
        q = s1["q"][lo:hi].astype(np.int32)
        return emax.tobytes() + shuffle_bytes(q.tobytes(), spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        nc = (n // 4) ** 3
        emax = np.frombuffer(payload[: nblk * nc], np.int8).astype(np.int32)
        q = np.frombuffer(
            unshuffle_bytes(payload[nblk * nc :], spec.shuffle, 4), np.int32
        )
        emax = emax.reshape(nblk, nc)
        q = q.reshape(nblk, nc, 64)
        dec = route(spec, _zfp.decode, "zfpx_decode")
        return np.asarray(dec(jnp.asarray(emax), jnp.asarray(q),
                              eps=spec.eps, n=n))
