"""repro.obs coverage (ISSUE 7): registry thread-safety, label handling and
exposition-format validity, Chrome-trace validity and per-rank merge
ordering, serve ``/metrics`` name/value parity with the pre-registry
formatter, the store instrumentation wrapper, and the naming lint over
everything that actually registered."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.obs.trace import Tracer, merge_traces

N = 24
BS = 8
RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the process tracer disabled and
    empty — tracing state is global and must not leak between tests."""
    obs_trace.disable()
    obs_trace.reset()
    yield
    obs_trace.disable()
    obs_trace.reset()


# ---------------------------------------------------------------------------
# registry: kinds, labels, validation
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = obs.Registry()
    c = reg.counter("cz_t_reqs_total", "Requests.")
    c.inc()
    c.inc(4)
    assert c.value() == 5
    with pytest.raises(ValueError):
        c.inc(-1)

    g = reg.gauge("cz_t_depth", "Queue depth.")
    g.set(7)
    g.dec(3)
    assert g.value() == 4

    h = reg.histogram("cz_t_lat_seconds", "Latency.", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    snap = h.snapshot()
    assert snap["count"] == 3
    assert snap["buckets"] == [(0.1, 1), (1.0, 2), (float("inf"), 3)]
    assert snap["sum"] == pytest.approx(5.55)


def test_labelled_series_and_cardinality():
    reg = obs.Registry()
    c = reg.counter("cz_t_ops_total", "Ops.", labelnames=("backend", "op"))
    c.inc(backend="mem", op="get")
    c.inc(2, backend="mem", op="put")
    c.inc(backend="file", op="get")
    assert c.value(backend="mem", op="put") == 2
    assert c.value(backend="nope", op="get") == 0  # untouched series reads 0
    assert len(c.samples()) == 4  # the read above materialized its series
    with pytest.raises(ValueError):
        c.inc(backend="mem")  # missing a label
    with pytest.raises(ValueError):
        c.inc(backend="mem", op="get", extra="x")


def test_name_and_help_validation():
    reg = obs.Registry()
    with pytest.raises(ValueError):
        reg.counter("serve_queries", "No cz_ prefix.")
    with pytest.raises(ValueError):
        reg.counter("cz_Bad_Case", "Uppercase.")
    with pytest.raises(ValueError):
        reg.counter("cz_ok_total", "")
    with pytest.raises(ValueError):
        reg.histogram("cz_h_seconds", "le is reserved.", labelnames=("le",))


def test_get_or_create_idempotent_and_collisions():
    reg = obs.Registry()
    a = reg.counter("cz_t_total", "Help.")
    assert reg.counter("cz_t_total", "Different help ignored.") is a
    with pytest.raises(ValueError):
        reg.gauge("cz_t_total", "Kind mismatch.")
    with pytest.raises(ValueError):
        reg.counter("cz_t_total", "Labels mismatch.", labelnames=("x",))
    with pytest.raises(ValueError):
        reg.register(obs.Counter("cz_t_total", "Other object."))
    assert reg.register(a) is a  # same object: idempotent


def test_registry_thread_safety_under_concurrent_increments():
    reg = obs.Registry()
    c = reg.counter("cz_t_concurrent_total", "Contended.", labelnames=("w",))
    h = reg.histogram("cz_t_concurrent_seconds", "Contended.",
                      buckets=(0.5,))
    nthreads, per = 8, 2000

    def work(i):
        for _ in range(per):
            c.inc(w=i % 2)
            h.observe(0.1)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(nthreads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value(w=0) + c.value(w=1) == nthreads * per
    assert h.snapshot()["count"] == nthreads * per


def test_set_total_and_histogram_load():
    reg = obs.Registry()
    c = reg.counter("cz_t_sync_total", "Synced.")
    c.set_total(41)
    c.inc()
    assert c.value() == 42

    src = obs.Histogram("cz_t_src_seconds", "Src.", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        src.observe(v)
    dst = reg.histogram("cz_t_dst_seconds", "Dst.", buckets=(0.1, 1.0))
    dst.load(src.snapshot())
    assert dst.snapshot() == src.snapshot()
    with pytest.raises(ValueError):
        dst.load({"buckets": [(0.1, 1)], "sum": 0.1})  # wrong bucket count


def test_render_parse_roundtrip_and_format_validity():
    reg = obs.Registry()
    reg.counter("cz_t_a_total", "A.").inc(3)
    g = reg.gauge("cz_t_b_bytes", "B.", labelnames=("tier",))
    g.set(10, tier="hot")
    g.set(20, tier="cold")
    h = reg.histogram("cz_t_c_seconds", "C.", buckets=(0.1,))
    h.observe(0.05)
    text = reg.render()

    # every metric has HELP+TYPE, in registration order
    helps = [ln.split()[2] for ln in text.splitlines()
             if ln.startswith("# HELP")]
    assert helps == ["cz_t_a_total", "cz_t_b_bytes", "cz_t_c_seconds"]

    parsed = obs.parse_prometheus(text)
    assert parsed["cz_t_a_total"] == [({}, 3.0)]
    assert ({"tier": "hot"}, 10.0) in parsed["cz_t_b_bytes"]
    assert ({"tier": "cold"}, 20.0) in parsed["cz_t_b_bytes"]
    assert ({"le": "0.1"}, 1.0) in parsed["cz_t_c_seconds_bucket"]
    assert ({"le": "+Inf"}, 1.0) in parsed["cz_t_c_seconds_bucket"]
    assert parsed["cz_t_c_seconds_count"] == [({}, 1.0)]
    with pytest.raises(ValueError):
        obs.parse_prometheus("not a metric line at all !!!")


def test_snapshot_shape():
    reg = obs.Registry()
    reg.counter("cz_t_snap_total", "S.").inc(2)
    reg.histogram("cz_t_snap_seconds", "S.", buckets=(1.0,)).observe(0.5)
    snap = reg.snapshot()
    assert snap["cz_t_snap_total"]["kind"] == "counter"
    assert snap["cz_t_snap_total"]["samples"] == [{"labels": {}, "value": 2}]
    hrow = snap["cz_t_snap_seconds"]["samples"][0]
    assert hrow["count"] == 1 and hrow["buckets"][0] == [1.0, 1]
    json.dumps(snap)  # JSON-able end to end


# ---------------------------------------------------------------------------
# trace: span API, Chrome validity, merge ordering
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing_and_reuses_null_span():
    assert not obs_trace.tracing()
    s1 = obs_trace.span("x", a=1)
    s2 = obs_trace.span("y")
    assert s1 is s2  # the shared no-op singleton: no per-span allocation
    with s1:
        pass
    obs_trace.TRACER.record("x", 0, 10)
    assert obs_trace.TRACER.events() == []


def test_span_and_chrome_document():
    obs_trace.enable()
    with obs_trace.span("outer", chunk=3):
        with obs_trace.span("inner"):
            pass
    obs_trace.disable()
    doc = obs_trace.TRACER.chrome()
    json.dumps(doc)  # valid JSON end to end
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in evs} == {"outer", "inner"}
    assert any(e["name"] == "process_name" for e in meta)
    assert any(e["name"] == "thread_name" for e in meta)
    for e in evs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] and "tid" in e
    # inner closed first and events are ts-sorted: inner within outer
    outer = next(e for e in evs if e["name"] == "outer")
    inner = next(e for e in evs if e["name"] == "inner")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"] == {"chunk": 3}
    assert "epoch_us" in doc["metadata"]


def test_traced_decorator_and_thread_tracks():
    obs_trace.enable()

    @obs_trace.traced("worker_fn")
    def fn():
        return 42

    assert fn() == 42
    t = threading.Thread(target=fn, name="side")
    t.start()
    t.join()
    evs = obs_trace.TRACER.events()
    tids = {e["tid"] for e in evs if e["name"] == "worker_fn"}
    assert len(evs) == 2 and len(tids) == 2  # one track per thread
    names = {e["args"]["name"]
             for e in obs_trace.TRACER._metadata_events()
             if e["name"] == "thread_name"}
    assert "side" in names


def test_merge_traces_ordering_and_pid_assignment(tmp_path):
    paths = []
    for r, (epoch, ts0) in enumerate([(2_000_000, 5.0), (1_000_000, 3.0)]):
        tr = Tracer(process_name=f"rank {r}")
        tr.enable()
        tr._epoch_us = epoch  # deterministic anchors for the ordering check
        tr._events = [{"name": "encode", "ph": "X", "ts": ts0, "dur": 1.0,
                       "pid": tr.pid, "tid": 0}]
        p = str(tmp_path / f"r{r}.json")
        tr.save(p)
        paths.append(p)

    merged = merge_traces(paths, out=str(tmp_path / "merged.json"),
                          pids=[0, 1])
    evs = [e for e in merged["traceEvents"] if e["ph"] == "X"]
    # doc 1's epoch is 1s earlier -> becomes t=0 base; doc 0 shifts +1e6 us
    assert [e["pid"] for e in evs] == [1, 0]
    assert evs[0]["ts"] == pytest.approx(3.0)
    assert evs[1]["ts"] == pytest.approx(1_000_005.0)
    assert sorted(e["ts"] for e in evs) == [e["ts"] for e in evs]
    assert merged["metadata"]["merged_from"] == 2
    reloaded = json.load(open(tmp_path / "merged.json"))
    assert reloaded["traceEvents"] == json.loads(
        json.dumps(merged["traceEvents"]))


def test_absorb_shifts_onto_parent_timeline():
    parent = Tracer()
    parent.enable()
    parent._epoch_us = 1_000_000
    child_doc = {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 999, "tid": 0,
         "args": {"name": "main"}},
        {"name": "encode", "ph": "X", "ts": 10.0, "dur": 2.0,
         "pid": 999, "tid": 0},
    ], "metadata": {"epoch_us": 1_000_100}}
    n = parent.absorb(child_doc, pid=3, process_name="rank 3")
    assert n == 2
    evs = parent.events()
    span = next(e for e in evs if e["ph"] == "X")
    assert span["pid"] == 3 and span["ts"] == pytest.approx(110.0)
    meta = next(e for e in evs if e["ph"] == "M")
    assert meta["pid"] == 3 and meta["args"] == {"name": "rank 3"}


# ---------------------------------------------------------------------------
# instrumentation wiring: pipeline, reader, store
# ---------------------------------------------------------------------------

def _field(n=N):
    return RNG.normal(size=(n, n, n)).astype(np.float32)


def test_pipeline_encode_decode_metrics_and_spans():
    from repro.core import CompressionSpec, Pipeline

    enc = obs.REGISTRY.get("cz_pipeline_chunks_encoded_total")
    dec = obs.REGISTRY.get("cz_pipeline_chunks_decoded_total")
    raw = obs.REGISTRY.get("cz_pipeline_raw_bytes_total")
    out = obs.REGISTRY.get("cz_pipeline_encoded_bytes_total")
    e0, d0 = enc.value(scheme="raw"), dec.value(scheme="raw")
    r0, o0 = raw.value(scheme="raw"), out.value(scheme="raw")

    obs_trace.enable()
    pipe = Pipeline(CompressionSpec(scheme="raw", block_size=BS,
                                    buffer_bytes=1 << 12))
    field = _field()
    comp = pipe.compress(field)
    rec = pipe.decompress(comp)
    obs_trace.disable()

    np.testing.assert_array_equal(rec, field)
    nchunks = len(comp.chunks)
    assert nchunks > 1
    assert enc.value(scheme="raw") - e0 == nchunks
    assert dec.value(scheme="raw") - d0 == nchunks
    assert raw.value(scheme="raw") - r0 == field.nbytes
    assert out.value(scheme="raw") - o0 == sum(len(c) for c in comp.chunks)
    ratio = obs.REGISTRY.get("cz_pipeline_ratio").value(scheme="raw")
    assert ratio > 0
    names = [e["name"] for e in obs_trace.TRACER.events()]
    assert names.count("encode") == nchunks
    assert names.count("decode") == nchunks
    assert "stage1" in names
    echunks = sorted(e["args"]["chunk"] for e in obs_trace.TRACER.events()
                     if e["name"] == "encode")
    assert echunks == list(range(nchunks))


def test_reader_fetch_vs_decode_split(tmp_path):
    from repro.core import CompressionSpec, container

    reads = obs.REGISTRY.get("cz_reader_chunk_reads_total")
    fetched = obs.REGISTRY.get("cz_reader_fetched_bytes_total")
    fsec = obs.REGISTRY.get("cz_reader_fetch_seconds")
    dsec = obs.REGISTRY.get("cz_reader_decode_seconds")
    h0, m0 = reads.value(result="hit"), reads.value(result="miss")
    b0 = fetched.value()
    fc0, dc0 = fsec.snapshot()["count"], dsec.snapshot()["count"]

    path = str(tmp_path / "f.cz")
    spec = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 12)
    container.write_field(path, _field(), spec)
    with container.FieldReader(path, cache_chunks=4) as rd:
        rd.read_block(0, 0, 0)
        rd.read_block(0, 0, 0)  # second read: LRU hit, no fetch
    assert reads.value(result="miss") - m0 == 1
    assert reads.value(result="hit") - h0 == 1
    assert fetched.value() - b0 > 0
    assert fsec.snapshot()["count"] - fc0 == 1
    assert dsec.snapshot()["count"] - dc0 == 1


def test_instrumented_store_wrapper_and_open_store_knob():
    from repro.store.backends import (
        InstrumentedStore,
        MemoryStore,
        open_store,
    )

    st = InstrumentedStore(MemoryStore())
    st.put("a/b.cz", b"0123456789")
    assert st.get("a/b.cz", (2, 6)) == b"2345"
    assert st.list("a/") == ["a/b.cz"]
    assert st.exists("a/b.cz")
    st.put_atomic("m.json", b"{}")
    s = st.stats()
    assert s["get_requests"] == 1 and s["range_requests"] == 1
    assert s["put_requests"] == 2  # put + put_atomic
    assert s["bytes_fetched"] == 4 and s["bytes_put"] == 12
    assert s["list_requests"] == 1

    ops = obs.REGISTRY.get("cz_store_ops_total")
    before = ops.value(backend="mem", op="get")
    wrapped = open_store("mem://t_obs_knob", instrument=True)
    assert isinstance(wrapped, InstrumentedStore)
    wrapped.put("k", b"x")
    wrapped.get("k")
    assert ops.value(backend="mem", op="get") - before == 1
    # idempotent: an instrumented store is not double-wrapped
    assert open_store(wrapped, instrument=True) is wrapped
    MemoryStore.drop("t_obs_knob")


def test_rangestore_compat_counters_feed_the_meter():
    from repro.store.backends import RangeStore

    ops = obs.REGISTRY.get("cz_store_ops_total")
    g0 = ops.value(backend="range", op="get")
    st = RangeStore()
    st.put("k", b"x" * 100)
    st.get("k", (0, 10))
    st.get("k")
    # historical attribute views still move
    assert st.get_requests == 2 and st.range_requests == 1
    assert st.bytes_fetched == 110 and st.bytes_put == 100
    assert st.put_requests == 1
    stats = st.stats()
    assert stats["objects"] == 1 and stats["bytes_stored"] == 100
    assert "list_requests" not in stats  # historical stats() shape
    # and the same traffic landed in the global registry
    assert ops.value(backend="range", op="get") - g0 == 2
    with pytest.raises(AttributeError):
        st.get_requests = 5  # counters are views now, not assignable


# ---------------------------------------------------------------------------
# naming lint: everything registered in the process-wide registry
# ---------------------------------------------------------------------------

def test_naming_lint_every_registered_metric():
    # import every instrumented tier so its metrics exist, then lint
    import repro.core.container  # noqa: F401
    import repro.core.pipeline  # noqa: F401
    import repro.cluster.engine  # noqa: F401
    import repro.store.backends.instrument  # noqa: F401

    assert len(obs.REGISTRY) >= 10
    for m in obs.REGISTRY:
        assert obs_registry.NAME_RE.fullmatch(m.name), m.name
        assert m.help.strip(), f"{m.name} has no help string"
        assert m.kind in ("counter", "gauge", "histogram")
        for ln in m.labelnames:
            assert ln != "le"


# ---------------------------------------------------------------------------
# serve: /metrics parity with the pre-registry formatter
# ---------------------------------------------------------------------------

#: exact metric names (and order) the PR 5 hand-rolled formatter exposed —
#: the registry migration must keep /metrics byte-compatible in names.
SERVE_METRIC_NAMES = [
    "cz_serve_queries_total",
    "cz_serve_bytes_served_total",
    "cz_serve_bytes_decoded_total",
    "cz_serve_region_cache_hits_total",
    "cz_serve_region_cache_misses_total",
    "cz_serve_region_cache_evictions_total",
    "cz_serve_region_cache_bytes",
    "cz_serve_chunk_cache_hits_total",
    "cz_serve_chunk_cache_misses_total",
    "cz_serve_chunks_decoded_total",
    "cz_serve_coalesced_requests_total",
    "cz_serve_request_seconds",
    "cz_serve_traces_sampled_total",
    "cz_serve_traces_kept_total",
    "cz_serve_traces_evicted_total",
    "cz_serve_trace_bytes",
    "cz_serve_http_responses_total",
]


@pytest.fixture(scope="module")
def serve_setup(tmp_path_factory):
    from repro.core import CompressionSpec
    from repro.serve import RegionHTTPServer
    from repro.store import CZDataset

    root = str(tmp_path_factory.mktemp("obs_serve") / "ds")
    spec = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 12)
    with CZDataset(root, "a", spec=spec) as ds:
        ds.append({"p": _field()}, time=0.0)
    with RegionHTTPServer(root, port=0).start() as srv:
        yield srv


def test_serve_metrics_name_parity_and_values(serve_setup):
    from repro.serve import Client

    srv = serve_setup
    with Client(srv.url) as c:
        for _ in range(3):
            c.region("p", 0, (0, 0, 0), (8, 8, 8))
        text = c.metrics()

        helps = [ln.split()[2] for ln in text.splitlines()
                 if ln.startswith("# HELP")]
        types = [ln.split()[2] for ln in text.splitlines()
                 if ln.startswith("# TYPE")]
        assert helps == SERVE_METRIC_NAMES
        assert types == SERVE_METRIC_NAMES

        # the old formatter's literal shapes survive the migration
        assert "cz_serve_queries_total 3" in text.splitlines()
        assert 'cz_serve_request_seconds_bucket{le="0.0005"}' in text
        assert 'cz_serve_request_seconds_bucket{le="+Inf"}' in text
        assert 'cz_serve_http_responses_total{code="200"}' in text

        # structured access: metric() / metrics_dict() replace text grepping
        assert c.metric("cz_serve_queries_total") == 3
        stats = srv.region.stats()
        assert c.metric("cz_serve_bytes_served_total") == stats["bytes_served"]
        assert c.metric("cz_serve_http_responses_total",
                        labels={"code": 200}) >= 3
        md = c.metrics_dict()
        assert md["cz_serve_request_seconds_count"][0][1] == stats["queries"]
        with pytest.raises(KeyError):
            c.metric("cz_serve_nope_total")
        with pytest.raises(KeyError):
            c.metric("cz_serve_http_responses_total", labels={"code": 999})
        with pytest.raises(KeyError):
            c.metric("cz_serve_http_responses_total")  # labelled-only metric


def test_latency_histogram_is_an_obs_histogram():
    from repro.serve.region import LATENCY_BUCKETS, LatencyHistogram

    h = LatencyHistogram()
    assert isinstance(h, obs.Histogram)
    assert h.name == "cz_serve_request_seconds"
    assert h.bounds == tuple(LATENCY_BUCKETS)
    h.observe(0.004)
    snap = h.snapshot()
    assert snap["count"] == 1 and snap["sum"] == pytest.approx(0.004)
    assert snap["buckets"][-1][0] == float("inf")


# ---------------------------------------------------------------------------
# cluster: per-rank trace files merge into one timeline (CLI end-to-end)
# ---------------------------------------------------------------------------

def test_parallel_cli_writes_merged_rank_trace(tmp_path):
    from repro.launch.compress import parallel_main

    npy = str(tmp_path / "f.npy")
    np.save(npy, _field(32))
    trace_out = str(tmp_path / "t.json")
    # block 16 at 32^3 -> 8 blocks; 32 KiB buffers -> 2 blocks/chunk
    # -> 4 chunks across 2 ranks: every rank encodes and commits
    rc = parallel_main([
        "--ranks", "2", "--source", "npy", "--npy", npy,
        "--scheme", "raw", "--block-size", "16",
        "--buffer-bytes", str(32 << 10),
        "--out", str(tmp_path / "out"), "--trace", trace_out,
    ])
    assert rc == 0

    doc = json.load(open(trace_out))
    evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in evs}
    assert {"encode", "exscan", "commit"} <= names

    # one track per rank, carrying that rank's encode + commit spans
    parent_pid = None
    for e in evs:
        if e["name"] == "exscan":
            parent_pid = e["pid"]
    assert parent_pid is not None
    for rank in (0, 1):
        rank_names = {e["name"] for e in evs if e["pid"] == rank}
        assert "encode" in rank_names, f"rank {rank} has no encode span"
        assert "commit" in rank_names, f"rank {rank} has no commit span"
    assert len({e["pid"] for e in evs}) >= 3  # parent + 2 rank tracks
    rank_meta = {e["args"]["name"]
                 for e in doc["traceEvents"]
                 if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"rank 0", "rank 1"} <= rank_meta

    # rank encode spans carry the pipeline's per-chunk events too
    assert any(e["pid"] in (0, 1) and e["name"] == "encode"
               and "chunk" in e.get("args", {}) for e in evs)

    # timestamps are globally sorted (the merge contract)
    ts = [e["ts"] for e in doc["traceEvents"] if e.get("ph") != "M"]
    assert ts == sorted(ts)

    # no rank trace temp files leak next to the output
    leftovers = [p for p in (tmp_path / "out").iterdir()
                 if "trace" in p.name]
    assert leftovers == []

    # phase timing landed in the registry as well
    ph = obs.REGISTRY.get("cz_cluster_phase_seconds")
    for phase in ("encode", "exscan", "commit"):
        assert ph.snapshot(phase=phase)["count"] >= 1
