"""Host vs device (jit'd Pallas) stage-1 throughput per device-capable scheme.

Times exactly the substage-1 transform each scheme runs inside
``Pipeline.iter_chunks`` — ``Scheme.stage1`` over a whole block batch — for
``device="host"`` (jnp reference math) against ``device="jax"`` (the
``repro.kernels.ops`` wrappers: one jitted call per batch, real Pallas
lowering on TPU, interpret mode elsewhere).  On a CPU container the jax rows
chiefly guard the device path against rot (interpret mode is not a perf
proxy); on TPU they are the paper's stage-1 speedup readout.

CSV rows: ``device_<scheme>_<device>,us_per_call,MB/s``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CompressionSpec, get_scheme
from repro.core import blocks as blk

from .common import BENCH_N, dataset, emit, save_json

#: schemes with a kernel-backed stage 1 (raw/fpzipx/szx stay host-only)
DEVICE_SCHEMES = ("wavelet", "zfpx", "lorenzo")


def _spec(scheme: str, device: str, block_size: int) -> CompressionSpec:
    return CompressionSpec(scheme=scheme, device=device, eps=1e-3,
                           block_size=block_size).validate()


def _time_stage1(scheme_obj, blocks_np, spec, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        scheme_obj.stage1(blocks_np, spec)
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True) -> None:
    n = 48 if quick else BENCH_N
    block_size = 16 if quick else 32
    repeats = 3 if quick else 10
    field = dataset(n=n)["p"]
    blocks_np = np.asarray(blk.blockify(field, block_size))
    raw_mb = blocks_np.nbytes / 2**20

    rows = []
    for scheme in DEVICE_SCHEMES:
        sch = get_scheme(scheme)
        for device in ("host", "jax"):
            spec = _spec(scheme, device, block_size)
            sch.stage1(blocks_np, spec)  # warmup: trace + compile
            dt = _time_stage1(sch, blocks_np, spec, repeats)
            mbps = raw_mb / dt
            emit(f"device_{scheme}_{device}", dt * 1e6, f"{mbps:.1f}")
            rows.append({"scheme": scheme, "device": device, "n": n,
                         "block_size": block_size, "s_per_call": dt,
                         "MBps": mbps})
    save_json("device", {"quick": quick, "rows": rows})


if __name__ == "__main__":
    run(quick=True)
