"""Fig. 12 — in-situ compression during a running simulation.

The mini Euler solver advances a bubble-collapse configuration while the
I/O hook compresses p / rho / |U| snapshots (W3ai + SHUF + ZLIB, per-QoI
eps).  Reports CR over time and the in-situ overhead (compress time as a
fraction of simulation time) — the paper reports ~2% at 262k cores."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import CompressionSpec, compress_field
from repro.fields import EulerConfig, init_bubble_cloud
from repro.fields.euler3d import cfl_dt, primitives, run as run_solver

from .common import emit, save_json


def run(quick: bool = True):
    n = 48 if quick else 64
    steps_per_io = 10
    n_snapshots = 6 if quick else 12
    cfg = EulerConfig(n=n, n_bubbles=6)
    U = init_bubble_cloud(cfg)
    dt = cfl_dt(U)
    spec = lambda eps: CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=eps, block_size=16)

    rows = []
    sim_t = 0.0
    io_t = 0.0
    for snap in range(n_snapshots):
        t0 = time.time()
        U = run_solver(U, steps_per_io, dt=dt)
        jnp.asarray(U).block_until_ready()
        sim_t += time.time() - t0

        rho, vel, p = primitives(U)
        fields = {
            "p": np.asarray(p, np.float32),
            "rho": np.asarray(rho, np.float32),
            "Umag": np.asarray(jnp.linalg.norm(vel, axis=0), np.float32),
        }
        t0 = time.time()
        for q, f in fields.items():
            eps = 1e-4 * max(float(f.max() - f.min()), 1e-9)
            comp = compress_field(f, spec(eps))
            rows.append({"snapshot": snap, "qoi": q,
                         "cr": comp.header["raw_bytes"] / comp.nbytes})
        io_t += time.time() - t0

    overhead = io_t / max(sim_t + io_t, 1e-9)
    out = {"rows": rows, "sim_s": sim_t, "io_s": io_t, "overhead": overhead}
    save_json("fig12_insitu", out)
    mean_cr = float(np.mean([r["cr"] for r in rows]))
    emit("fig12_mean_cr", (sim_t + io_t) * 1e6 / n_snapshots, f"{mean_cr:.2f}")
    emit("fig12_io_overhead_frac", (sim_t + io_t) * 1e6 / n_snapshots,
         f"{overhead:.3f}")
    return out


if __name__ == "__main__":
    run(quick=False)
