"""Ex-situ compression of CFD output (the CubismZ tool use case):
compress all four QoIs of one snapshot into a CZDataset — a manifest-driven
directory of CZ2 members, one per quantity per timestep — then random-access
a sub-box through the store's chunk cache without inflating any full field.

Run:  PYTHONPATH=src python examples/compress_cfd.py
"""
from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields
from repro.store import CZDataset

fields = cavitation_fields(CloudConfig(n=64), t=9.4)
spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                       block_size=32, shuffle="byte")

# one append = one committed timestep of all quantities; chunk encoding for
# every member runs on a shared 4-thread pool (the paper's per-thread
# writers), drained in order so the files match a serial write byte-for-byte
with CZDataset("artifacts/example_dataset", mode="a", spec=spec,
               workers=4) as ds:
    t = ds.append(fields, time=9.4)
    for q in ds.quantities:
        ts = ds.timestep_info(q, t)
        print(f"{q:4s}: {ts['raw_bytes']/2**20:.1f} MiB -> "
              f"{ts['bytes']/2**20:.2f} MiB "
              f"(CR {ts['raw_bytes']/ts['bytes']:.1f}x) -> {ts['file']}")

# region read: only the chunks covering the box are decoded (LRU-cached)
ds = CZDataset("artifacts/example_dataset")
box = ds.read_box("p", t, (16, 0, 16), (48, 32, 48))
r = ds.reader("p", t)
print(f"box (16,0,16)-(48,32,48): shape {box.shape}, mean {box.mean():.3f}, "
      f"decoded {r.chunks_decoded}/{r.nchunks} chunks, "
      f"stats {ds.stats()}")
ds.close()
