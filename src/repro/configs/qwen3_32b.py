"""qwen3-32b — dense GQA with qk-norm [hf:Qwen/Qwen3]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
)
