"""Scientific-field substrate: cavitation QoI generator + mini Euler solver."""
from .cavitation import PAPER_TIMES, QOIS, CloudConfig, cavitation_fields  # noqa: F401
from .euler3d import EulerConfig, init_bubble_cloud, primitives, run, step  # noqa: F401
