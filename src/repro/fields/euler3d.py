"""Mini 3D compressible Euler solver (finite volume, Rusanov, RK2) in JAX.

Stands in for Cubism-MPCF as the *data producer* for the in-situ compression
benchmark (paper Fig. 12): an ideal-gas bubble-collapse configuration evolves
while the I/O hook compresses QoI snapshots.  Periodic box, conservative
update — mass/momentum/energy conserved to fp rounding (tested).

State layout: (5, n, n, n) = [rho, rho*u, rho*v, rho*w, E].
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["EulerConfig", "init_bubble_cloud", "step", "run", "primitives", "cfl_dt"]

GAMMA = 1.4


@dataclasses.dataclass(frozen=True)
class EulerConfig:
    n: int = 64
    n_bubbles: int = 8
    p_ambient: float = 10.0
    p_bubble: float = 0.5
    rho_liquid: float = 1.0
    rho_gas: float = 0.05
    seed: int = 7


def init_bubble_cloud(cfg: EulerConfig) -> jnp.ndarray:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n
    ax = (np.arange(n) + 0.5) / n
    X, Y, Z = np.meshgrid(ax, ax, ax, indexing="ij")
    chi = np.zeros((n, n, n), np.float32)
    for _ in range(cfg.n_bubbles):
        c = rng.uniform(0.3, 0.7, 3)
        r = rng.uniform(0.04, 0.09)
        d = np.sqrt((X - c[0]) ** 2 + (Y - c[1]) ** 2 + (Z - c[2]) ** 2)
        chi = np.maximum(chi, 0.5 * (1 - np.tanh((d - r) / (1.5 / n))))
    rho = cfg.rho_liquid * (1 - chi) + cfg.rho_gas * chi
    p = cfg.p_ambient * (1 - chi) + cfg.p_bubble * chi
    E = p / (GAMMA - 1)
    U = np.zeros((5, n, n, n), np.float32)
    U[0] = rho
    U[4] = E
    return jnp.asarray(U)


def primitives(U):
    rho = U[0]
    vel = U[1:4] / rho
    ke = 0.5 * rho * jnp.sum(vel**2, axis=0)
    p = (GAMMA - 1) * (U[4] - ke)
    return rho, vel, p


def _flux(U, axis: int):
    rho, vel, p = primitives(U)
    un = vel[axis]
    F = jnp.stack(
        [
            rho * un,
            U[1] * un + (p if axis == 0 else 0.0),
            U[2] * un + (p if axis == 1 else 0.0),
            U[3] * un + (p if axis == 2 else 0.0),
            (U[4] + p) * un,
        ]
    )
    return F


def _rusanov_div(U, dx: float):
    """sum_axis d(F)/dx with local Lax-Friedrichs (Rusanov) fluxes, periodic."""
    rho, vel, p = primitives(U)
    c = jnp.sqrt(GAMMA * jnp.maximum(p, 1e-8) / rho)
    div = jnp.zeros_like(U)
    for axis in range(3):
        sp = jnp.abs(vel[axis]) + c                      # wave speed
        F = _flux(U, axis)
        ax = axis + 1                                     # state axis offset
        Up = jnp.roll(U, -1, axis=ax)
        Fp = jnp.roll(F, -1, axis=ax)
        a = jnp.maximum(sp, jnp.roll(sp, -1, axis=axis))
        Fface_hi = 0.5 * (F + Fp) - 0.5 * a[None] * (Up - U)  # face i+1/2
        Fface_lo = jnp.roll(Fface_hi, 1, axis=ax)             # face i-1/2
        div = div + (Fface_hi - Fface_lo) / dx
    return div


@functools.partial(jax.jit, static_argnames=("n",))
def _step_impl(U, dt: float, n: int):
    dx = 1.0 / n
    k1 = -_rusanov_div(U, dx)
    U1 = U + dt * k1
    k2 = -_rusanov_div(U1, dx)
    return U + 0.5 * dt * (k1 + k2)


def step(U, dt: float):
    return _step_impl(U, dt, U.shape[-1])


def cfl_dt(U, cfl: float = 0.35) -> float:
    rho, vel, p = primitives(U)
    c = jnp.sqrt(GAMMA * jnp.maximum(p, 1e-8) / rho)
    smax = float(jnp.max(jnp.abs(vel) + c[None]))
    # dimension-unsplit 3D update: stability needs dt <= cfl * dx / (3 * smax)
    return cfl * (1.0 / U.shape[-1]) / (3.0 * smax)


def run(U, steps: int, dt: float | None = None):
    """Advance ``steps`` with a fixed (or CFL-derived) dt; returns final state."""
    if dt is None:
        dt = cfl_dt(U)
    n = U.shape[-1]

    def body(U, _):
        return _step_impl(U, dt, n), None

    U, _ = jax.lax.scan(body, U, None, length=steps)
    return U
