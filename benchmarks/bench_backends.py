"""Storage-backend benchmark: append + region-read cost per backend.

Same dataset, same spec, three stores — what does the byte-store layer
cost, and what does the read path ask of an object store?

* **append** — timesteps/s through FileStore (streaming file writer),
  MemoryStore (buffered put), and RangeStore (whole-object put);
* **read_box cold** — per-query latency with an empty chunk cache (every
  query pays ranged gets + decode);
* **read_box warm** — the same queries again through a warm cache (the
  backend drops out entirely — this row should be backend-independent);
* **amplification** — RangeStore's request counters over the cold pass:
  bytes fetched vs bytes stored, and requests per query.  This is the
  honesty check that region reads stay byte-ranged on S3-style backends.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core import CompressionSpec
from repro.store import CZDataset, FileStore, MemoryStore, RangeStore

from .common import dataset, emit, save_json


def _queries(n: int, box: int, k: int, seed: int = 11) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n - box, (k, 3))


def run(quick: bool = True):
    steps = 2 if quick else 6
    box = 24
    n_queries = 16 if quick else 64
    qois = ["p"] if quick else ["p", "rho"]

    fields = {q: f for q, f in dataset("10k").items() if q in qois}
    n = next(iter(fields.values())).shape[0]
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                           block_size=16, buffer_bytes=1 << 18)
    lows = _queries(n, box, n_queries)

    tmp = tempfile.mkdtemp()
    backends = {
        "file": FileStore(f"{tmp}/ds"),
        "mem": MemoryStore(),
        "range": RangeStore(),
    }
    results = {"n": n, "box": box, "steps": steps, "queries": n_queries,
               "backends": {}}
    for name, store in backends.items():
        t0 = time.perf_counter()
        with CZDataset(store, "a", spec=spec, workers=4) as ds:
            for k in range(steps):
                ds.append({q: f + np.float32(k) for q, f in fields.items()},
                          time=float(k))
        append_s = time.perf_counter() - t0

        # cold: fresh handle, tiny chunk cache -> every query hits the store
        before = store.stats() if name == "range" else None
        t0 = time.perf_counter()
        with CZDataset(store, cache_chunks=4) as ds:
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            cold_s = time.perf_counter() - t0
            amp = None
            if before is not None:
                after = store.stats()
                amp = {
                    "range_requests": after["range_requests"] - before["range_requests"],
                    "bytes_fetched": after["bytes_fetched"] - before["bytes_fetched"],
                    "bytes_stored": after["bytes_stored"],
                }
            # warm: same handle, same queries -> served from the chunk LRU
            ds.read_box(qois[0], 0, lows[0], lows[0] + box)  # prime
            t0 = time.perf_counter()
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            warm_s = time.perf_counter() - t0

        row = {
            "append_s": append_s,
            "steps_per_s": steps / append_s,
            "cold_us_per_query": cold_s / n_queries * 1e6,
            "warm_us_per_query": warm_s / n_queries * 1e6,
        }
        if amp is not None:
            row["amplification"] = amp
            row["fetched_over_stored"] = amp["bytes_fetched"] / amp["bytes_stored"]
            row["requests_per_query"] = amp["range_requests"] / n_queries
        results["backends"][name] = row

        emit(f"backends_append_{name}", append_s / steps * 1e6,
             f"{steps / append_s:.2f}steps_per_s")
        emit(f"backends_cold_{name}", row["cold_us_per_query"],
             f"{n_queries}q_box{box}")
        emit(f"backends_warm_{name}", row["warm_us_per_query"],
             f"{n_queries}q_box{box}")
    amp = results["backends"]["range"]["amplification"]
    emit("backends_range_amplification",
         results["backends"]["range"]["requests_per_query"] * 1e6,
         f"fetched{amp['bytes_fetched']}_stored{amp['bytes_stored']}")

    shutil.rmtree(tmp, ignore_errors=True)
    path = save_json("backends", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (also the default under benchmarks.run)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
