"""CZDataset: a directory of per-quantity/per-timestep CZ2 members.

See :mod:`repro.store` for the on-disk layout.  One object serves both ends
of the paper's workflow:

* **append mode** — an in-situ simulation opens the dataset once and calls
  :meth:`CZDataset.append` as snapshots are produced; every commit writes the
  member files first and then atomically patches the manifest, so readers
  never observe a half-written timestep.
* **random access** — :meth:`CZDataset.read_box` decodes only the chunks
  covering the requested sub-box through a pool of cached
  :class:`~repro.core.container.FieldReader` objects (each with its own LRU
  chunk cache); the full field is never inflated for a region query.
"""
from __future__ import annotations

import collections
import os
import re
import threading

import numpy as np

from repro.core.container import FieldReader
from repro.core.pipeline import CompressionSpec

from .manifest import (
    MANIFEST_NAME,
    ManifestError,
    new_manifest,
    read_manifest,
    write_manifest,
)
from .writer import ShardWriter

__all__ = ["CZDataset"]

_QUANTITY_RE = re.compile(r"^[A-Za-z0-9_.\-]+$")


class CZDataset:
    """Sharded multi-quantity dataset store over CZ2 member files.

    Parameters
    ----------
    root:
        Dataset directory.
    mode:
        ``"r"`` (read-only, manifest must exist) or ``"a"`` (append; the
        dataset is created on first use if ``root`` holds no manifest).
    spec:
        Dataset-default :class:`CompressionSpec` for newly created datasets
        (ignored when opening an existing one — the committed spec wins).
        The dtype tag is re-derived per quantity from the appended array.
    workers:
        Encode threads shared by all member writes of this dataset
        (``1`` = serial; output is byte-identical either way).
    """

    def __init__(self, root: str, mode: str = "r",
                 spec: CompressionSpec | None = None, workers: int = 1,
                 cache_readers: int = 8, cache_chunks: int = 8):
        if mode not in ("r", "a"):
            raise ValueError(f"mode must be 'r' or 'a', got {mode!r}")
        self.root = str(root)
        self.mode = mode
        self._lock = threading.RLock()
        self._cache_readers = cache_readers
        self._cache_chunks = cache_chunks
        self._readers: collections.OrderedDict[tuple[str, int], FieldReader] = \
            collections.OrderedDict()
        self._retired_decoded = 0
        self._retired_hits = 0

        try:
            self._m = read_manifest(self.root)
        except ManifestError:
            if mode != "a" or os.path.exists(
                    os.path.join(self.root, MANIFEST_NAME)):
                raise  # corrupt, or missing in read-only mode: surface it
            os.makedirs(self.root, exist_ok=True)
            self._m = new_manifest((spec or CompressionSpec()).validate().to_json())
            write_manifest(self.root, self._m)
        self.spec = CompressionSpec.from_json(self._m["spec"])
        self._writer = (ShardWriter(self.spec, workers=workers)
                        if mode == "a" else None)

    # -- introspection -----------------------------------------------------

    @property
    def quantities(self) -> list[str]:
        return sorted(self._m["quantities"])

    def timesteps(self, quantity: str) -> list[int]:
        """Committed timestep indices for one quantity, in append order."""
        return [ts["t"] for ts in self._entry(quantity)["timesteps"]]

    def timestep_info(self, quantity: str, t: int | None = None):
        """Committed timestep record(s) — ``{"t", "time", "file", "bytes",
        "raw_bytes"}`` dicts (copies).  ``t=None`` returns the full list."""
        if t is None:
            return [dict(ts) for ts in self._entry(quantity)["timesteps"]]
        return dict(self._timestep(quantity, int(t)))

    def shape(self, quantity: str) -> tuple[int, int, int]:
        return tuple(self._entry(quantity)["shape"])

    def dtype(self, quantity: str) -> np.dtype:
        return np.dtype(self._entry(quantity)["dtype"])

    @property
    def version(self) -> int:
        return int(self._m["version"])

    def _entry(self, quantity: str) -> dict:
        try:
            return self._m["quantities"][quantity]
        except KeyError:
            raise KeyError(
                f"quantity {quantity!r} not in dataset "
                f"(has: {', '.join(self.quantities) or 'none'})") from None

    def _timestep(self, quantity: str, t: int) -> dict:
        for ts in self._entry(quantity)["timesteps"]:
            if ts["t"] == t:
                return ts
        raise KeyError(f"quantity {quantity!r} has no timestep {t} "
                       f"(has: {self.timesteps(quantity)})")

    def refresh(self) -> None:
        """Re-read the manifest (pick up commits by a concurrent appender)."""
        with self._lock:
            self._m = read_manifest(self.root)

    # -- append mode -------------------------------------------------------

    def append(self, fields: dict[str, np.ndarray],
               time: float | None = None) -> int:
        """Commit one timestep of one or more quantities; returns its index.

        Member files are written first (concurrently chunk-encoded through
        the shared pool), then the manifest is patched atomically — a crash
        mid-append leaves at most orphaned member files, never a timestep
        that is half-visible.
        """
        if self._writer is None:
            raise IOError("dataset opened read-only; reopen with mode='a'")
        if not fields:
            raise ValueError("append needs at least one quantity")
        with self._lock:
            t = int(self._m["next_t"])
            staged = []
            for q, field in fields.items():
                if not _QUANTITY_RE.match(q):
                    raise ValueError(f"invalid quantity name {q!r}")
                field = np.asarray(field)
                ent = self._m["quantities"].get(q)
                if ent is not None and tuple(ent["shape"]) != field.shape:
                    raise ValueError(
                        f"quantity {q!r} has shape {tuple(ent['shape'])}, "
                        f"append got {field.shape}")
                rel = os.path.join(q, f"t{t:06d}.cz")
                os.makedirs(os.path.join(self.root, q), exist_ok=True)
                nbytes = self._writer.write(
                    os.path.join(self.root, rel), field,
                    extra_header={"quantity": q, "t": t, "time": time})
                staged.append((q, field, rel, nbytes))
            # all members on disk -> patch the manifest in one atomic commit
            for q, field, rel, nbytes in staged:
                ent = self._m["quantities"].setdefault(q, {
                    "shape": list(field.shape),
                    "dtype": str(self._writer.spec_for(field).np_dtype),
                    "timesteps": [],
                })
                ent["timesteps"].append({
                    "t": t, "time": time, "file": rel, "bytes": int(nbytes),
                    "raw_bytes": int(field.nbytes),
                })
            self._m["next_t"] = t + 1
            self._m["version"] = int(self._m["version"]) + 1
            write_manifest(self.root, self._m)
            return t

    # -- random access -----------------------------------------------------

    def reader(self, quantity: str, t: int) -> FieldReader:
        """Cached (LRU) FieldReader for one member — the decode cache shared
        by every region query against that quantity/timestep."""
        key = (quantity, int(t))
        with self._lock:
            r = self._readers.get(key)
            if r is not None:
                self._readers.move_to_end(key)
                return r
            ts = self._timestep(quantity, int(t))
            r = FieldReader(os.path.join(self.root, ts["file"]),
                            cache_chunks=self._cache_chunks)
            self._readers[key] = r
            while len(self._readers) > self._cache_readers:
                _, old = self._readers.popitem(last=False)
                self._retired_decoded += old.chunks_decoded
                self._retired_hits += old.cache_hits
                old.close()
            return r

    def read_box(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        """Decode the sub-box ``[lo, hi)`` of one quantity at one timestep,
        touching only the chunks that cover it."""
        return self.reader(quantity, t).read_box(lo, hi)

    def read_field(self, quantity: str, t: int) -> np.ndarray:
        """Decode one full field (through the same chunk cache)."""
        return self.reader(quantity, t).read_all()

    def stats(self) -> dict:
        """Aggregate decode-cache counters across member readers."""
        with self._lock:
            live = list(self._readers.values())
            return {
                "open_readers": len(live),
                "chunks_decoded": self._retired_decoded
                + sum(r.chunks_decoded for r in live),
                "cache_hits": self._retired_hits
                + sum(r.cache_hits for r in live),
            }

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        with self._lock:
            for r in self._readers.values():
                self._retired_decoded += r.chunks_decoded
                self._retired_hits += r.cache_hits
                r.close()
            self._readers.clear()
            if self._writer is not None:
                self._writer.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __repr__(self) -> str:
        qs = {q: len(self._m["quantities"][q]["timesteps"])
              for q in self.quantities}
        return (f"CZDataset({self.root!r}, mode={self.mode!r}, "
                f"quantities={qs}, version={self.version})")
