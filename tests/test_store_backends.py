"""Backend conformance: the Store protocol contract and CZDataset behavior
over every built-in backend (file / memory / object-store), plus the
fault-injection wrapper and the URL registry.

Two layers:

* **protocol contract** — put/get/ranged-get/list/delete/exists/put_atomic/
  open_write/lock behave identically on every backend (parametrized over
  all three);
* **dataset conformance** — a CZDataset appended through any backend reads
  back bit-exact, member objects are byte-identical *across* backends
  (FileStore's streaming file writer and the buffered object-store sink
  must produce the same CZ2 bytes), gc agrees everywhere, and the
  object-store backend proves the read path really is byte-ranged.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import CompressionSpec, container
from repro.store import (
    CZDataset,
    FileStore,
    FlakyStore,
    InjectedFault,
    MemoryStore,
    RangeStore,
    open_store,
)
from repro.store.backends import STORE_SCHEMES, Store, register_store_scheme

from test_pipeline_api import smooth_field

N = 32
BS = 16
# 16 KiB buffers -> one 16^3 float32 block per chunk: 8 chunks per member
SPEC = CompressionSpec(scheme="raw", block_size=BS, buffer_bytes=1 << 14)

FIELDS = {"p": smooth_field(N, seed=3), "rho": smooth_field(N, seed=4)}

BACKENDS = ["file", "mem", "range"]


def _make_store(kind: str, tmp_path) -> Store:
    if kind == "file":
        return FileStore(os.path.join(tmp_path, "ds"))
    if kind == "mem":
        return MemoryStore()
    return RangeStore()


@pytest.fixture(params=BACKENDS)
def store(request, tmp_path):
    return _make_store(request.param, tmp_path)


# ---------------------------------------------------------------------------
# protocol contract
# ---------------------------------------------------------------------------

def test_put_get_roundtrip_and_ranges(store):
    store.put("a/b.bin", b"0123456789")
    assert store.get("a/b.bin") == b"0123456789"
    assert store.get("a/b.bin", (2, 5)) == b"234"
    assert store.get("a/b.bin", (4, None)) == b"456789"
    assert store.get("a/b.bin", (0, 0)) == b""
    # a range past the end returns what exists (HTTP-range semantics)
    assert store.get("a/b.bin", (8, 100)) == b"89"
    store.put("a/b.bin", b"xy")  # overwrite replaces the whole object
    assert store.get("a/b.bin") == b"xy"


def test_range_contract(store):
    """The pinned Store.get range semantics (HTTP-416 contract): short
    reads only at EOF, a start at/past the object's end raises
    StoreRangeError, start 0 is always in range."""
    from repro.store.backends import StoreKeyError, StoreRangeError

    store.put("a/b.bin", b"0123456789")
    # short read at EOF is fine — start strictly inside the object
    assert store.get("a/b.bin", (8, 100)) == b"89"
    assert store.get("a/b.bin", (9, None)) == b"9"
    # start at or past the end can never be satisfied
    for start in (10, 11, 100):
        for end in (None, start + 4):
            with pytest.raises(StoreRangeError) as ei:
                store.get("a/b.bin", (start, end))
            assert ei.value.start == start
            assert isinstance(ei.value, IOError)
    # start 0 is always in range, even on an empty object
    store.put("a/empty.bin", b"")
    assert store.get("a/empty.bin", (0, None)) == b""
    assert store.get("a/empty.bin", (0, 8)) == b""
    with pytest.raises(StoreRangeError):
        store.get("a/empty.bin", (1, None))
    # a missing key is a key error even when the range would also be bad
    with pytest.raises(StoreKeyError):
        store.get("a/nope.bin", (100, None))


def test_missing_key_raises_storekeyerror(store):
    from repro.store import StoreKeyError

    for op in (lambda: store.get("nope"), lambda: store.get("nope", (0, 4)),
               lambda: store.delete("nope")):
        with pytest.raises(StoreKeyError) as ei:
            op()
        assert isinstance(ei.value, KeyError)
        assert "nope" in str(ei.value)
    assert not store.exists("nope")


def test_list_prefix_sorted(store):
    for k in ("q/t2.cz", "q/t0.cz", "p/t0.cz", "manifest.json"):
        store.put(k, b"x")
    assert store.list("") == ["manifest.json", "p/t0.cz", "q/t0.cz", "q/t2.cz"]
    assert store.list("q/") == ["q/t0.cz", "q/t2.cz"]
    assert store.list("manifest") == ["manifest.json"]
    assert store.list("zzz") == []


def test_delete_and_exists(store):
    store.put("p/t0.cz", b"x")
    assert store.exists("p/t0.cz")
    store.delete("p/t0.cz")
    assert not store.exists("p/t0.cz")
    assert store.list("") == []


def test_put_atomic_overwrites(store):
    store.put_atomic("manifest.json", b'{"v": 1}')
    store.put_atomic("manifest.json", b'{"v": 2}')
    assert store.get("manifest.json") == b'{"v": 2}'
    assert store.list("") == ["manifest.json"]  # no tmp residue


def test_open_write_streams_and_commits(store):
    with store.open_write("p/t0.cz") as f:
        f.write(b"head")
        f.write(b"body")
        f.seek(0)
        f.write(b"H")  # the CZ2 writer seeks back to patch its footer ptr
    assert store.get("p/t0.cz") == b"Headbody"


def test_open_write_exception_leaves_no_torn_object(store):
    with pytest.raises(RuntimeError):
        with store.open_write("p/t0.cz") as f:
            f.write(b"partial")
            raise RuntimeError("simulated encoder crash")
    # FileStore necessarily has a partial file (it streams); the contract
    # is that *buffered* backends never expose a torn object
    if not isinstance(store, FileStore):
        assert not store.exists("p/t0.cz")


def test_bad_keys_rejected(store):
    for bad in ("", "/abs", "a//b", "a/../b", ".", "..", "a\\b", None, 7):
        with pytest.raises((ValueError, TypeError)):
            store.put(bad, b"x")


def test_lock_is_exclusive(store):
    counter = {"v": 0}

    def bump():
        for _ in range(200):
            with store.lock(".l"):
                v = counter["v"]
                counter["v"] = v + 1

    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert counter["v"] == 800


# ---------------------------------------------------------------------------
# dataset conformance
# ---------------------------------------------------------------------------

def _fill(store) -> CZDataset:
    ds = CZDataset(store, "a", spec=SPEC)
    for k in range(2):
        ds.append({q: f + np.float32(k) for q, f in FIELDS.items()},
                  time=0.5 * k)
    return ds


def test_dataset_roundtrip_every_backend(store):
    with _fill(store):
        pass
    with CZDataset(store) as ds:
        assert ds.quantities == ["p", "rho"]
        for q, f in FIELDS.items():
            np.testing.assert_array_equal(ds.read_field(q, 0), f)
            np.testing.assert_array_equal(
                ds.read_box(q, 1, (3, 4, 5), (19, 20, 21)),
                (f + np.float32(1))[3:19, 4:20, 5:21])


def test_members_byte_identical_across_backends(tmp_path):
    stores = [_make_store(kind, tmp_path) for kind in BACKENDS]
    for st in stores:
        with _fill(st):
            pass
    ref = stores[0]
    keys = [k for k in ref.list("") if k.endswith(".cz")]
    assert len(keys) == 4
    for st in stores[1:]:
        assert [k for k in st.list("") if k.endswith(".cz")] == keys
        for k in keys:
            assert st.get(k) == ref.get(k), f"{k} differs on {st.url}"


def test_file_url_opens_plain_path_dataset(tmp_path):
    """A dataset created with the historical plain-path constructor opens
    unchanged through its file:// URL (and vice versa)."""
    root = os.path.join(tmp_path, "ds")
    with CZDataset(root, "a", spec=SPEC) as ds:
        ds.append(FIELDS)
    with CZDataset(f"file://{root}") as ds:
        np.testing.assert_array_equal(ds.read_field("p", 0), FIELDS["p"])
    # and the manifest on disk is where it always was
    with open(os.path.join(root, "manifest.json")) as f:
        assert json.load(f)["magic"] == "CZDS"


def test_mem_url_shares_one_registry_instance(tmp_path):
    with CZDataset("mem://conformance", "a", spec=SPEC) as w:
        w.append(FIELDS)
        with CZDataset("mem://conformance") as r:
            np.testing.assert_array_equal(r.read_field("rho", 0),
                                          FIELDS["rho"])
        t = w.append(FIELDS)  # a second handle sees later commits too
        with CZDataset("mem://conformance") as r:
            assert r.timesteps("p") == [0, t]
    MemoryStore.drop("conformance")


def test_gc_identical_across_backends(tmp_path):
    want = ["manifest.json.tmp", "p/t000099.cz", "rho/t000000.cz.rank0.part"]
    for kind in BACKENDS:
        st = _make_store(kind, tmp_path / kind)
        with _fill(st):
            pass
        st.put("p/t000099.cz", b"orphan")              # torn append
        st.put("rho/t000000.cz.rank0.part", b"part")   # stale partial
        st.put("manifest.json.tmp", b"{}")             # stale commit tmp
        with CZDataset(st) as ds:
            assert ds.gc(dry_run=True) == want
        with CZDataset(st, "a") as ds:
            assert ds.gc() == want
            assert ds.gc(dry_run=True) == []
        for k in want:
            assert not st.exists(k)


def test_rangestore_reads_are_byte_ranged(tmp_path):
    """The acceptance check on the whole refactor: a sub-box read over the
    object-store backend fetches *byte ranges*, not whole members."""
    st = RangeStore()
    with _fill(st):
        pass
    stored = st.stats()["bytes_stored"]
    before = st.stats()
    with CZDataset(st, cache_chunks=2) as ds:
        box = ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))  # 1 of 8 chunks
    np.testing.assert_array_equal(box, FIELDS["p"][:BS, :BS, :BS])
    delta_reqs = st.stats()["range_requests"] - before["range_requests"]
    delta_bytes = st.stats()["bytes_fetched"] - before["bytes_fetched"]
    assert delta_reqs >= 2            # footer fetch + >=1 chunk fetch
    assert 0 < delta_bytes < stored / 4  # nowhere near a full-member read


def test_rank_parallel_append_over_memory_store():
    from repro.cluster.multiwriter import RankWriter, merge_manifests

    st = MemoryStore.named("conformance_ranks")
    try:
        with CZDataset(st, "a", spec=SPEC):
            pass
        for rank in range(2):
            with RankWriter(st, rank) as w:
                w.append({"p": FIELDS["p"] + np.float32(rank)}, t=rank)
        assert merge_manifests(st) == 2
        with CZDataset(st) as ds:
            assert ds.timesteps("p") == [0, 1]
            np.testing.assert_array_equal(ds.read_field("p", 1),
                                          FIELDS["p"] + np.float32(1))
    finally:
        MemoryStore.drop("conformance_ranks")


def test_region_server_over_mem_url():
    from repro.serve import FieldRegionServer

    with CZDataset("mem://conformance_serve", "a", spec=SPEC) as w:
        w.append(FIELDS)
    try:
        with FieldRegionServer("mem://conformance_serve") as srv:
            reg = srv.query("p", 0, (1, 2, 3), (9, 10, 11))
            np.testing.assert_array_equal(reg, FIELDS["p"][1:9, 2:10, 3:11])
    finally:
        MemoryStore.drop("conformance_serve")


# ---------------------------------------------------------------------------
# fault injection: mid-read failures surface cleanly, retry succeeds
# ---------------------------------------------------------------------------

def test_flaky_store_read_box_fails_clean_then_retries():
    flaky = FlakyStore(MemoryStore())
    with _fill(flaky):
        pass
    with CZDataset(flaky, cache_chunks=8) as ds:
        warm = ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS))  # caches chunk 0
        flaky.fail_on_get = flaky.gets + 1  # arm: next get (a cold chunk)
        with pytest.raises(InjectedFault):
            ds.read_box("p", 0, (BS, 0, 0), (N, BS, BS))  # needs a cold chunk
        assert flaky.faults == 1
        assert isinstance(InjectedFault("x"), IOError)  # surfaces as IOError
        # caches were not corrupted by the failed fetch: the warm box still
        # serves without any store traffic, and the retry round-trips
        gets = flaky.gets
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (0, 0, 0), (BS, BS, BS)), warm)
        assert flaky.gets == gets
        np.testing.assert_array_equal(
            ds.read_box("p", 0, (BS, 0, 0), (N, BS, BS)),
            FIELDS["p"][BS:N, :BS, :BS])


def test_flaky_store_periodic_faults_counted():
    flaky = FlakyStore(MemoryStore(), fail_on_get=2, fail_every=2)
    flaky.put("k", b"abc")
    assert flaky.get("k") == b"abc"          # get #1
    with pytest.raises(InjectedFault):
        flaky.get("k")                       # get #2: first fault
    assert flaky.get("k") == b"abc"          # get #3
    with pytest.raises(InjectedFault):
        flaky.get("k")                       # get #4: periodic fault
    assert flaky.faults == 2


def test_flaky_store_put_faults():
    """Write-path injection: ``put`` and ``put_atomic`` share one counter,
    and the buffered ``open_write`` sink commits through ``put`` so
    streamed member writes are injectable too."""
    flaky = FlakyStore(MemoryStore(), fail_on_put=2)
    flaky.put("a", b"1")                       # put #1
    with pytest.raises(InjectedFault):
        flaky.put_atomic("b", b"2")            # put #2: fault
    assert flaky.faults == 1 and flaky.puts == 2
    flaky.fail_on_put = flaky.puts + 1         # arm the next commit
    with pytest.raises(InjectedFault):
        with flaky.open_write("c") as f:       # commit = put #3
            f.write(b"stream")
    assert not flaky.inner.exists("c")         # no torn object visible
    with flaky.open_write("c") as f:           # unarmed: commits fine
        f.write(b"stream")
    assert flaky.get("c") == b"stream"
    # per-op arms cover the rest of the protocol
    flaky.fail_on_op = {"delete": 1, "list": 1}
    with pytest.raises(InjectedFault):
        flaky.delete("a")
    with pytest.raises(InjectedFault):
        flaky.list("")
    assert flaky.exists("a")                   # exists is never faulted


def test_mid_append_fault_leaves_last_committed_state():
    """A fault anywhere inside an append — member write or manifest commit
    — must leave the dataset readable at its previous committed state:
    members are written before the manifest's put_atomic publishes them."""
    flaky = FlakyStore(MemoryStore())
    with _fill(flaky):                          # 2 committed timesteps
        pass
    for arm in ("member", "manifest"):
        with CZDataset(flaky, "a", spec=SPEC) as ds:
            if arm == "member":
                flaky.fail_on_put = flaky.puts + 1   # first member write
            else:
                # let both member puts through, fail the manifest commit
                flaky.fail_on_op = {"put_atomic":
                                    flaky.op_calls.get("put_atomic", 0) + 1}
            with pytest.raises(InjectedFault):
                ds.append({q: f + np.float32(9) for q, f in FIELDS.items()})
            flaky.fail_on_put = None
            flaky.fail_on_op = {}
        with CZDataset(flaky) as ds:            # reopen: last committed state
            assert ds.timesteps("p") == [0, 1]
            np.testing.assert_array_equal(
                ds.read_field("p", 1), FIELDS["p"] + np.float32(1))
        # the torn append left at most orphans gc can identify, not members
        with CZDataset(flaky, "a") as ds:
            ds.gc()
            assert ds.gc(dry_run=True) == []


def test_mid_merge_fault_leaves_sidecars_intact():
    """An injected fault during merge_manifests (its manifest put_atomic)
    leaves the primary manifest at its previous state and the rank sidecars
    in place, so a retried merge completes."""
    from repro.cluster.multiwriter import RankWriter, merge_manifests

    flaky = FlakyStore(MemoryStore())
    with CZDataset(flaky, "a", spec=SPEC):
        pass
    for rank in range(2):
        with RankWriter(flaky, rank) as w:
            w.append({"p": FIELDS["p"] + np.float32(rank)}, t=rank)
    flaky.fail_on_op = {"put_atomic":
                        flaky.op_calls.get("put_atomic", 0) + 1}
    with pytest.raises(InjectedFault):
        merge_manifests(flaky)
    flaky.fail_on_op = {}
    with CZDataset(flaky) as ds:                 # primary manifest untouched
        assert ds.quantities == []
    assert merge_manifests(flaky) == 2           # retry completes the merge
    with CZDataset(flaky) as ds:
        assert ds.timesteps("p") == [0, 1]
        np.testing.assert_array_equal(ds.read_field("p", 1),
                                      FIELDS["p"] + np.float32(1))


# ---------------------------------------------------------------------------
# URL registry
# ---------------------------------------------------------------------------

def test_open_store_url_parsing(tmp_path):
    st = open_store(os.path.join(tmp_path, "plain"))
    assert isinstance(st, FileStore)
    st = open_store(f"file://{tmp_path}/sub")
    assert isinstance(st, FileStore) and st.root.endswith("sub")
    assert open_store("mem://conformance_urls") is \
        open_store("mem://conformance_urls")
    MemoryStore.drop("conformance_urls")
    r = open_store("range://conformance_urls")
    assert isinstance(r, RangeStore)
    RangeStore.drop("conformance_urls")
    passthrough = MemoryStore()
    assert open_store(passthrough) is passthrough
    with pytest.raises(ValueError, match="unknown store scheme 's3'"):
        open_store("s3://bucket/prefix")
    with pytest.raises(ValueError, match="mem:// URLs need a name"):
        open_store("mem://")


def test_register_third_party_scheme():
    class UpperStore(MemoryStore):
        scheme = "upper"
        _named = {}

    register_store_scheme("upper", UpperStore.from_url)
    try:
        st = open_store("upper://thirdparty")
        assert isinstance(st, UpperStore)
        with CZDataset("upper://thirdparty", "a", spec=SPEC) as ds:
            ds.append({"p": FIELDS["p"]})
        with CZDataset("upper://thirdparty") as ds:
            np.testing.assert_array_equal(ds.read_field("p", 0), FIELDS["p"])
    finally:
        STORE_SCHEMES.pop("upper", None)
        UpperStore.drop("thirdparty")


def test_standalone_container_reads_from_any_store(store):
    """The container layer itself (not just CZDataset) is store-backed:
    write_compressed/read_field/describe/FieldReader all take store=."""
    f = FIELDS["p"]
    container.write_compressed("solo.cz", f, SPEC, store=store)
    np.testing.assert_array_equal(
        container.read_field("solo.cz", store=store), f)
    d = container.describe("solo.cz", verify=True, store=store)
    assert d["container"] == "CZ2" and d["crc_ok"] is True
    r = container.FieldReader("solo.cz", store=store)
    np.testing.assert_array_equal(r.read_box((0, 0, 0), (BS, BS, BS)),
                                  f[:BS, :BS, :BS])
    r.close()
    assert r.closed
    with pytest.raises(ValueError, match="closed"):
        r.read_box((0, 0, 0), (BS, BS, BS))
