"""Concurrent shard writer: pooled chunk encoding, single ordered drain.

The paper's writers are per-thread: each thread compresses its own
aggregation buffer and the buffers are concatenated in deterministic order.
:class:`ShardWriter` reproduces that shape for dataset members — one shared
:class:`~concurrent.futures.ThreadPoolExecutor` encodes aggregation buffers
(scheme serialize + stage-2 lossless, both GIL-releasing) for *all*
quantities of a timestep, while each CZ2 member file is drained by a single
writer strictly in chunk order.  Serial (``workers=1``) and pooled output are
byte-identical.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import warnings

import numpy as np

from repro.core import container
from repro.core.pipeline import DTYPES, CompressionSpec, check_device

__all__ = ["ShardWriter", "DtypeCoercionWarning"]


class DtypeCoercionWarning(UserWarning):
    """A field's dtype could not be carried through the dataset spec's scheme
    and the value stream was cast to the spec's dtype (e.g. float64 into an
    fpzipx dataset, whose lossless guarantee is float32-only)."""


class ShardWriter:
    """Writes 3D fields to CZ2 member files through a shared encode pool."""

    def __init__(self, spec: CompressionSpec, workers: int = 1):
        self.spec = spec.validate()
        self.workers = max(1, int(workers))
        self._pool = (concurrent.futures.ThreadPoolExecutor(self.workers)
                      if self.workers > 1 else None)

    def spec_for(self, field: np.ndarray) -> CompressionSpec:
        """Dataset spec re-tagged with the field's dtype (auto dtype tags).
        Dtypes the spec's scheme can't take (unsupported ones, or e.g.
        float64 into an fpzipx dataset) fall back to the spec's own dtype —
        the field is coerced, never rejected mid-append, but the cast is
        surfaced as a :class:`DtypeCoercionWarning` rather than silent.

        An unknown ``device=`` is *not* coercible: it would silently run the
        host path under a lying header, so it raises here even if the spec
        skipped validation (e.g. was rebuilt from a hand-edited manifest)."""
        check_device(self.spec.device)
        dt = str(np.asarray(field).dtype)
        if dt == self.spec.dtype:
            return self.spec
        if dt not in DTYPES:
            warnings.warn(
                f"dtype {dt} is not a supported field dtype {DTYPES}; "
                f"values will be cast to {self.spec.dtype}",
                DtypeCoercionWarning, stacklevel=3)
            return self.spec
        try:
            return dataclasses.replace(self.spec, dtype=dt).validate()
        except ValueError as e:
            warnings.warn(
                f"scheme {self.spec.scheme!r} cannot encode dtype {dt} "
                f"({e}); values will be cast to {self.spec.dtype}",
                DtypeCoercionWarning, stacklevel=3)
            return self.spec

    def write(self, path: str, field: np.ndarray,
              extra_header: dict | None = None,
              spec: CompressionSpec | None = None, store=None) -> int:
        """Stream one field into a CZ2 member; returns bytes written.

        ``spec`` lets a caller that already ran :meth:`spec_for` (e.g. for
        the manifest's dtype tag) pass it in instead of re-deriving it —
        and re-emitting any coercion warning.  ``store`` routes the member
        bytes through a :class:`~repro.store.backends.Store` (``path`` is
        then a store key); ``None`` keeps the historical local-file path.
        Members are fsynced (where the backend has an fd to sync): the
        dataset's atomic-manifest guarantee needs member data on stable
        storage *before* the manifest references it.
        """
        field = np.asarray(field)
        if field.ndim != 3:
            raise ValueError(f"expected a 3D field, got shape {field.shape}")
        return container.write_compressed(
            path, field, spec or self.spec_for(field),
            extra_header=extra_header, workers=self.workers,
            executor=self._pool, fsync=True, store=store)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
