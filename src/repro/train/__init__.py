"""train subsystem."""
