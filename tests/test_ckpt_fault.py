"""Checkpoint/restart, fault tolerance, offsets, data pipeline tests."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import (
    Checkpointer,
    latest_step,
    load_checkpoint,
    restore_tree,
    save_checkpoint,
)
from repro.core import CompressionSpec
from repro.data.tokens import DataConfig, batch_at
from repro.dist.fault import StragglerWatchdog, elastic_plan
from repro.dist.offsets import exclusive_offsets_np


def small_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.zeros((32,))},
        "m": {"w": jnp.ones((64, 32)) * 0.1, "b": jnp.zeros((32,))},
        "v": {"w": jnp.ones((64, 32)) * 0.2, "b": jnp.zeros((32,))},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip_lossless(tmp_path):
    state = small_state()
    save_checkpoint(str(tmp_path), state, 7)
    flat, manifest = load_checkpoint(str(tmp_path))
    assert manifest["step"] == 7
    restored = restore_tree(state, flat)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["cr"] > 0.9  # random data ~1x; structured params compress


def test_checkpoint_atomic_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), every=1, keep=2)
    st = small_state()
    for s in (1, 2, 3, 4):
        ck.maybe_save(st, s)
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_detects_corruption(tmp_path):
    state = small_state()
    save_checkpoint(str(tmp_path), state, 1)
    qfile = os.path.join(tmp_path, "step_00000001", "params.czq")
    with open(qfile, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), 1)


def test_checkpoint_wavelet_lossy_ckpt(tmp_path):
    state = {"params": {"w": jnp.asarray(
        np.random.default_rng(0).standard_normal((32, 32)).astype(np.float32))}}
    spec = CompressionSpec(scheme="szx", eps=1e-3, block_size=16)
    save_checkpoint(str(tmp_path), state, 1, spec=spec)
    flat, m = load_checkpoint(str(tmp_path), 1)
    err = np.max(np.abs(flat["params/w"] - np.asarray(state["params"]["w"])))
    assert err <= 1e-3 * 1.01 + 1e-6


def test_exclusive_offsets():
    sizes = [5, 0, 7, 3]
    np.testing.assert_array_equal(exclusive_offsets_np(sizes), [0, 5, 5, 12])


def test_offsets_sharded_matches_np():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    from repro.dist.offsets import exclusive_offsets_sharded

    sizes = jnp.asarray([3, 9, 1, 4], jnp.int32)
    with mesh:
        out = exclusive_offsets_sharded(sizes, mesh, "data")
    np.testing.assert_array_equal(np.asarray(out), [0, 3, 12, 13])


def test_straggler_watchdog():
    w = StragglerWatchdog(window=8, flag_ratio=1.5, redispatch_ratio=3.0)
    for i in range(10):
        rep = w.observe(i, 1.0)
        assert rep.action == "ok"
    rep = w.observe(10, 2.0)
    assert rep.action == "flag"
    rep = w.observe(11, 5.0)
    assert rep.action == "redispatch"
    assert len(w.reports) == 2


def test_elastic_plan():
    p = elastic_plan(256, 240, global_batch=256)
    assert p["mesh_shape"][0] * p["mesh_shape"][1] == 240
    p = elastic_plan(256, 256, global_batch=256)
    assert p["mesh_shape"] == (16, 16)


def test_data_deterministic_and_learnable_structure():
    cfg = DataConfig(vocab=64, batch=4, seq=32, seed=9)
    a = batch_at(cfg, 5)
    b = batch_at(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def _run_train(args, tmp):
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd="/root/repo", timeout=900)


@pytest.mark.slow
def test_train_kill_resume_end_to_end(tmp_path):
    """Fault injection: die at step 6, resume from the step-5 checkpoint."""
    ck = str(tmp_path / "ck")
    base = ["--arch", "smollm-135m", "--reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
            "--ckpt-every", "5", "--log-every", "4"]
    r1 = _run_train(base + ["--fail-at-step", "6"], tmp_path)
    assert r1.returncode == 17, r1.stderr[-2000:]
    assert latest_step(ck) == 5
    out = str(tmp_path / "m.json")
    r2 = _run_train(base + ["--metrics-out", out], tmp_path)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] from step 5" in r2.stdout
    with open(out) as f:
        m = json.load(f)
    assert m["steps"] == 7  # steps 5..11
