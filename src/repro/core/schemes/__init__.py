"""Open codec-scheme registry (the pluggable substage-1 layer).

The paper's framework is a *testbed of comparison*: wavelet, ZFP-, SZ- and
FPZIP-style compressors plug interchangeably into one block-structured
pipeline.  This package makes that pluggability literal, in the spirit of
Zarr's codec registry: each scheme is a self-describing object that owns

  * ``validate(spec)``  — scheme-specific spec checks,
  * ``stage1(blocks, spec)`` — the device (jit/Pallas) transform over a whole
    block batch, returning named numpy streams,
  * ``serialize(s1, lo, hi, spec)`` / ``deserialize(payload, nblk, spec)`` —
    the host byte layout of one aggregation-buffer chunk (stage-2 lossless
    coding is applied *outside*, by :class:`repro.core.pipeline.Pipeline`).

Third-party schemes register with :func:`register_scheme` and immediately
work through ``Pipeline``, the CZ2 container and the CLI — no core edits.
``SCHEMES`` is a live, read-only view of the registry (iterates names).
"""
from __future__ import annotations

import abc
from collections.abc import Mapping
from typing import TYPE_CHECKING

import numpy as np

from .. import shuffle as _shuf
from ._device import (  # noqa: F401  (re-export)
    DEVICES,
    DeviceFallbackWarning,
    check_device,
    resolve_ops,
    resolved_device,
    route,
)

if TYPE_CHECKING:  # avoid a runtime cycle with repro.core.pipeline
    from ..pipeline import CompressionSpec

__all__ = ["Scheme", "SCHEMES", "register_scheme", "unregister_scheme",
           "get_scheme", "shuffle_bytes", "unshuffle_bytes",
           "DEVICES", "DeviceFallbackWarning", "check_device", "resolve_ops",
           "resolved_device", "route"]

_REGISTRY: dict[str, "Scheme"] = {}


def shuffle_bytes(buf: bytes, mode: str, itemsize: int) -> bytes:
    """Optional byte/bit transpose of a value stream (improves stage 2 CR)."""
    if mode == "none" or itemsize == 1:
        return buf
    fn = _shuf.byte_shuffle if mode == "byte" else _shuf.bit_shuffle
    return fn(buf, itemsize)


def unshuffle_bytes(buf: bytes, mode: str, itemsize: int) -> bytes:
    if mode == "none" or itemsize == 1:
        return buf
    fn = _shuf.byte_unshuffle if mode == "byte" else _shuf.bit_unshuffle
    return fn(buf, itemsize)


class Scheme(abc.ABC):
    """One substage-1 compressor: device transform + host byte layout."""

    #: registry key; also recorded in CZ2 headers
    name: str = ""

    #: whether this scheme has a kernel-backed stage 1 (``device="jax"``
    #: routes through ``repro.kernels.ops``); host-only schemes accept the
    #: knob but truthfully record ``device="host"`` in headers
    device_capable: bool = False

    def validate(self, spec: "CompressionSpec") -> None:
        """Raise ValueError if ``spec`` is invalid for this scheme."""

    def params(self, spec: "CompressionSpec") -> dict:
        """Scheme-relevant knobs, recorded explicitly in container headers.

        ``device`` is always recorded (provenance of where stage 1 *ran*,
        not what the knob asked for — a host-only scheme or a Pallas-less
        fallback reports "host") but is never *required* to decode — see
        ``schemes._device``.
        """
        p = dict(spec.extra) if spec.extra else {}
        # the resolved value wins over any extra key of the same name
        p["device"] = resolved_device(spec, self.device_capable)
        return p

    def error_bound(self, spec: "CompressionSpec") -> float | None:
        """Declared max-abs-error contract for this spec, used by the
        cross-scheme conformance suite (``tests/test_scheme_conformance.py``):

        * ``None``    — lossless: decode must be bit-exact;
        * a float     — decode must satisfy ``max|x - xhat| <= bound``;
        * ``math.inf``— lossy with no declared bound (best effort).
        """
        return None

    def decode_spec(self, spec: "CompressionSpec", fmt: int) -> "CompressionSpec":
        """Spec to decode a payload written under container format ``fmt``.

        Lets a scheme change its byte layout across format bumps while old
        containers keep reading bit-exact (see szx's outlier shuffle in v2).
        """
        return spec

    def chunk_record(self, s1: dict, lo: int, hi: int,
                     spec: "CompressionSpec") -> dict | None:
        """Optional JSON-able per-chunk footer record for blocks [lo, hi),
        called right after :meth:`serialize` for the same range.

        ``None`` (the default) records nothing — containers stay
        byte-identical.  A scheme that varies per chunk (the ``auto``
        meta-scheme records each chunk's winning scheme + eps) returns a
        dict; the container writer collects them into the footer's
        ``chunk_schemes`` table so inspection tooling can describe the
        chunk mix without decoding.
        """
        return None

    @abc.abstractmethod
    def stage1(self, blocks_np: np.ndarray, spec: "CompressionSpec") -> dict[str, np.ndarray]:
        """Device transform of a whole (nblk, bs, bs, bs) batch -> streams."""

    @abc.abstractmethod
    def serialize(self, s1: dict, lo: int, hi: int, spec: "CompressionSpec") -> bytes:
        """Byte layout of blocks [lo, hi) from the stage-1 streams."""

    @abc.abstractmethod
    def deserialize(self, payload: bytes, nblk: int, spec: "CompressionSpec") -> np.ndarray:
        """Inverse of :meth:`serialize`: payload -> (nblk, bs, bs, bs) blocks."""


def register_scheme(cls: type) -> type:
    """Class decorator: instantiate and add to the live registry."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    _REGISTRY[inst.name] = inst
    return cls


def unregister_scheme(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_scheme(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {', '.join(sorted(_REGISTRY))}"
        ) from None


class _SchemesView(Mapping):
    """Live, read-only view of the registry.  Iterates scheme names, so both
    ``"wavelet" in SCHEMES`` and ``for name in SCHEMES`` keep working."""

    def __getitem__(self, name: str) -> Scheme:
        return get_scheme(name)

    def __iter__(self):
        return iter(_REGISTRY)

    def __len__(self) -> int:
        return len(_REGISTRY)

    def __contains__(self, name) -> bool:
        return name in _REGISTRY

    def __repr__(self) -> str:
        return f"SCHEMES({', '.join(sorted(_REGISTRY))})"


SCHEMES = _SchemesView()

# Built-in schemes self-register on import.  ``auto`` comes last: the
# meta-scheme delegates to whatever else is registered.
from . import fpzipx, lorenzo, raw, szx, wavelet, zfpx  # noqa: E402,F401
from . import auto  # noqa: E402,F401
