"""repro.obs — unified observability: metrics registry + span tracing.

Two stdlib-only modules:

* :mod:`repro.obs.registry` — thread-safe ``Counter``/``Gauge``/
  ``Histogram`` with labels, a process-wide default ``REGISTRY``, and
  Prometheus text exposition (``render``) / JSON snapshots (``snapshot``).
* :mod:`repro.obs.trace` — ``with span("encode", chunk=i):`` span API
  exporting Chrome trace-event JSON (Perfetto-viewable), disabled by
  default at near-zero cost, with cross-process merge for the cluster
  engine's per-rank traces.

Every tier (pipeline, container reader, store backends, cluster engine,
serve) instruments through this package; ``cz-compress ... --trace`` and
``cz-compress stats`` surface it on the CLI.
"""
from repro.obs.registry import (  # noqa: F401
    DEFAULT_BUCKETS,
    FAST_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Metric,
    Registry,
    counter,
    gauge,
    histogram,
    parse_prometheus,
    render,
    snapshot,
)
from repro.obs.trace import (  # noqa: F401
    TRACER,
    Tracer,
    merge_traces,
    span,
    traced,
    tracing,
)
from repro.obs import trace  # noqa: F401

__all__ = [
    "Counter", "Gauge", "Histogram", "Metric", "Registry", "REGISTRY",
    "DEFAULT_BUCKETS", "FAST_BUCKETS", "counter", "gauge", "histogram",
    "render", "snapshot", "parse_prometheus",
    "Tracer", "TRACER", "span", "traced", "tracing", "trace", "merge_traces",
]
