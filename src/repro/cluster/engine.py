"""Rank-parallel compression into one shared CZ2 file (the cluster tier).

The paper's defining mechanism: every MPI rank compresses its share of the
grid in parallel and writes into **one shared per-quantity file** at a byte
offset computed with ``MPI_Exscan`` over the per-rank compressed sizes.
:class:`ParallelCompressor` reproduces that with worker *processes* as the
MPI stand-in:

1. the global block raster is split into contiguous per-rank spans that land
   on aggregation-buffer (chunk) boundaries (:func:`~repro.cluster.decompose.
   chunk_spans`) — each rank's span is its block-structured subdomain of the
   serial chunk stream;
2. each rank encodes its blocks through :meth:`Pipeline.iter_chunks` into a
   private part file and reports its per-chunk sizes/CRCs (the gather);
3. the parent runs :func:`~repro.dist.offsets.exclusive_offsets_np` — the
   Exscan — over the per-rank byte totals;
4. each rank copies its part into the shared file at its offset
   (``MPI_File_write_at``), and the parent appends the CZ2 JSON footer and
   patches the footer pointer.

Because rank cuts align with chunk boundaries and every registered scheme
transforms blocks independently, the assembled file is **bit-identical to
the serial writer** (:func:`repro.core.container.write_field`) for any rank
count — rank-count invariance is a tested guarantee, not an accident.
"""
from __future__ import annotations

import contextlib
import json
import multiprocessing
import os
import shutil
import time
import zlib

import numpy as np

from repro import obs
from repro.obs import trace
from repro.core import blocks as blk
from repro.core import container
from repro.core.pipeline import CompressionSpec, Pipeline
from repro.dist.offsets import exclusive_offsets_np

from .decompose import chunk_spans

__all__ = ["ParallelCompressor"]

#: env override for the worker start method ("spawn" is jax-safe; "fork" is
#: faster to boot but inherits the parent's initialized XLA runtime)
_START_ENV = "REPRO_CLUSTER_START"

#: the paper's per-stage timing as live series (parent-side wall clock)
_PHASE_SECONDS = obs.histogram(
    "cz_cluster_phase_seconds",
    "Parallel-compress phase wall time (encode / exscan / commit).",
    labelnames=("phase",))
_COMPRESSIONS = obs.counter("cz_cluster_compressions_total",
                            "Parallel compress() calls by rank count.",
                            labelnames=("ranks",))


@contextlib.contextmanager
def _rank_tracing(rank, trace_path):
    """Worker-side tracing scope: when the parent asked for a trace file,
    re-anchor this process's global tracer, collect, and save on exit (the
    parent absorbs the file onto rank track ``pid=rank``)."""
    if trace_path is None:
        yield
        return
    trace.TRACER.reset()
    trace.TRACER.process_name = f"rank {rank}"
    trace.TRACER.enable()
    try:
        yield
    finally:
        trace.TRACER.disable()
        trace.TRACER.save(trace_path)


def _encode_rank(task) -> tuple[list[int], list[int], list[int], list]:
    """Worker: encode one rank's block span into a private part file.

    Returns (chunk_sizes, chunk_nblocks, chunk_crc32, chunk_records) — the
    per-rank metadata the parent gathers before the Exscan.
    """
    spec_json, blocks_np, part_path, rank, trace_path = task
    sizes: list[int] = []
    nblks: list[int] = []
    crcs: list[int] = []
    recs: list = []
    with _rank_tracing(rank, trace_path), \
            trace.span("encode", rank=rank, nblocks=int(blocks_np.shape[0])):
        with open(part_path, "wb") as f:
            if blocks_np.shape[0]:
                pipe = Pipeline(CompressionSpec.from_json(spec_json))
                for chunk, nblk in pipe.iter_chunks(blocks_np, records=recs):
                    f.write(chunk)
                    sizes.append(len(chunk))
                    nblks.append(nblk)
                    crcs.append(zlib.crc32(chunk) & 0xFFFFFFFF)
            f.flush()
            os.fsync(f.fileno())
    return sizes, nblks, crcs, recs


def _write_at(task) -> None:
    """Worker: copy this rank's part file into the shared file at its
    Exscan offset (the ``MPI_File_write_at`` step), then drop the part."""
    path, offset, part_path, rank, trace_path = task
    with _rank_tracing(rank, trace_path), \
            trace.span("commit", rank=rank, offset=int(offset)):
        with open(part_path, "rb") as src, open(path, "r+b") as dst:
            dst.seek(offset)
            shutil.copyfileobj(src, dst, 1 << 20)
        os.unlink(part_path)


class ParallelCompressor:
    """Compress fields through N rank processes into single shared CZ2 files.

    Parameters
    ----------
    ranks:
        Worker-pool size and the default rank count per :meth:`compress`
        call (individual calls may use fewer ranks — the pool is shared, so
        one compressor amortizes worker startup across rank counts).
    start_method:
        ``multiprocessing`` start method.  Default ``"spawn"`` (fresh
        interpreter per rank — safe with an initialized jax runtime in the
        parent); override with ``"fork"`` or the ``REPRO_CLUSTER_START`` env
        var when boot time matters more.

    The pool is created lazily on the first multi-rank compress and reused
    until :meth:`close`.  ``ranks=1`` calls stay in-process.
    """

    def __init__(self, ranks: int, start_method: str | None = None):
        self.ranks = int(ranks)
        if self.ranks < 1:
            raise ValueError(f"ranks must be >= 1, got {ranks}")
        self._start = (start_method or os.environ.get(_START_ENV) or "spawn")
        self._pool = None

    def _get_pool(self):
        if self._pool is None:
            from ._env import worker_env
            ctx = multiprocessing.get_context(self._start)
            with worker_env():  # children inherit the thread caps at exec
                self._pool = ctx.Pool(self.ranks)
        return self._pool

    def plan(self, field_shape: tuple[int, int, int], spec: CompressionSpec,
             ranks: int | None = None) -> list[dict]:
        """Per-rank work plan: chunk span, block span, block count."""
        spec = spec.validate()
        pipe = Pipeline(spec)
        nblocks = int(np.prod(blk.num_blocks(tuple(field_shape), spec.block_size)))
        bpc = pipe.blocks_per_chunk
        nchunks = -(-nblocks // bpc)
        spans = chunk_spans(nchunks, self._nranks(ranks))
        return [
            {"rank": r, "chunks": (clo, chi),
             "blocks": (clo * bpc, min(chi * bpc, nblocks)),
             "nblocks": min(chi * bpc, nblocks) - clo * bpc}
            for r, (clo, chi) in enumerate(spans)
        ]

    def _nranks(self, ranks: int | None) -> int:
        n = self.ranks if ranks is None else int(ranks)
        if not 1 <= n <= self.ranks:
            raise ValueError(f"ranks must be in [1, {self.ranks}], got {n}")
        return n

    def compress(self, path: str, field: np.ndarray, spec: CompressionSpec,
                 extra_header: dict | None = None, ranks: int | None = None,
                 fsync: bool = False) -> int:
        """Write ``field`` to ``path`` as a CZ2 container; returns bytes
        written.  Output is bit-identical to
        ``container.write_compressed(path, field, spec, extra_header)``
        for every rank count and every registered scheme.
        """
        spec = spec.validate()
        nranks = self._nranks(ranks)
        pipe = Pipeline(spec)
        header, data = container.build_field_header(pipe, field, extra_header)

        nblocks = data.shape[0]
        bpc = pipe.blocks_per_chunk
        nchunks = -(-nblocks // bpc)
        if nranks == 1 or nchunks <= 1:
            records: list = []
            return container.write_stream(
                path, pipe.iter_chunks(data, records=records), header,
                fsync=fsync, records=records)
        _COMPRESSIONS.inc(ranks=nranks)

        # when the parent is tracing, every worker task also gets a trace
        # file path: the worker collects its own timeline there and the
        # parent absorbs each onto rank track pid=r after the run
        tracing = trace.TRACER.enabled
        spec_json = spec.to_json()
        tasks, parts, rank_traces = [], [], []
        for r, (clo, chi) in enumerate(chunk_spans(nchunks, nranks)):
            blo, bhi = clo * bpc, min(chi * bpc, nblocks)
            part = f"{path}.rank{r}.part"
            parts.append(part)
            enc_trace = f"{part}.enc-trace.json" if tracing else None
            wr_trace = f"{part}.wr-trace.json" if tracing else None
            rank_traces.append((enc_trace, wr_trace))
            tasks.append((spec_json, data[blo:bhi], part, r, enc_trace))
        shared_created = False
        try:
            # -- phase 1: per-rank encode (scatter of spans, gather of sizes)
            t0 = time.perf_counter_ns()
            with trace.span("encode", ranks=nranks, nchunks=nchunks):
                enc = self._get_pool().map(_encode_rank, tasks)
            _PHASE_SECONDS.observe((time.perf_counter_ns() - t0) / 1e9,
                                   phase="encode")

            # -- phase 2: Exscan over per-rank totals -> shared-file offsets
            t0 = time.perf_counter_ns()
            with trace.span("exscan", ranks=nranks):
                totals = np.asarray(
                    [sum(sizes) for sizes, *_ in enc], np.int64)
                offsets = exclusive_offsets_np(totals)
            _PHASE_SECONDS.observe((time.perf_counter_ns() - t0) / 1e9,
                                   phase="exscan")

            # -- phase 3: ranks write at their offsets, the parent commits
            # the footer (rank-order concatenation of the gathered metadata
            # == the serial writer's chunk table, through same layout code)
            t0 = time.perf_counter_ns()
            with trace.span("commit", ranks=nranks):
                data_start = len(container.MAGIC) + 8
                with open(path, "wb") as f:
                    f.write(container.MAGIC)
                    f.write(container._FOOTER_PTR.pack(0))
                shared_created = True
                self._get_pool().map(
                    _write_at,
                    [(path, int(data_start + off), part, r, wr)
                     for r, (off, part, (_enc, wr))
                     in enumerate(zip(offsets, parts, rank_traces))])
                with open(path, "r+b") as f:
                    nbytes = container.commit_footer(
                        f, header,
                        [s for ss, _, _, _ in enc for s in ss],
                        [n for _, ns, _, _ in enc for n in ns],
                        [c for _, _, cs, _ in enc for c in cs],
                        data_start + int(totals.sum()), fsync=fsync,
                        records=[r for _, _, _, rs in enc for r in rs])
            _PHASE_SECONDS.observe((time.perf_counter_ns() - t0) / 1e9,
                                   phase="commit")
            self._absorb_rank_traces(rank_traces)
            return nbytes
        except BaseException:
            # don't leak part files / a headerless stub on a failed rank
            for part in parts:
                try:
                    os.unlink(part)
                except FileNotFoundError:
                    pass
            for pair in rank_traces:
                for tp in pair:
                    if tp is not None:
                        try:
                            os.unlink(tp)
                        except FileNotFoundError:
                            pass
            if shared_created:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass
            raise

    @staticmethod
    def _absorb_rank_traces(rank_traces) -> None:
        """Fold each rank's saved trace files into the parent's timeline as
        ``pid=rank`` tracks, then drop the temp files.  Missing files (a
        worker died before saving) are skipped — tracing never fails a
        successful compress."""
        for r, pair in enumerate(rank_traces):
            for tp in pair:
                if tp is None:
                    continue
                try:
                    with open(tp) as f:
                        doc = json.load(f)
                except (OSError, ValueError):
                    continue
                trace.TRACER.absorb(doc, pid=r, process_name=f"rank {r}")
                try:
                    os.unlink(tp)
                except FileNotFoundError:
                    pass

    def close(self) -> None:
        if self._pool is not None:
            self._pool.close()
            self._pool.join()
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __repr__(self) -> str:
        return (f"ParallelCompressor(ranks={self.ranks}, "
                f"start={self._start!r}, "
                f"pool={'live' if self._pool else 'cold'})")
