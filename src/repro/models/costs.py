"""Analytic FLOPs accounting per (arch x shape) — the roofline's yardstick.

``model_flops`` implements the assignment formula (6*N*tokens for train with
N = active non-embedding params; 2*N*tokens for decode).  ``detailed_flops``
adds the attention quadratic term and the train multiplier (fwd + 2x bwd +
remat recompute), giving the "useful compute" that the loop-aware HLO FLOPs
are compared against: HLO/useful > 1 means redundant compute (masked-causal
waste, replicated attention on unshardable head counts, remat).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig

__all__ = ["model_flops", "detailed_flops", "matmul_params"]


def matmul_params(cfg: ArchConfig, active: bool = True) -> int:
    """Active parameters that participate in matmuls (excludes the embedding
    gather; the LM head counts, tied or not, since it is a matmul)."""
    from repro.models import count_params

    n = count_params(cfg, active_only=active)
    n -= cfg.vocab * cfg.d_model          # embedding gather
    if cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model      # tied head still does the matmul
    return n


def _tokens(cfg: ArchConfig, shape: ShapeConfig) -> int:
    if shape.kind == "decode":
        return shape.global_batch          # one new token per sequence
    return shape.global_batch * shape.seq_len


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Assignment MODEL_FLOPS: 6*N*tokens (train), 2*N*tokens (inference)."""
    n = matmul_params(cfg)
    t = _tokens(cfg, shape)
    if shape.kind == "train":
        return 6.0 * n * t
    return 2.0 * n * t


def _attention_flops_fwd(cfg: ArchConfig, shape: ShapeConfig,
                         masked_full: bool) -> float:
    """QK^T + AV flops, global, forward, per full model."""
    B, S = shape.global_batch, shape.seq_len
    Hhd = cfg.n_heads * cfg.hd
    if cfg.family == "ssm":
        # rwkv6 wkv state update+readout: ~4 flops per state element per token
        return 4.0 * B * S * cfg.d_model * cfg.hd * 1.0
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.attn_period
    if shape.kind == "decode":
        flops = 4.0 * B * Hhd * S * n_attn_layers
        if cfg.family == "hybrid":
            # + mamba state update per non-attn layer
            nm = cfg.n_layers - n_attn_layers
            flops += 6.0 * B * cfg.ssm_expand * cfg.d_model * cfg.d_state * nm
        return flops
    causal_factor = 1.0 if masked_full else 0.5
    flops = 4.0 * B * S * S * Hhd * n_attn_layers * causal_factor
    if cfg.family == "hybrid":
        nm = cfg.n_layers - n_attn_layers
        flops += 6.0 * B * S * cfg.ssm_expand * cfg.d_model * cfg.d_state * nm
    if cfg.family == "encdec":
        F = cfg.enc_frames
        flops += 4.0 * B * F * F * Hhd * cfg.encoder_layers      # encoder self
        flops += 4.0 * B * S * F * Hhd * cfg.n_layers            # cross
    return flops


def detailed_flops(cfg: ArchConfig, shape: ShapeConfig, *,
                   attn_impl: str = "masked", remat: str = "full") -> dict:
    """Global (all-device) flops decomposition."""
    t = _tokens(cfg, shape)
    n = matmul_params(cfg)
    matmul_fwd = 2.0 * n * t
    attn_fwd = _attention_flops_fwd(cfg, shape, masked_full=(attn_impl == "masked"))
    fwd = matmul_fwd + attn_fwd
    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat == "full" else 0.0)   # fwd + 2 bwd (+ remat)
        total = fwd * mult
    else:
        total = fwd
    return {
        "matmul_fwd": matmul_fwd,
        "attn_fwd": attn_fwd,
        "fwd": fwd,
        "total": total,
        "model_flops": model_flops(cfg, shape),
    }
