"""Cross-scheme conformance suite: one parametrized contract for **every**
registered scheme x device x dtype.

The contracts (per scheme, from its own declarations):

* round-trip error within ``Scheme.error_bound`` (bit-exact when None);
* CZ2 write -> re-read equals the in-memory decode exactly, with scheme,
  params and device recorded in the header;
* ``decode_spec`` is stable: identity at the current ``CODEC_FORMAT`` and
  idempotent for every historical format;
* device routing is never a decode requirement: a container written with
  ``device="jax"`` decodes on host (and vice versa) bit-exact for lossless
  layouts, within the declared bound for lossy ones;
* a dummy third-party ``@register_scheme`` plugin passes the same matrix.

Specs that reject a combination (e.g. fpzipx for non-float32) skip it —
rejection-at-validate is itself part of the contract.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import CODEC_FORMAT, CompressionSpec, Pipeline, SCHEMES, container
from repro.core.schemes import (
    DeviceFallbackWarning,
    Scheme,
    _device,
    get_scheme,
    register_scheme,
    shuffle_bytes,
    unregister_scheme,
    unshuffle_bytes,
)

DEVICES = ("host", pytest.param("jax", marks=pytest.mark.device))
DTYPES = ("float32", "float64", "float16")
BS = 8          # smallest side every scheme supports (2^k >= 8, % 4 == 0)
N = 24          # 27 blocks; with 8 KiB buffers -> 4 blocks/chunk, 7 chunks


def _field(dtype: str) -> np.ndarray:
    rng = np.random.default_rng(7)
    g = np.mgrid[0:N, 0:N, 0:N].astype(np.float32) / N
    f = 40.0 + 8.0 * np.sin(6 * g[0]) * np.cos(5 * g[1]) - 6.0 * g[2] ** 2
    f += rng.standard_normal((N, N, N)).astype(np.float32) * 0.05
    return f.astype(dtype)


#: the combos a scheme rejects *by contract* — only these may skip; any
#: other validation failure is a regression and fails the matrix outright
EXPECTED_REJECTS = {
    ("fpzipx", "float64"),   # lossless guarantee is float32-only
    ("fpzipx", "float16"),
}


def _spec(scheme: str, device: str = "host", dtype: str = "float32",
          **kw) -> CompressionSpec:
    spec = CompressionSpec(scheme=scheme, device=device, dtype=dtype,
                           eps=1e-3, block_size=BS, buffer_bytes=1 << 13, **kw)
    try:
        return spec.validate()
    except ValueError as e:
        if (scheme, dtype) in EXPECTED_REJECTS:
            pytest.skip(f"{scheme} rejects dtype={dtype} by contract: {e}")
        raise


def _tolerance(spec: CompressionSpec, field: np.ndarray) -> float:
    """Declared bound plus the unavoidable quanta: one ulp of the field's
    dtype at its magnitude (decode casts back to the tagged dtype)."""
    bound = get_scheme(spec.scheme).error_bound(spec)
    assert bound is not None
    absmax = float(np.abs(field).max())
    # lossy schemes compute in float32 and cast back to the tagged dtype:
    # allow one ulp at the field magnitude in whichever grid is coarser
    ulp = max(float(np.spacing(np.dtype(field.dtype).type(absmax))),
              float(np.spacing(np.float32(absmax))))
    return bound * (1 + 1e-4) + ulp


def _check_roundtrip(spec: CompressionSpec, field: np.ndarray) -> None:
    pipe = Pipeline(spec)
    comp = pipe.compress(field)
    assert len(comp.chunks) > 1, "conformance field must span several chunks"
    dec = pipe.decompress(comp)
    assert dec.shape == field.shape
    assert dec.dtype == field.dtype
    bound = get_scheme(spec.scheme).error_bound(spec)
    if bound is None:
        np.testing.assert_array_equal(dec, field)
    elif np.isfinite(bound):
        err = np.max(np.abs(dec.astype(np.float64) - field.astype(np.float64)))
        assert err <= _tolerance(spec, field), \
            f"{spec.scheme}: err {err:.3e} above declared bound {bound:.3e}"
    else:
        assert np.isfinite(dec).all()


def _ran_on(spec: CompressionSpec) -> str:
    """Where stage 1 actually runs for this spec: 'jax' only for a
    kernel-backed scheme with the Pallas toolchain importable — what the
    header must record as provenance."""
    sch = get_scheme(spec.scheme)
    capable = sch.device_capable and _device.kernel_ops() is not None
    return spec.device if capable else "host"


def _check_container(spec: CompressionSpec, field: np.ndarray, tmp_path) -> None:
    path = str(tmp_path / f"{spec.scheme}-{spec.device}-{spec.dtype}.cz")
    container.write_field(path, field, spec)
    pipe = Pipeline(spec)
    mem = pipe.decompress(pipe.compress(field))
    disk = container.read_field(path)
    np.testing.assert_array_equal(disk, mem)
    with container.FieldReader(path) as r:
        assert r.header["scheme"] == spec.scheme
        assert r.header["scheme_params"]["device"] == _ran_on(spec)
        assert r.header["format"] == CODEC_FORMAT
        np.testing.assert_array_equal(r.read_all(), mem)


def _check_decode_spec(spec: CompressionSpec) -> None:
    sch = get_scheme(spec.scheme)
    assert sch.decode_spec(spec, CODEC_FORMAT) == spec, \
        "decode_spec must be the identity at the current format"
    for fmt in range(1, CODEC_FORMAT + 1):
        ds = sch.decode_spec(spec, fmt)
        assert ds.scheme == spec.scheme
        assert sch.decode_spec(ds, fmt) == ds, \
            f"decode_spec must be idempotent (format {fmt})"


# ---------------------------------------------------------------------------
# The matrix: every registered scheme x device x dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_roundtrip_within_declared_bound(scheme, device, dtype):
    _check_roundtrip(_spec(scheme, device, dtype), _field(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_cz2_write_reread_equality(scheme, device, dtype, tmp_path):
    _check_container(_spec(scheme, device, dtype), _field(dtype), tmp_path)


@pytest.mark.parametrize("device", DEVICES)
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_decode_spec_stability(scheme, device):
    _check_decode_spec(_spec(scheme, device))


# ---------------------------------------------------------------------------
# Device routing is provenance, not a decode requirement
# ---------------------------------------------------------------------------

@pytest.mark.device
@pytest.mark.parametrize("write_dev,read_dev", [("jax", "host"), ("host", "jax")])
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_cross_device_decode(scheme, write_dev, read_dev, tmp_path):
    """A file written on one device decodes on the other: bit-exact for
    lossless layouts, within the declared bound for lossy ones."""
    field = _field("float32")
    spec = _spec(scheme, write_dev)
    path = str(tmp_path / f"{scheme}.cz")
    container.write_field(path, field, spec)
    dec = container.read_field(path, device=read_dev)
    bound = get_scheme(scheme).error_bound(spec)
    if bound is None:
        np.testing.assert_array_equal(dec, field)
    else:
        err = np.max(np.abs(dec.astype(np.float64) - field.astype(np.float64)))
        assert err <= _tolerance(spec, field)
    with container.FieldReader(path, device=read_dev) as r:
        assert r.spec.device == read_dev         # decode routing overridden
        # provenance records where stage 1 actually ran at write time
        assert r.header["scheme_params"]["device"] == _ran_on(spec)


def test_host_only_scheme_records_host_provenance(tmp_path):
    """szx/raw/fpzipx accept the device knob (a dataset-level spec may be
    shared across schemes) but have no kernel path — the header must record
    that stage 1 actually ran on host, not echo the knob."""
    spec = _spec("szx", "jax")
    path = str(tmp_path / "szx.cz")
    container.write_field(path, _field("float32"), spec)
    with container.FieldReader(path) as r:
        assert r.header["scheme_params"]["device"] == "host"
        assert r.spec.device == "jax"   # the requested knob stays in the spec


@pytest.mark.parametrize("scheme", ["wavelet", "zfpx", "lorenzo"])
def test_device_fallback_warns_and_matches_host(scheme, monkeypatch):
    """Without a Pallas toolchain, device='jax' degrades to the host path
    with a DeviceFallbackWarning — same bytes, nothing raised."""
    field = _field("float32")
    host = Pipeline(_spec(scheme, "host")).compress(field)
    monkeypatch.setattr(_device, "_OPS", None)   # simulate: kernels missing
    spec = _spec(scheme, "jax")
    with pytest.warns(DeviceFallbackWarning):
        jax_comp = Pipeline(spec).compress(field)
    assert jax_comp.chunks == host.chunks


# ---------------------------------------------------------------------------
# Unknown device= is rejected loudly, never silently run on the host path
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_device():
    with pytest.raises(ValueError, match="unknown device 'tpu'"):
        CompressionSpec(device="tpu").validate()


def test_shard_writer_rejects_unknown_device():
    from repro.store import ShardWriter

    with pytest.raises(ValueError, match="unknown device"):
        ShardWriter(CompressionSpec(scheme="raw", device="cuda"))
    # even a spec that dodged validation (e.g. rebuilt from a hand-edited
    # manifest) must fail in spec_for, not be warn-coerced onto the host path
    sw = ShardWriter(CompressionSpec(scheme="raw", block_size=BS))
    object.__setattr__(sw.spec, "device", "cuda")
    with pytest.raises(ValueError, match="unknown device 'cuda'"):
        sw.spec_for(_field("float64"))


@pytest.mark.parametrize("sub", [[], ["parallel"]])
def test_cli_rejects_unknown_device(sub, capsys):
    from repro.launch import compress

    with pytest.raises(SystemExit) as exc:
        compress.main(sub + ["--device", "tpu", "--n", str(N)])
    assert exc.value.code == 2
    assert "unknown device 'tpu'" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# Third-party plugin through the same matrix
# ---------------------------------------------------------------------------

class OffsetScheme(Scheme):
    """Dummy third-party scheme: stores the negated field in the spec's
    tagged dtype (negation is IEEE-exact, so lossless for every dtype)."""

    name = "conformance-neg"

    def stage1(self, blocks_np, spec):
        return {"v": -np.asarray(blocks_np, spec.np_dtype)}

    def serialize(self, s1, lo, hi, spec):
        dt = spec.np_dtype
        return shuffle_bytes(s1["v"][lo:hi].tobytes(), spec.shuffle, dt.itemsize)

    def deserialize(self, payload, nblk, spec):
        dt = spec.np_dtype
        v = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, dt.itemsize), dt)
        n = spec.block_size
        return -v.reshape(nblk, n, n, n)


@pytest.fixture()
def offset_scheme():
    register_scheme(OffsetScheme)
    yield OffsetScheme.name
    unregister_scheme(OffsetScheme.name)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("device", DEVICES)
def test_plugin_scheme_full_conformance(offset_scheme, device, dtype, tmp_path):
    field = _field(dtype)
    spec = _spec(offset_scheme, device, dtype)
    _check_roundtrip(spec, field)
    _check_container(spec, field, tmp_path)
    _check_decode_spec(spec)


def test_plugin_unregistered_cleanly(offset_scheme):
    assert offset_scheme in SCHEMES
    spec = dataclasses.replace(_spec(offset_scheme), extra={"knob": 1})
    assert get_scheme(offset_scheme).params(spec)["knob"] == 1
