"""Fig. 6 — effect of block size (8^3..64^3); small blocks lose CR."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields

from .common import emit, eps_sweep, save_json, sweep


def run(quick: bool = True):
    n = 128  # need divisibility by 64 for the largest block size
    fields = cavitation_fields(CloudConfig(n=n), 9.4)
    eps_list = eps_sweep(n=3 if quick else 6)
    rows = []
    t0 = time.time()
    for q in ("p", "rho"):
        for bs in (8, 16, 32, 64):
            specs = [CompressionSpec(scheme="wavelet", wavelet="w3ai",
                                     eps=e, block_size=bs) for e in eps_list]
            for e, r in zip(eps_list, sweep(fields[q], specs)):
                rows.append({"qoi": q, "block_size": bs, "eps": e,
                             "cr": r["cr"], "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig6_blocksize", rows)

    def mean_cr(bs):
        return np.mean([r["cr"] for r in rows if r["block_size"] == bs])

    emit("fig6_cr_bs8_over_bs32", dt * 1e6 / max(len(rows), 1),
         f"{mean_cr(8) / mean_cr(32):.3f}")
    emit("fig6_cr_bs64_over_bs32", dt * 1e6 / max(len(rows), 1),
         f"{mean_cr(64) / mean_cr(32):.3f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
