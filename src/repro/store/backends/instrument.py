"""Store instrumentation: per-op counters/bytes/latency for any backend.

:class:`RangeStore` proved the idea — its private request/byte tallies are
what lets ``bench_backends`` print amplification rows and lets tests assert
that a region query fetched *ranges of* a member.  This module generalizes
that accounting to every backend:

* :class:`StoreMeter` — one instance's tally (requests and bytes per op,
  with range gets split out), doubling as the bridge into the process-wide
  :data:`repro.obs.REGISTRY`: every recorded op also lands in the labelled
  metrics ``cz_store_ops_total{backend,op}``,
  ``cz_store_bytes_total{backend,op}`` and the latency histogram
  ``cz_store_op_seconds{backend,op}``.
* :class:`InstrumentedStore` — a delegating wrapper (same shape as
  :class:`FlakyStore`) that times ``get``/``put``/``put_atomic``/``list``/
  ``delete``/``exists`` on any inner :class:`Store` and feeds a meter.
  ``open_write``/``lock`` delegate untouched so :class:`FileStore` keeps
  its streaming, one-chunk-in-memory writer — streaming writes are only
  attributed on backends whose sink commits through ``put``.

``open_store(root, instrument=True)`` wraps any resolved backend.
"""
from __future__ import annotations

import threading
import time

from repro import obs
from repro.obs import FAST_BUCKETS

from .base import Store

__all__ = ["StoreMeter", "InstrumentedStore"]

_OPS = obs.counter("cz_store_ops_total",
                   "Store operations by backend and op.",
                   labelnames=("backend", "op"))
_BYTES = obs.counter("cz_store_bytes_total",
                     "Bytes moved through store ops (payload size).",
                     labelnames=("backend", "op"))
_SECONDS = obs.histogram("cz_store_op_seconds",
                         "Store operation latency by backend and op.",
                         buckets=FAST_BUCKETS,
                         labelnames=("backend", "op"))


class StoreMeter:
    """Request/byte tally for one store instance.

    ``record`` is the single entry point: it bumps the per-instance
    counters (readable via attributes or :meth:`stats`) *and* the global
    registry series for ``backend``.  The attribute names intentionally
    match :class:`RangeStore`'s historical public counters so that class
    can expose its meter through compat properties.
    """

    __slots__ = ("backend", "get_requests", "range_requests", "put_requests",
                 "list_requests", "bytes_fetched", "bytes_put", "_guard")

    def __init__(self, backend: str):
        self.backend = str(backend)
        self.get_requests = 0
        self.range_requests = 0    # subset of get_requests
        self.put_requests = 0     # put + put_atomic
        self.list_requests = 0
        self.bytes_fetched = 0
        self.bytes_put = 0
        self._guard = threading.Lock()

    def record(self, op: str, nbytes: int = 0, seconds: float | None = None,
               ranged: bool = False) -> None:
        """Account one completed operation.

        ``op`` is one of ``get``/``put``/``put_atomic``/``list``/``delete``/
        ``exists``; ``nbytes`` is the payload size (fetched for gets, stored
        for puts); ``seconds`` feeds the latency histogram when the caller
        timed the op.
        """
        with self._guard:
            if op == "get":
                self.get_requests += 1
                if ranged:
                    self.range_requests += 1
                self.bytes_fetched += nbytes
            elif op in ("put", "put_atomic"):
                self.put_requests += 1
                self.bytes_put += nbytes
            elif op == "list":
                self.list_requests += 1
        _OPS.inc(backend=self.backend, op=op)
        if nbytes:
            _BYTES.inc(nbytes, backend=self.backend, op=op)
        if seconds is not None:
            _SECONDS.observe(seconds, backend=self.backend, op=op)

    def stats(self) -> dict:
        """Counters since construction (RangeStore-compatible key names)."""
        with self._guard:
            return {
                "get_requests": self.get_requests,
                "range_requests": self.range_requests,
                "put_requests": self.put_requests,
                "list_requests": self.list_requests,
                "bytes_fetched": self.bytes_fetched,
                "bytes_put": self.bytes_put,
            }


class InstrumentedStore(Store):
    """Delegating store that meters every operation on ``inner``.

    ``backend`` defaults to the inner store's URL scheme (falling back to
    its class name) and becomes the ``backend`` label on the global
    ``cz_store_*`` series; ``.meter`` holds this wrapper's own tally.
    """

    def __init__(self, inner: Store, backend: str | None = None):
        super().__init__()
        self.inner = inner
        label = backend or inner.scheme or type(inner).__name__.lower()
        self.meter = StoreMeter(label)

    def _timed(self, op, fn, *args, nbytes=None, ranged=False):
        t0 = time.perf_counter()
        result = fn(*args)
        dt = time.perf_counter() - t0
        if nbytes is None:
            nbytes = len(result) if op == "get" else 0
        self.meter.record(op, nbytes, dt, ranged=ranged)
        return result

    def get(self, key, byte_range=None):
        return self._timed("get", self.inner.get, key, byte_range,
                           ranged=byte_range is not None)

    def get_many(self, requests):
        """Forward the batch to the inner store (keeping its pipelining)
        and meter each constituent get."""
        reqs = list(requests)
        out = self.inner.get_many(reqs)
        for (_key, rng), data in zip(reqs, out):
            self.meter.record("get", len(data), ranged=rng is not None)
        return out

    def put(self, key, data):
        return self._timed("put", self.inner.put, key, data,
                           nbytes=len(data))

    def put_atomic(self, key, data):
        return self._timed("put_atomic", self.inner.put_atomic, key, data,
                           nbytes=len(data))

    def list(self, prefix=""):
        return self._timed("list", self.inner.list, prefix)

    def delete(self, key):
        return self._timed("delete", self.inner.delete, key)

    def exists(self, key):
        return self._timed("exists", self.inner.exists, key)

    def open_write(self, key):
        return self.inner.open_write(key)

    def lock(self, name):
        return self.inner.lock(name)

    def stats(self) -> dict:
        return self.meter.stats()

    @property
    def url(self) -> str:
        return self.inner.url
