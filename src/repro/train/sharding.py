"""Sharding rules: FSDP-style baseline (+ expert parallelism) for every arch.

Baseline policy (must compile for all 40 dry-run cells):

* every parameter is sharded along its largest "model"-divisible axis
  (ZeRO-3 semantics: GSPMD all-gathers weights at use; avoids head-count
  divisibility hazards — qwen2.5 has 40 heads, smollm 9);
* expert-stacked leaves (``we*``) shard the expert axis when divisible
  (expert parallelism);
* scanned layer-stack axes (leading 1-2 dims of ``blocks`` leaves) are never
  sharded (the scan carries them);
* activations/batches shard over ("pod","data");
* decode caches shard batch over "data" when divisible and the KV sequence
  axis over "model" (the long-context axis — this is what makes
  decode_32k x 128 batch fit).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "state_shardings", "path_str"]


def path_str(path) -> str:
    return "/".join(getattr(k, "key", str(k)) for k in path)


def _n_stack_dims(pstr: str, hybrid: bool = False) -> int:
    if "blocks" not in pstr:
        return 0
    if hybrid:
        # jamba sub-stacks carry (P, n_sub, ...) leading dims
        segs = pstr.split("/")
        if any(seg in ("mamba", "mlp", "moe") for seg in segs[:-1]):
            return 2
    return 1


def _largest_divisible_dim(shape, start: int, n_model: int,
                           prefer: int | None = None) -> int | None:
    if prefer is not None and prefer < len(shape) and shape[prefer] % n_model == 0:
        return prefer
    best, best_size = None, 0
    for i in range(start, len(shape)):
        if shape[i] % n_model == 0 and shape[i] > best_size:
            best, best_size = i, shape[i]
    return best


def _param_pspec(pstr: str, shape, n_model: int, n_data: int = 1,
                 hybrid: bool = False) -> P:
    if len(shape) == 0:
        return P()
    stack = min(_n_stack_dims(pstr, hybrid), len(shape) - 1)
    prefer = None
    leaf = pstr.rsplit("/", 1)[-1]
    if leaf.startswith("we"):            # experts (.., E, D, F) -> shard E
        prefer = stack
    if leaf == "embed":                  # (V, D) -> shard V
        prefer = 0
    spec = [None] * len(shape)
    dim = _largest_divisible_dim(shape, stack, n_model, prefer)
    if dim is not None:
        spec[dim] = "model"
    if n_data > 1:
        # second FSDP axis: shard another dim over "data" (ZeRO-3 within the
        # pod; params stay replicated across pods to bound cross-pod traffic)
        best2, best2_size = None, 0
        for i in range(stack, len(shape)):
            if i != dim and shape[i] % n_data == 0 and shape[i] > best2_size:
                best2, best2_size = i, shape[i]
        if best2 is not None:
            spec[best2] = "data"
    return P(*spec)


_TP_LAST = {"wq", "wk", "wv", "w1", "w3", "ws1", "ws3", "ck", "bq", "bk",
            "bv", "wr", "wg", "in_proj"}
_TP_FIRST_OF_TAIL = {"wo", "w2", "ws2", "cv", "out_proj"}


def _param_pspec_tp(pstr: str, shape, n_model: int, n_data: int,
                    hybrid: bool = False) -> P:
    """Megatron-style tensor parallelism: shard heads/ffn dims over "model";
    params carry no data-axis sharding (pure TP within the pod; optimizer
    moments still use the 2-axis FSDP rule -> ZeRO-1 reduce-scatter/gather
    appears once per step instead of per layer)."""
    if len(shape) == 0:
        return P()
    stack = min(_n_stack_dims(pstr, hybrid), len(shape) - 1)
    leaf = pstr.rsplit("/", 1)[-1]
    spec = [None] * len(shape)
    dim = None
    if leaf in _TP_LAST:
        dim = len(shape) - 1
    elif leaf in _TP_FIRST_OF_TAIL:
        dim = len(shape) - 2
    elif leaf == "embed":
        dim = 0
    elif leaf == "lm_head":
        dim = 1
    elif leaf.startswith("we"):
        dim = stack                      # experts stay expert-parallel
    if dim is not None and dim >= stack and shape[dim] % n_model == 0:
        spec[dim] = "model"
        return P(*spec)
    # fall back to the FSDP rule when TP does not divide
    return _param_pspec(pstr, shape, n_model, 1, hybrid)


def param_shardings(param_tree, mesh, hybrid: bool = False, mode: str = "fsdp"):
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)

    fn = _param_pspec_tp if mode == "tp" else _param_pspec

    def one(path, leaf):
        return NamedSharding(
            mesh, fn(path_str(path), leaf.shape, n_model, n_data, hybrid))

    return jax.tree_util.tree_map_with_path(one, param_tree)


def state_shardings(state_tree, mesh, hybrid: bool = False, mode: str = "fsdp"):
    """Optimizer state mirrors parameter sharding; scalars replicated."""
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)

    def one(path, leaf):
        pstr = path_str(path)
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        # m/v/residual trees live under their own key; strip it for the rule
        is_param = pstr.startswith("params/")
        for pre in ("m/", "v/", "params/", "residual/"):
            if pstr.startswith(pre):
                pstr = pstr[len(pre):]
        if mode == "tp" and is_param:
            # compute path uses TP params; moments keep 2-axis ZeRO sharding
            return NamedSharding(
                mesh, _param_pspec_tp(pstr, leaf.shape, n_model, n_data, hybrid))
        return NamedSharding(
            mesh, _param_pspec(pstr, leaf.shape, n_model, n_data, hybrid))

    return jax.tree_util.tree_map_with_path(one, state_tree)


def batch_shardings(batch_tree, mesh):
    from repro.launch.mesh import batch_axes

    baxes = batch_axes(mesh)
    n_batch = 1
    for a in baxes:
        n_batch *= mesh.shape[a]

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) and shape[0] % n_batch == 0 and shape[0] > 0:
            spec[0] = baxes if len(baxes) > 1 else baxes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh):
    """Decode caches: batch -> data (if divisible), KV seq -> model."""
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]

    def one(path, leaf):
        pstr = path_str(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        leafname = pstr.rsplit("/", 1)[-1]
        if leafname in ("k", "v", "xk", "xv"):
            # (L_or_P, B, S, Hkv, hd)
            if shape[1] % n_data == 0:
                spec[1] = "data"
            if shape[2] % n_model == 0:
                spec[2] = "model"
        elif leafname in ("wkv",):        # (L, B, H, hd, hd)
            if shape[1] % n_data == 0:
                spec[1] = "data"
            if shape[2] % n_model == 0:
                spec[2] = "model"
        elif leafname in ("ssm", "conv"):  # (P, nm, B, Di, ds) / (P, nm, B, K-1, Di)
            if shape[2] % n_data == 0:
                spec[2] = "data"
            di_dim = 3 if leafname == "ssm" else 4
            if shape[di_dim] % n_model == 0:
                spec[di_dim] = "model"
        else:                              # x_tm/x_cm (L, B, 1, D)
            if shape[1] % n_data == 0:
                spec[1] = "data"
            if shape[-1] % n_model == 0:
                spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
