"""GQA attention: chunked streaming-softmax (flash-style) with a custom VJP.

Forward: outer loop over query chunks, inner ``lax.scan`` over KV chunks
carrying the running (max, denom, accum) — never materializes an (S, S)
score tensor, so 32k prefill fits.  Saves only (q, k, v, out, logsumexp).

Backward: custom VJP recomputes each score block from the saved logsumexp
(the FlashAttention recipe) — without it, scan-AD stores every per-chunk
probability block and a 135M model wants ~36 GiB of temps at 4k.

Causal modes:
* ``impl="masked"``      — every q-chunk scans all kv chunks (baseline;
                           ~2x causal-attention FLOPs at long S).
* ``impl="triangular"``  — q-chunk i scans only kv chunks [0..i] (static
                           Python loop); halves causal compute.  §Perf lever.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import rmsnorm, rope

__all__ = ["attention", "decode_attention", "flash_attention"]

NEG_INF = -1e30


def _divisor_chunk(chunk: int, S: int) -> int:
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def _mask_block(s, qi, ki, qc, kc, q_offset):
    qpos = q_offset + qi * qc + jnp.arange(qc)
    kpos = ki * kc + jnp.arange(kc)
    mask = kpos[None, :] <= qpos[:, None]
    return jnp.where(mask[None, None, None], s, NEG_INF)


def _fwd_qchunk(qblk, kg, vg, qi, nk_hi, *, causal, qc, kc, q_offset, scale):
    """One q chunk over kv chunks [0..nk_hi). qblk (B,qc,Hkv,G,hd).
    Returns (out (B,qc,Hkv,G,hd) f32, lse (B,Hkv,G,qc) f32)."""
    B, _, Hkv, G, hd = qblk.shape

    def step(carry, inp):
        kblk, vblk, ki = inp
        m, l, acc = carry
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk).astype(jnp.float32) * scale
        if causal:
            s = _mask_block(s, qi, ki, qc, kc, q_offset)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc * alpha[..., None] + pv), None

    init = (jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, qc), jnp.float32),
            jnp.zeros((B, Hkv, G, qc, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init,
        (kg[:, :nk_hi].swapaxes(0, 1), vg[:, :nk_hi].swapaxes(0, 1),
         jnp.arange(nk_hi)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4), lse  # (B,qc,Hkv,G,hd), (B,Hkv,G,qc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, q_chunk, kv_chunk, q_offset, impl, shard_axes):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset,
                             impl, shard_axes)
    return out


def _cp_constrain(x, shard_axes, n_dim=1):
    """Shard the q-chunk grid dim over "model" (context parallelism)."""
    if not shard_axes:
        return x
    from jax.sharding import PartitionSpec as P

    baxis, maxis = shard_axes
    spec = [None] * x.ndim
    spec[0] = baxis
    spec[n_dim] = maxis
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _cp_mask(s, nq, qc, kc, ki, q_offset):
    qpos = q_offset + (jnp.arange(nq) * qc)[:, None] + jnp.arange(qc)[None, :]
    kpos = ki * kc + jnp.arange(kc)
    mask = kpos[None, None, :] <= qpos[:, :, None]          # (nq, qc, kc)
    return jnp.where(mask[None, :, None, None, :, :], s, NEG_INF)


def _fwd_cp(qg, kg, vg, *, causal, qc, kc, q_offset, scale, shard_axes):
    """Context-parallel flash: all q chunks vectorized (dim 1, sharded over
    "model"), single scan over kv chunks.  No head-divisibility requirement,
    no redundant compute: each device owns S/n_model query rows."""
    B, nq, _, Hkv, G, hd = qg.shape
    nk = kg.shape[1]
    qg = _cp_constrain(qg, shard_axes)

    def step(carry, inp):
        kblk, vblk, ki = inp
        m, l, acc = carry
        s = jnp.einsum("bnqhgd,bkhd->bnhgqk", qg, kblk).astype(jnp.float32) * scale
        if causal:
            s = _cp_mask(s, nq, qc, kc, ki, q_offset)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bnhgqk,bkhd->bnhgqd", p.astype(vblk.dtype), vblk)
        return (m_new, l_new, acc * alpha[..., None] + pv.astype(jnp.float32)), None

    init = (
        _cp_constrain(jnp.full((B, nq, Hkv, G, qc), NEG_INF, jnp.float32), shard_axes),
        _cp_constrain(jnp.zeros((B, nq, Hkv, G, qc), jnp.float32), shard_axes),
        _cp_constrain(jnp.zeros((B, nq, Hkv, G, qc, hd), jnp.float32), shard_axes),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nk)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 1, 4, 2, 3, 5)                    # (B,nq,qc,Hkv,G,hd)
    return out, lse                                          # lse (B,nq,Hkv,G,qc)


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset, impl,
                    shard_axes=None):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qc = _divisor_chunk(q_chunk, Sq)
    kc = _divisor_chunk(kv_chunk, Skv)
    nq, nk = Sq // qc, Skv // kc
    scale = hd ** -0.5
    qg = q.reshape(B, nq, qc, Hkv, G, hd)
    kg = k.reshape(B, nk, kc, Hkv, hd)
    vg = v.reshape(B, nk, kc, Hkv, hd)

    fwd1 = functools.partial(_fwd_qchunk, causal=causal, qc=qc, kc=kc,
                             q_offset=q_offset, scale=scale)
    if impl == "cp":
        out, lse = _fwd_cp(qg, kg, vg, causal=causal, qc=qc, kc=kc,
                           q_offset=q_offset, scale=scale,
                           shard_axes=shard_axes)
        out = out.reshape(B, Sq, Hq, hd).astype(q.dtype)
        return out, lse
    if impl == "triangular" and causal:
        outs, lses = [], []
        for qi in range(nq):
            hi = min(nk, -(-((qi + 1) * qc) // kc))
            o, lse = fwd1(qg[:, qi], kg, vg, qi, hi)
            outs.append(o)
            lses.append(lse)
        out = jnp.stack(outs, 1)          # (B,nq,qc,Hkv,G,hd)
        lse = jnp.stack(lses, 1)          # (B,nq,Hkv,G,qc)
    else:
        def one(args):
            qi, qblk = args
            return fwd1(qblk, kg, vg, qi, nk)

        out, lse = jax.lax.map(one, (jnp.arange(nq), qg.swapaxes(0, 1)))
        out = out.swapaxes(0, 1)
        lse = lse.swapaxes(0, 1)
    out = out.reshape(B, Sq, Hq, hd).astype(q.dtype)
    return out, lse  # lse (B,nq,Hkv,G,qc)


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset, impl, shard_axes):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset,
                               impl, shard_axes)
    return out, (q, k, v, out, lse)


def _bwd_cp(q, k, v, out, lse, dout, *, causal, qc, kc, q_offset, scale,
            shard_axes):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // qc, Skv // kc
    qg = _cp_constrain(q.reshape(B, nq, qc, Hkv, G, hd), shard_axes)
    dog = _cp_constrain(dout.reshape(B, nq, qc, Hkv, G, hd), shard_axes)
    kg = k.reshape(B, nk, kc, Hkv, hd)
    vg = v.reshape(B, nk, kc, Hkv, hd)
    Drow = (dout.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    Drow = Drow.reshape(B, nq, qc, Hkv, G).transpose(0, 1, 3, 4, 2)

    def kv_step(dq_acc, inp):
        kblk, vblk, ki = inp
        s = jnp.einsum("bnqhgd,bkhd->bnhgqk", qg, kblk).astype(jnp.float32) * scale
        if causal:
            s = _cp_mask(s, nq, qc, kc, ki, q_offset)
        p = jnp.exp(s - lse[..., None])
        dv_j = jnp.einsum("bnhgqk,bnqhgd->bkhd", p.astype(dog.dtype), dog)
        dp = jnp.einsum("bnqhgd,bkhd->bnhgqk", dog, vblk).astype(jnp.float32)
        ds = p * (dp - Drow[..., None])
        dq_c = jnp.einsum("bnhgqk,bkhd->bnqhgd", ds.astype(kblk.dtype), kblk)
        dk_j = jnp.einsum("bnhgqk,bnqhgd->bkhd", ds.astype(qg.dtype), qg)
        return dq_acc + dq_c.astype(jnp.float32) * scale, (
            dk_j.astype(jnp.float32) * scale, dv_j.astype(jnp.float32))

    dq0 = _cp_constrain(jnp.zeros((B, nq, qc, Hkv, G, hd), jnp.float32),
                        shard_axes)
    dq, (dk_js, dv_js) = jax.lax.scan(
        kv_step, dq0, (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nk)))
    dq = dq.reshape(B, Sq, Hq, hd)
    dk = dk_js.swapaxes(0, 1).reshape(B, Skv, Hkv, hd)
    dv = dv_js.swapaxes(0, 1).reshape(B, Skv, Hkv, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _flash_bwd(causal, q_chunk, kv_chunk, q_offset, impl, shard_axes, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qc = _divisor_chunk(q_chunk, Sq)
    kc = _divisor_chunk(kv_chunk, Skv)
    nq, nk = Sq // qc, Skv // kc
    scale = hd ** -0.5
    if impl == "cp":
        return _bwd_cp(q, k, v, out, lse, dout, causal=causal, qc=qc, kc=kc,
                       q_offset=q_offset, scale=scale, shard_axes=shard_axes)

    qg = q.reshape(B, nq, qc, Hkv, G, hd)
    kg = k.reshape(B, nk, kc, Hkv, hd)
    vg = v.reshape(B, nk, kc, Hkv, hd)
    dog = dout.reshape(B, nq, qc, Hkv, G, hd)
    # D_i = rowsum(dout * out) per query position
    Drow = (dout.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    Drow = Drow.reshape(B, nq, qc, Hkv, G).transpose(0, 1, 3, 4, 2)  # (B,nq,Hkv,G,qc)

    def qchunk_bwd(carry, inp):
        dk_acc, dv_acc = carry
        qi, qblk, doblk, lse_i, D_i = inp
        # qblk (B,qc,Hkv,G,hd); doblk same; lse_i/D_i (B,Hkv,G,qc)

        def kv_step(dq_acc, kv_inp):
            kblk, vblk, ki = kv_inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                s = _mask_block(s, qi, ki, qc, kc, q_offset)
            p = jnp.exp(s - lse_i[..., None])                      # (B,Hkv,G,qc,kc)
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p.astype(doblk.dtype), doblk)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doblk, vblk).astype(jnp.float32)
            ds = p * (dp - D_i[..., None])
            dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(kblk.dtype), kblk)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(qblk.dtype), qblk)
            return dq_acc + dq_c.astype(jnp.float32) * scale, (
                dk_j.astype(jnp.float32) * scale, dv_j.astype(jnp.float32))

        dq_i = jnp.zeros((B, qc, Hkv, G, hd), jnp.float32)
        dq_i, (dk_js, dv_js) = jax.lax.scan(
            kv_step, dq_i,
            (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nk)))
        dk_acc = dk_acc + dk_js.swapaxes(0, 1).reshape(B, Skv, Hkv, hd)
        dv_acc = dv_acc + dv_js.swapaxes(0, 1).reshape(B, Skv, Hkv, hd)
        return (dk_acc, dv_acc), dq_i

    init = (jnp.zeros((B, Skv, Hkv, hd), jnp.float32),
            jnp.zeros((B, Skv, Hkv, hd), jnp.float32))
    (dk, dv), dqs = jax.lax.scan(
        qchunk_bwd, init,
        (jnp.arange(nq), qg.swapaxes(0, 1), dog.swapaxes(0, 1),
         lse.swapaxes(0, 1), Drow.swapaxes(0, 1)))
    dq = dqs.swapaxes(0, 1).reshape(B, Sq, Hq, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool, q_chunk: int = 256,
                    kv_chunk: int = 512, impl: str = "masked",
                    q_offset: int = 0, shard_axes=None):
    """q (B,Sq,Hq,hd); k,v (B,Skv,Hkv,hd); Hq = Hkv*G -> (B,Sq,Hq,hd)."""
    return _flash(q, k, v, causal, q_chunk, kv_chunk, q_offset, impl,
                  shard_axes)


def _project_qkv(x, p, cfg, positions, use_rope=True):
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qnorm"], cfg.norm_eps)
        k = rmsnorm(k, p["knorm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(x, p, cfg, *, causal=True, impl="masked", q_chunk=256,
              kv_chunk=512, attn_shard="auto", batch_axes=("data",),
              n_model=1):
    """Full-sequence attention (train/prefill). x: (B,S,D).

    ``attn_shard``:
      auto      — let GSPMD propagate (it may shard the contraction dim when
                  head counts don't divide the mesh, paying a score
                  all-reduce per flash chunk-step — measured 4.3 TB/step on
                  qwen3 train_4k);
      replicate — pin q/k/v replicated over "model": attention computes
                  locally (redundant over the model axis, zero collectives);
      heads     — shard q heads over "model" when divisible, k/v replicated
                  (GQA: every device holds all 8 KV heads, its slice of the
                  64 q heads; no collectives, no redundant compute).
    """
    B, S, D = x.shape
    use_rope = cfg.family != "encdec"
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(x, p, cfg, positions, use_rope)
    if attn_shard in ("replicate", "heads", "cp") and n_model > 1:
        from jax.sharding import PartitionSpec as P

        baxis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        kv_spec = P(baxis, None, None, None)
        if attn_shard == "heads" and cfg.n_heads % n_model == 0                 and (cfg.n_heads // cfg.n_kv_heads) % n_model == 0:
            q = jax.lax.with_sharding_constraint(
                q, P(baxis, None, "model", None))
        else:
            q = jax.lax.with_sharding_constraint(q, kv_spec)
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)
    shard_axes = None
    if attn_shard == "cp" and n_model > 1:
        baxis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        shard_axes = (baxis, "model")
        impl = "cp"
    elif attn_shard == "cp":
        impl = "cp"
    o = flash_attention(q, k, v, causal=causal, impl=impl,
                        q_chunk=q_chunk, kv_chunk=kv_chunk,
                        shard_axes=shard_axes)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])


def decode_attention(x, p, cfg, cache, pos):
    """Single-token decode. x: (B,1,D); cache: dict(k,v) (B,S,Hkv,hd).

    The new KV is written at ``pos``; attention masks positions > pos."""
    B, _, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = cache["k"].shape[1]
    use_rope = cfg.family != "encdec"
    positions = jnp.full((B, 1), pos)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions, use_rope)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * hd ** -0.5
    mask = jnp.arange(S)[None, None, None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, Hq * hd), p["wo"])
    return out, {"k": k, "v": v}
