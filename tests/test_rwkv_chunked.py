"""Chunked (GLA-style) WKV must match the sequential recurrence exactly."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import ssm
from repro.models.common import Maker
from repro.models.transformer import _rwkv_leaves


def setup(seed=0, B=2, S=64):
    cfg = reduced(ARCHS["rwkv6-7b"])
    mk = Maker("init", key=jax.random.PRNGKey(seed), dtype=jnp.float32)
    p = _rwkv_leaves(mk, cfg, ())["tm"]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("chunk", [4, 16, 32, 64])
@pytest.mark.parametrize("seed", [0, 3])
def test_chunked_matches_sequential(chunk, seed):
    cfg, p, x = setup(seed)
    o1, (s1, _) = ssm.rwkv6_timemix(x, p, cfg)
    o2, (s2, _) = ssm.rwkv6_timemix_chunked(x, p, cfg, chunk=chunk)
    scale = float(jnp.max(jnp.abs(o1))) + 1e-9
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               atol=3e-5 * scale, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1),
                               atol=3e-5 * float(jnp.max(jnp.abs(s1))) + 1e-9,
                               rtol=1e-4)


def test_chunked_with_carried_state():
    """Chunked over [0:32] then [32:64] == sequential over [0:64]."""
    cfg, p, x = setup(seed=1)
    o_ref, (s_ref, _) = ssm.rwkv6_timemix(x, p, cfg)
    o_a, (s_a, xp) = ssm.rwkv6_timemix_chunked(x[:, :32], p, cfg, chunk=16)
    o_b, (s_b, _) = ssm.rwkv6_timemix_chunked(x[:, 32:], p, cfg, state=s_a,
                                              x_prev=xp, chunk=16)
    got = jnp.concatenate([o_a, o_b], axis=1)
    scale = float(jnp.max(jnp.abs(o_ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(got), np.asarray(o_ref),
                               atol=3e-5 * scale, rtol=1e-4)


def test_chunked_grads_finite():
    cfg, p, x = setup(seed=2)

    def loss(p):
        o, _ = ssm.rwkv6_timemix_chunked(x, p, cfg, chunk=16)
        return (o ** 2).sum()

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
