"""Block-structured layout for 3D fields (CubismZ cluster/node layer analogue).

A 3D field of shape (nx, ny, nz) is decomposed into cubic blocks of side
``bs`` (power of two).  Blocks are fully independent compression units — the
"on the interval" wavelet property means no halo exchange is required, which
is what makes the scheme embarrassingly parallel in the paper and lets us
``vmap``/Pallas-grid over blocks here.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["blockify", "unblockify", "num_blocks", "check_block_size"]


def check_block_size(bs: int) -> None:
    if bs < 4 or (bs & (bs - 1)) != 0:
        raise ValueError(f"block size must be a power of 2 and >= 4, got {bs}")


def num_blocks(shape: tuple[int, int, int], bs: int) -> tuple[int, int, int]:
    check_block_size(bs)
    for s in shape:
        if s % bs != 0:
            raise ValueError(f"field shape {shape} not divisible by block size {bs}")
    return tuple(s // bs for s in shape)


def blockify(field, bs: int):
    """(nx, ny, nz) -> (n_blocks, bs, bs, bs), C-order block raster."""
    nx, ny, nz = field.shape
    bx, by, bz = num_blocks((nx, ny, nz), bs)
    xp = jnp if isinstance(field, jnp.ndarray) else np
    f = field.reshape(bx, bs, by, bs, bz, bs)
    f = xp.transpose(f, (0, 2, 4, 1, 3, 5))
    return f.reshape(bx * by * bz, bs, bs, bs)


def unblockify(blocks, shape: tuple[int, int, int]):
    """(n_blocks, bs, bs, bs) -> (nx, ny, nz); inverse of :func:`blockify`."""
    bs = blocks.shape[-1]
    nx, ny, nz = shape
    bx, by, bz = num_blocks((nx, ny, nz), bs)
    xp = jnp if isinstance(blocks, jnp.ndarray) else np
    f = blocks.reshape(bx, by, bz, bs, bs, bs)
    f = xp.transpose(f, (0, 3, 1, 4, 2, 5))
    return f.reshape(nx, ny, nz)
