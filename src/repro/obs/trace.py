"""Low-overhead span tracing exported as Chrome trace-event JSON.

The paper's per-stage timing figure, reproduced as a timeline: wrap any
region of work in ``with span("encode", chunk=i):`` (or decorate it with
:func:`traced`) and, when tracing is enabled, a complete event (``"ph":
"X"``) lands on the current thread's track.  :meth:`Tracer.save` writes the
collected events as Chrome trace-event JSON — load the file in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing`` to see per-thread and
per-rank tracks.

Disabled is the default and costs almost nothing: :func:`span` returns a
shared no-op context manager after one attribute check, so instrumented hot
paths (per-chunk encode, store gets) stay within noise when nobody is
tracing (the ``bench_speed`` overhead budget is < 2%).

Clocks are monotonic (``time.perf_counter_ns``); each tracer also anchors a
wall-clock epoch at :meth:`Tracer.enable` so traces from *different
processes* can be merged onto one timeline: the cluster engine's worker
ranks each dump a trace file, and the parent folds them in with
:meth:`Tracer.absorb` (or standalone :func:`merge_traces`), one ``pid``
track per rank.

Stdlib only — importable before numpy/jax.
"""
from __future__ import annotations

import json
import os
import threading
import time

from repro.obs import context as _context

__all__ = ["Tracer", "TRACER", "span", "traced", "tracing", "enable",
           "disable", "record", "reset", "save", "merge_traces"]


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        _emit(self._tracer, self._name, self._t0, time.perf_counter_ns(),
              self._args)
        return False


def _emit(tracer: "Tracer", name: str, t0_ns: int, t1_ns: int,
          args: dict) -> None:
    """Deliver one completed span to the tracer *and* the active request
    context: the request ID is stamped onto the tracer event (so one slow
    query is findable on the Perfetto timeline) and, when the context is
    collecting, the span joins the per-request timeline the tail sampler
    may keep."""
    ctx = _context.current()
    if ctx is not None:
        if args.get("rid") is None:
            args = {**args, "rid": ctx.rid} if args else {"rid": ctx.rid}
        ctx.record(name, t0_ns, t1_ns, args)
    tracer.record(name, t0_ns, t1_ns, **args)


class Tracer:
    """One process's span collector.

    Thread-safe; every thread gets its own track (``tid``) named after
    ``threading.current_thread().name``.  ``process_name`` labels the
    ``pid`` track in viewers (the cluster engine sets ``"rank N"`` in its
    workers).
    """

    def __init__(self, process_name: str | None = None):
        self.enabled = False
        self.pid = os.getpid()
        self.process_name = process_name or "main"
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._threads: dict[int, str] = {}
        self._local = threading.local()
        self._origin_ns = time.perf_counter_ns()
        self._epoch_us = time.time_ns() // 1000

    # -- lifecycle -----------------------------------------------------------

    def enable(self) -> None:
        """Start collecting (idempotent).  Re-anchors the clock only when
        turning on from scratch, so enable/disable around phases of one run
        share a timeline."""
        with self._lock:
            if not self.enabled and not self._events:
                self._origin_ns = time.perf_counter_ns()
                self._epoch_us = time.time_ns() // 1000
            self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all events and re-anchor the clock (enabled state kept)."""
        with self._lock:
            self._events.clear()
            self._threads.clear()
            self._local = threading.local()
            self._origin_ns = time.perf_counter_ns()
            self._epoch_us = time.time_ns() // 1000

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args):
        """Context manager timing one region of work.  A no-op singleton
        when disabled — the enabled check is the only cost."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, args)

    def _tid(self) -> int:
        tid = getattr(self._local, "tid", None)
        if tid is None:
            with self._lock:
                tid = self._local.tid = len(self._threads)
                self._threads[tid] = threading.current_thread().name
        return tid

    def record(self, name: str, t0_ns: int, t1_ns: int, **args) -> None:
        """Append one complete event from explicit ``perf_counter_ns``
        stamps — for instrumentation that already timed the work (the
        pipeline's per-chunk path computes bytes/ratio after the fact)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "X", "cat": "repro",
              "ts": (t0_ns - self._origin_ns) / 1e3,
              "dur": (t1_ns - t0_ns) / 1e3,
              "pid": self.pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, **args) -> None:
        """Mark a point in time (``"ph": "i"``)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t", "cat": "repro",
              "ts": (time.perf_counter_ns() - self._origin_ns) / 1e3,
              "pid": self.pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return [dict(ev) for ev in self._events]

    def _metadata_events(self) -> list[dict]:
        with self._lock:
            threads = dict(self._threads)
        evs = [{"name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
                "args": {"name": self.process_name}}]
        for tid, tname in threads.items():
            evs.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                        "tid": tid, "args": {"name": tname}})
        return evs

    def chrome(self) -> dict:
        """The Chrome trace-event document (``traceEvents`` + metadata).
        Events are sorted by timestamp; ``metadata.epoch_us`` anchors this
        process's monotonic origin to the wall clock for cross-process
        merges."""
        evs = self.events()
        # absorbed child docs contribute their own ph="M" rows (no ts) —
        # metadata leads, timed events sort globally
        meta = [e for e in evs if e.get("ph") == "M"]
        timed = sorted((e for e in evs if e.get("ph") != "M"),
                       key=lambda e: e["ts"])
        return {"traceEvents": self._metadata_events() + meta + timed,
                "displayTimeUnit": "ms",
                "metadata": {"epoch_us": self._epoch_us,
                             "process_name": self.process_name}}

    def save(self, path: str) -> str:
        """Write the Chrome trace JSON; returns ``path``."""
        doc = self.chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def absorb(self, doc: dict, pid=None, process_name: str | None = None
               ) -> int:
        """Fold another process's saved trace document into this tracer,
        shifting its timestamps onto this timeline via the wall-clock
        anchors.  ``pid`` reassigns the absorbed events' track (the cluster
        engine passes the rank number); returns the event count absorbed."""
        shift = (doc.get("metadata", {}).get("epoch_us", self._epoch_us)
                 - self._epoch_us)
        absorbed = []
        for ev in doc.get("traceEvents", []):
            ev = dict(ev)
            if pid is not None:
                ev["pid"] = pid
            if ev.get("ph") == "M":
                if process_name is not None and \
                        ev.get("name") == "process_name":
                    ev["args"] = {"name": process_name}
            else:
                ev["ts"] = float(ev.get("ts", 0.0)) + shift
            absorbed.append(ev)
        with self._lock:
            self._events.extend(absorbed)
        return len(absorbed)


#: the process-wide tracer (module-level helpers target it).
TRACER = Tracer()


def span(name: str, **args):
    """``with span("encode", chunk=i): ...`` against the process tracer.

    Live when the process tracer is enabled **or** the calling thread is
    inside a collecting request context (the serve tier's tail sampling) —
    otherwise the shared no-op singleton, so uninstrumented runs pay two
    cheap checks."""
    if TRACER.enabled:
        return _Span(TRACER, name, args)
    ctx = _context.current()
    if ctx is not None and ctx.collecting:
        return _Span(TRACER, name, args)
    return _NULL


def record(name: str, t0_ns: int, t1_ns: int, **args) -> None:
    """Record one already-timed span against the process tracer *and* the
    active request context (instrumentation that computes byte counts after
    the fact uses this instead of :func:`span`)."""
    if TRACER.enabled or _context.current() is not None:
        _emit(TRACER, name, t0_ns, t1_ns, args)


def traced(name: str | None = None, **cargs):
    """Decorator form: ``@traced()`` (span named after the function) or
    ``@traced("stage1", scheme="wavelet")``."""
    import functools

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **k):
            with span(label, **cargs):
                return fn(*a, **k)

        return wrapper

    return deco


def tracing() -> bool:
    return TRACER.enabled


def enable() -> None:
    TRACER.enable()


def disable() -> None:
    TRACER.disable()


def reset() -> None:
    TRACER.reset()


def save(path: str) -> str:
    return TRACER.save(path)


def merge_traces(sources, out: str | None = None, pids=None) -> dict:
    """Merge saved trace files (paths or already-loaded documents) into one
    Chrome trace document on a common timeline.

    Timestamps are aligned via each document's ``metadata.epoch_us`` anchor
    (earliest anchor becomes t=0); ``pids`` optionally reassigns each
    source's events to a track (e.g. ``pids=range(nranks)`` for per-rank
    files).  Non-metadata events come out globally sorted by timestamp.
    ``out`` additionally writes the merged document to a file.
    """
    docs = []
    for src in sources:
        if isinstance(src, (str, os.PathLike)):
            with open(src) as f:
                docs.append(json.load(f))
        else:
            docs.append(src)
    if not docs:
        raise ValueError("merge_traces needs at least one source")
    anchors = [d.get("metadata", {}).get("epoch_us", 0) for d in docs]
    base = min(anchors)
    meta: list[dict] = []
    events: list[dict] = []
    for i, (doc, anchor) in enumerate(zip(docs, anchors)):
        pid = None if pids is None else pids[i]
        shift = anchor - base
        for ev in doc.get("traceEvents", []):
            ev = dict(ev)
            if pid is not None:
                ev["pid"] = pid
            if ev.get("ph") == "M":
                meta.append(ev)
            else:
                ev["ts"] = float(ev.get("ts", 0.0)) + shift
                events.append(ev)
    events.sort(key=lambda e: e["ts"])
    merged = {"traceEvents": meta + events, "displayTimeUnit": "ms",
              "metadata": {"epoch_us": base, "merged_from": len(docs)}}
    if out is not None:
        with open(out, "w") as f:
            json.dump(merged, f)
    return merged
