"""Chunk sampler + trial runner: score every candidate scheme on a sample.

One decision = encode a deterministic sample of the chunk's blocks under
every admissible candidate spec (stage 1 + byte layout + stage 2 — the
real encode path, so trial sizes are the sizes the winner will actually
produce), decode it back, and rank by achieved ratio under the measured
bound.  Trials run concurrently on a shared daemon pool; the ranking is
pure (sampling, candidate order, and tie-breaks use no randomness and no
wall clock), so the same chunk bytes always produce the same decision —
the property the cluster engine's rank invariance rests on.

The ranked list — not just the winner — is returned: a winner whose
stage 1 rejects the *full* chunk (e.g. szx's eps/magnitude guard firing on
values the sample missed) falls through to the runner-up, ending at a
lossless scheme which can never fail.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
import threading
import time

import numpy as np

from repro import obs
from repro.obs import trace
from repro.core import lossless
from repro.core.pipeline import CompressionSpec
from repro.core.schemes import SCHEMES, get_scheme

from .bound import Target, candidate_spec

__all__ = ["Trial", "Decision", "sample_blocks", "run_trials"]

#: blocks per trial sample — enough to expose per-regime behaviour, small
#: enough that a full candidate sweep costs a fraction of one chunk encode
SAMPLE_BLOCKS = 4

_TRIALS = obs.counter("cz_tune_trials_total",
                      "Auto-tuner candidate trial encodes by scheme.",
                      labelnames=("scheme",))
_DECISION_SECONDS = obs.histogram(
    "cz_tune_decision_seconds",
    "Wall time of one per-chunk auto-tuning decision (all trials).",
    buckets=obs.FAST_BUCKETS)

_POOL = None
_POOL_GUARD = threading.Lock()


def _trial_pool():
    """Shared daemon pool for candidate trials — separate from the
    pipeline's chunk-encode pool (a chunk worker *waits* on its trials;
    sharing one pool would deadlock once saturated with waiting parents)."""
    global _POOL
    with _POOL_GUARD:
        if _POOL is None:
            _POOL = concurrent.futures.ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="cz-tune")
        return _POOL


@dataclasses.dataclass(frozen=True)
class Trial:
    """One candidate's measured score on the sample."""

    scheme: str
    eps: float
    nbytes: int          # stage-1+2 encoded size of the sample
    ratio: float         # raw sample bytes / nbytes
    max_err: float       # measured on the decoded sample
    psnr: float          # paper Eq. 1 on the sample (inf when exact)
    seconds: float       # encode+decode wall time
    admissible: bool     # meets the target on the sample
    error: str | None = None   # stage-1/serialize failure, if any


@dataclasses.dataclass(frozen=True)
class Decision:
    """Ranked outcome of one chunk's trials (best candidate first)."""

    target: str                              # normalized target string
    abs_bound: float                         # bound the trials enforced
    ranked: tuple[CompressionSpec, ...]      # admissible specs, best first
    trials: tuple[Trial, ...]                # every trial, scored

    @property
    def winner(self) -> CompressionSpec:
        return self.ranked[0]


def sample_blocks(blocks_np: np.ndarray,
                  max_blocks: int = SAMPLE_BLOCKS) -> np.ndarray:
    """A deterministic, content-independent sample of the chunk's blocks:
    an even stride over block indices (always including block 0).  Content
    independence matters — the *same* blocks are sampled however the chunk
    reached us (serial, threaded, or any rank partitioning)."""
    n = int(blocks_np.shape[0])
    if n <= max_blocks:
        return blocks_np
    stride = -(-n // max_blocks)  # ceil: at most max_blocks samples
    return blocks_np[::stride]


def _measured_psnr(sample: np.ndarray, dec: np.ndarray,
                   rng: float) -> float:
    m = float(np.mean((np.asarray(sample, np.float64)
                       - np.asarray(dec, np.float64)) ** 2))
    if m == 0.0:
        return float("inf")
    if rng <= 0.0:
        return float("-inf")  # inexact decode of constant data
    return 20.0 * math.log10(rng / (2.0 * math.sqrt(m)))


def _run_one(cand: CompressionSpec, sample: np.ndarray, rng: float,
             target: Target, abs_bound: float) -> Trial:
    """Encode + decode the sample under one candidate and score it."""
    sch = get_scheme(cand.scheme)
    nblk = int(sample.shape[0])
    raw = int(sample.size * cand.np_dtype.itemsize)
    t0 = time.perf_counter()
    _TRIALS.inc(scheme=cand.scheme)
    try:
        with trace.span("tune.trial", scheme=cand.scheme, eps=cand.eps,
                        nblocks=nblk):
            s1 = sch.stage1(np.asarray(sample, cand.np_dtype), cand)
            enc = lossless.encode(sch.serialize(s1, 0, nblk, cand),
                                  cand.stage2)
            dec = sch.deserialize(lossless.decode(enc, cand.stage2),
                                  nblk, cand).astype(cand.np_dtype,
                                                     copy=False)
    except ValueError as e:  # e.g. szx eps/magnitude guard on this sample
        return Trial(cand.scheme, cand.eps, 0, 0.0, float("inf"),
                     float("-inf"), time.perf_counter() - t0,
                     admissible=False, error=str(e))
    max_err = float(np.max(np.abs(np.asarray(sample, np.float64)
                                  - np.asarray(dec, np.float64)))) \
        if nblk else 0.0
    psnr = _measured_psnr(sample, dec, rng)
    if target.mode == "psnr":
        ok = psnr >= target.value
    else:
        # one ulp of slack at the sample magnitude: decode casts back to
        # the tagged dtype (same quanta the conformance suite allows)
        ulp = float(np.spacing(cand.np_dtype.type(
            max(abs(float(sample.max())), abs(float(sample.min()))) or 1.0)))
        ok = max_err <= abs_bound * (1 + 1e-6) + ulp
    return Trial(cand.scheme, cand.eps, len(enc),
                 raw / max(1, len(enc)), max_err, psnr,
                 time.perf_counter() - t0, admissible=ok)


def run_trials(blocks_np: np.ndarray, spec: CompressionSpec,
               target: Target) -> Decision:
    """Trial every admissible candidate scheme on a sample of this chunk
    and return the ranked :class:`Decision`.

    Candidates are every registered scheme except ``spec.scheme`` itself
    (the meta-scheme must not recurse), each at the eps that meets the
    chunk's absolute bound (:func:`~repro.tune.bound.candidate_spec`).
    Ranking is by measured sample size ascending with the scheme name as
    the deterministic tie-break; at least one lossless candidate (``raw``)
    is always admissible, so the ranking is never empty.
    """
    t0 = time.perf_counter()
    blocks_np = np.asarray(blocks_np, spec.np_dtype)
    vmin = float(blocks_np.min())
    vmax = float(blocks_np.max())
    abs_bound = target.abs_bound(vmin, vmax)
    sample = sample_blocks(blocks_np)
    rng = float(np.asarray(sample, np.float64).max()
                - np.asarray(sample, np.float64).min()) if sample.size else 0.0

    cands = [c for c in (candidate_spec(name, spec, abs_bound)
                         for name in sorted(SCHEMES)
                         if name != spec.scheme) if c is not None]
    futs = [_trial_pool().submit(_run_one, c, sample, rng, target, abs_bound)
            for c in cands]
    trials = [f.result() for f in futs]

    order = sorted(
        (i for i, t in enumerate(trials) if t.admissible),
        key=lambda i: (trials[i].nbytes, trials[i].scheme))
    ranked = tuple(cands[i] for i in order)
    if not ranked:  # unreachable while `raw` is registered; stay safe
        raise ValueError(
            f"no registered scheme can meet target {target} "
            f"(bound {abs_bound:.3e}) on this chunk")
    _DECISION_SECONDS.observe(time.perf_counter() - t0)
    return Decision(target=str(target), abs_bound=abs_bound,
                    ranked=ranked, trials=tuple(trials))
