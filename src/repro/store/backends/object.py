"""RangeStore: an object-store-style backend with S3 access semantics.

The test double that keeps the read path honest.  Like a cloud object
store, it permits exactly two data operations:

* **whole-object put** — objects are immutable blobs, there is no seek,
  no append, no rename.  ``put_atomic`` *is* ``put`` (a single PUT is
  atomic), and the CZ2 writer goes through the buffering ``open_write``
  because you cannot patch a footer pointer in place;
* **byte-range get** — ``get(key, byte_range=(off, end))``, the S3
  ``Range: bytes=off-`` request.

Every request is counted (``stats()``) through a shared
:class:`~repro.store.backends.instrument.StoreMeter`, so tests and
benchmarks can assert that a region query fetched *ranges of* a member,
not the member — the access pattern error-bounded compressors are judged
on — and the same tallies surface as ``cz_store_*`` series in the global
metrics registry.  The historical per-instance counters
(``get_requests`` etc.) remain readable as compat properties.  An optional
``latency`` models per-request round-trip cost so ``bench_backends`` can
show how chunk caching amortizes a remote store.
"""
from __future__ import annotations

import time

from .base import shared_io_pool
from .instrument import StoreMeter
from .memory import MemoryStore

__all__ = ["RangeStore"]


class RangeStore(MemoryStore):
    """Object-store semantics over in-memory blobs, with request counters."""

    scheme = "range"

    #: distinct ``range://`` namespace (MemoryStore's registry is per-class)
    _named: dict[str, "RangeStore"] = {}

    def __init__(self, name: str | None = None, latency: float = 0.0):
        super().__init__(name)
        self.latency = float(latency)
        self.meter = StoreMeter("range")

    def _request(self) -> None:
        if self.latency:
            time.sleep(self.latency)

    # -- historical counter attributes, now views over the meter ------------

    @property
    def get_requests(self) -> int:
        return self.meter.get_requests

    @property
    def range_requests(self) -> int:
        return self.meter.range_requests

    @property
    def put_requests(self) -> int:
        return self.meter.put_requests

    @property
    def bytes_fetched(self) -> int:
        return self.meter.bytes_fetched

    @property
    def bytes_put(self) -> int:
        return self.meter.bytes_put

    def get(self, key, byte_range=None):
        t0 = time.perf_counter()
        self._request()
        data = super().get(key, byte_range)
        self.meter.record("get", len(data), time.perf_counter() - t0,
                          ranged=byte_range is not None)
        return data

    def get_many(self, requests):
        """Pipelined ranged gets: each request still pays ``latency``, but
        the round trips overlap — what a real object store's concurrent
        range requests buy, and what the prefetch bench measures."""
        reqs = list(requests)
        if len(reqs) < 2:
            return [self.get(k, r) for k, r in reqs]
        pool = shared_io_pool()
        return [f.result()
                for f in [pool.submit(self.get, k, r) for k, r in reqs]]

    def put(self, key, data):
        t0 = time.perf_counter()
        self._request()
        super().put(key, data)
        self.meter.record("put", len(data), time.perf_counter() - t0)

    def stats(self) -> dict:
        """Request/traffic counters since construction."""
        out = self.meter.stats()
        del out["list_requests"]  # not part of the historical shape
        with self._guard:
            out["objects"] = len(self._objects)
            out["bytes_stored"] = sum(map(len, self._objects.values()))
        return out
