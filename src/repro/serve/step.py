"""Serve-step builders: batched single-token decode and prompt prefill,
jitted with production-mesh shardings (KV sequence axis sharded over
"model", batch over "data").  The compressed-field analogue —
:class:`~repro.serve.region.FieldRegionServer`, region queries against a
CZDataset through a shared decode cache — lives in the jax-free
:mod:`repro.serve.region` (re-exported here)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import ModelSettings, decode_step, prefill
from repro.train.sharding import batch_shardings, cache_shardings, param_shardings

from .region import FieldRegionServer  # noqa: F401  (back-compat re-export)

__all__ = ["build_decode_step", "build_prefill_step", "FieldRegionServer"]


def build_decode_step(cfg, mesh, *, settings: ModelSettings = ModelSettings(),
                      donate_cache: bool = True):
    """decode(params, cache, token, pos) -> (logits, new_cache)."""

    def fn(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg, settings)

    def jit_for(param_tree, cache_tree, token_spec):
        in_sh = (
            param_shardings(param_tree, mesh, hybrid=(cfg.family == "hybrid")),
            cache_shardings(cache_tree, mesh),
            batch_shardings(token_spec, mesh),
            NamedSharding(mesh, P()),
        )
        out_sh = (None, cache_shardings(cache_tree, mesh))
        return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(1,) if donate_cache else ())

    return fn, jit_for


def build_prefill_step(cfg, mesh, *, settings: ModelSettings = ModelSettings()):
    import dataclasses as _dc

    from repro.launch.mesh import batch_axes as _baxes

    baxes = _baxes(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    settings = _dc.replace(settings, batch_axes=baxes,
                           n_model=mesh.shape["model"], n_batch=nb)

    def fn(params, tokens, frames=None):
        return prefill(params, tokens, cfg, settings, enc_inputs=frames)

    def jit_for(param_tree, batch_specs):
        in_sh = [param_shardings(param_tree, mesh, hybrid=(cfg.family == "hybrid")),
                 batch_shardings(batch_specs["tokens"], mesh)]
        nargs = 2
        if "frames" in batch_specs:
            in_sh.append(batch_shardings(batch_specs["frames"], mesh))
            nargs = 3
        return jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=None), nargs

    return fn, jit_for
