"""Deterministic synthetic token pipeline (sharded, resumable).

Sequences follow a mixture of order-1 Markov regimes over the vocab, so a
language model can actually *learn* (loss decreases measurably within a few
hundred steps — the end-to-end example's success criterion), while every
batch is a pure function of (seed, step, shard), which makes data iteration
order exactly reproducible across restarts and elastic resharding: shard i
of step t is identical no matter how many hosts are reading.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DataConfig", "batch_at"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 512
    batch: int = 8
    seq: int = 64
    seed: int = 1234
    n_regimes: int = 4
    branching: int = 8      # successors per token (lower = easier)


def _regime_tables(cfg: DataConfig) -> np.ndarray:
    """(n_regimes, vocab, branching) successor tables, deterministic."""
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(0, cfg.vocab, (cfg.n_regimes, cfg.vocab, cfg.branching))


_TABLE_CACHE: dict = {}


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Batch for a global step: dict(tokens (B,S), labels (B,S)) int32."""
    key = (cfg.vocab, cfg.seed, cfg.n_regimes, cfg.branching)
    if key not in _TABLE_CACHE:
        _TABLE_CACHE[key] = _regime_tables(cfg)
    tables = _TABLE_CACHE[key]
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.batch, cfg.seq
    regime = rng.integers(0, cfg.n_regimes, (B,))
    tok = np.empty((B, S + 1), np.int64)
    tok[:, 0] = rng.integers(0, cfg.vocab, (B,))
    choice = rng.integers(0, cfg.branching, (B, S))
    for t in range(S):
        tok[:, t + 1] = tables[regime, tok[:, t], choice[:, t]]
    return {
        "tokens": tok[:, :-1].astype(np.int32),
        "labels": tok[:, 1:].astype(np.int32),
    }
