"""whisper-small — encoder-decoder ASR backbone [arXiv:2212.04356].

The conv audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, frames, d_model).  Positions are sinusoidal
on both sides (whisper uses sinusoidal encoder / learned decoder positions;
we use sinusoidal everywhere so parameter shapes are independent of the
assigned synthetic sequence lengths — noted in DESIGN.md)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    encoder_layers=12,
    enc_frames=1500,
    act="gelu",
)
