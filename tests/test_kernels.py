"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def blocks(b, n, seed=0, scale=50.0):
    rng = np.random.default_rng(seed)
    # smooth-ish blocks: random low-order polynomial + small noise
    g = np.mgrid[0:n, 0:n, 0:n].astype(np.float32) / n
    out = np.empty((b, n, n, n), np.float32)
    for i in range(b):
        c = rng.standard_normal(9).astype(np.float32)
        out[i] = scale * (
            c[0] + c[1] * g[0] + c[2] * g[1] + c[3] * g[2]
            + c[4] * g[0] * g[1] + c[5] * g[1] * g[2]
            + c[6] * g[0] ** 2 + c[7] * g[1] ** 2 + c[8] * g[2] ** 2
        ) + rng.standard_normal((n, n, n)).astype(np.float32) * 0.01 * scale
    return jnp.asarray(out)


@pytest.mark.parametrize("kind", ["w4i", "w4l", "w3ai"])
@pytest.mark.parametrize("b,n", [(1, 8), (4, 16), (3, 32), (8, 32)])
def test_wavelet_kernel_matches_ref(kind, b, n):
    x = blocks(b, n, seed=n + b)
    got = ops.wavelet_forward(x, kind=kind, interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=2e-3)
    back = ops.wavelet_inverse(got, kind=kind, interpret=True)
    scale = float(np.max(np.abs(np.asarray(x))))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-5, atol=1e-4 * scale)


@pytest.mark.parametrize("eps", [1e-4, 1e-2])
@pytest.mark.parametrize("b,n", [(2, 8), (4, 16), (5, 32)])
def test_zfpx_kernel_matches_ref(eps, b, n):
    x = blocks(b, n, seed=b * n)
    e_got, q_got = ops.zfpx_encode(x, eps=eps, interpret=True)
    e_want, q_want = ref.zfpx_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(e_got), np.asarray(e_want))
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    d_got = ops.zfpx_decode(e_got, q_got, eps=eps, n=n, interpret=True)
    d_want = ref.zfpx_decode_ref(e_want, q_want, eps=eps, n=n)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("eps", [1e-3, 1e-1])
@pytest.mark.parametrize("b,n", [(2, 8), (4, 16), (3, 32), (16, 16)])
def test_lorenzo_kernel_matches_ref(eps, b, n):
    x = blocks(b, n, seed=7 * b + n)
    r_got = ops.lorenzo_encode(x, eps=eps, interpret=True)
    r_want = ref.lorenzo_encode_ref(x, eps=eps)
    np.testing.assert_array_equal(np.asarray(r_got), np.asarray(r_want))
    d_got = ops.lorenzo_decode(r_got, eps=eps, interpret=True)
    d_want = ref.lorenzo_decode_ref(r_want, eps=eps)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-6)
    assert float(jnp.max(jnp.abs(d_got - x))) <= eps * (1 + 1e-4) + 1e-5


def test_kernels_handle_non_divisible_batch():
    x = blocks(5, 16, seed=11)  # 5 % 4 != 0 -> tile fallback path
    got = ops.wavelet_forward(x, kind="w3ai", interpret=True)
    want = ref.wavelet3d_forward_ref(x, kind="w3ai")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=2e-3)


def test_wavelet_kernel_dtype_promotion():
    x = blocks(2, 16).astype(jnp.float64) if False else blocks(2, 16)
    got = ops.wavelet_forward(x.astype(jnp.bfloat16), kind="w3ai", interpret=True)
    assert got.dtype == jnp.float32  # kernels compute in f32
    assert np.isfinite(np.asarray(got)).all()
