"""Fig. 5 — byte shuffling and bit zeroing (Z4/Z8) on top of W3ai.

Expected reproductions: shuffling raises CR at identical PSNR (reversible);
bit zeroing adds CR below a PSNR knee (flatness region for Z8)."""
from __future__ import annotations

import time

from repro.core import CompressionSpec

from .common import dataset, emit, eps_sweep, save_json, sweep


def run(quick: bool = True):
    fields = dataset("10k")
    eps_list = eps_sweep(n=4 if quick else 8)
    variants = {
        "plain": dict(shuffle="none", zero_bits=0),
        "shuf": dict(shuffle="byte", zero_bits=0),
        "shuf_z4": dict(shuffle="byte", zero_bits=4),
        "shuf_z8": dict(shuffle="byte", zero_bits=8),
    }
    rows = []
    t0 = time.time()
    for q in ("p", "rho"):
        for name, kw in variants.items():
            specs = [CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=e, **kw)
                     for e in eps_list]
            for e, r in zip(eps_list, sweep(fields[q], specs)):
                rows.append({"qoi": q, "variant": name, "eps": e,
                             "cr": r["cr"], "psnr": r["psnr"]})
    dt = time.time() - t0
    save_json("fig5_shuffle_zeroing", rows)

    def cr_of(var, q="p", i=0):
        e = eps_list[i]
        return next(r["cr"] for r in rows
                    if r["variant"] == var and r["qoi"] == q and r["eps"] == e)

    gain = cr_of("shuf") / cr_of("plain")
    emit("fig5_shuffle_cr_gain", dt * 1e6 / max(len(rows), 1), f"{gain:.3f}")
    psnr_same = abs(
        next(r["psnr"] for r in rows if r["variant"] == "shuf" and r["qoi"] == "p" and r["eps"] == eps_list[0])
        - next(r["psnr"] for r in rows if r["variant"] == "plain" and r["qoi"] == "p" and r["eps"] == eps_list[0]))
    emit("fig5_shuffle_psnr_delta_db", dt * 1e6 / max(len(rows), 1), f"{psnr_same:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
