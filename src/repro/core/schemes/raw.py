"""Identity scheme: float32 blocks passed straight to shuffle + stage 2.

The control arm of the testbed — isolates what the lossless stage alone buys.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import Scheme, register_scheme, shuffle_bytes, unshuffle_bytes


@register_scheme
class RawScheme(Scheme):
    name = "raw"

    def stage1(self, blocks_np, spec):
        return {"raw": np.asarray(jnp.asarray(blocks_np, jnp.float32))}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        buf = s1["raw"][lo:hi].astype(np.float32).tobytes()
        return shuffle_bytes(buf, spec.shuffle, 4)

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        raw = np.frombuffer(unshuffle_bytes(payload, spec.shuffle, 4), np.float32)
        return raw.reshape(nblk, n, n, n).copy()
