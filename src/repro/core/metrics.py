"""Quality metrics: PSNR (paper Eq. 1) and compression ratio."""
from __future__ import annotations

import numpy as np

__all__ = ["mse", "psnr", "compression_ratio"]


def mse(ref, dec) -> float:
    r = np.asarray(ref, np.float64)
    d = np.asarray(dec, np.float64)
    return float(np.mean((r - d) ** 2))


def psnr(ref, dec) -> float:
    """PSNR per the paper's Eq. (1): 20*log10( range / (2*sqrt(MSE)) )."""
    r = np.asarray(ref, np.float64)
    rng = float(r.max() - r.min())
    m = mse(ref, dec)
    if m == 0.0:
        return float("inf")
    return 20.0 * np.log10(rng / (2.0 * np.sqrt(m)))


def compression_ratio(raw_bytes: int, compressed_bytes: int) -> float:
    return raw_bytes / max(1, compressed_bytes)
