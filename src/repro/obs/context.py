"""Request-scoped correlation: one ID per request, visible to every tier.

The serve tier's production question — *why was this one query slow?* —
needs every span, metric observation, and log line a request touched to
carry the same identifier.  This module provides that identifier as a
:mod:`contextvars` context: the HTTP front mints (or honors) an
``X-CZ-Request-Id``, enters a :class:`RequestContext`, and everything
downstream on that thread — :class:`FieldRegionServer`,
``ChunkScheduler``/``SingleFlight``, ``FieldReader``, the byte store —
sees it through :func:`request_id` without any parameter plumbing.

A :class:`RequestContext` can also *collect*: when ``collect=True`` every
span recorded while the context is active (via :func:`repro.obs.trace.span`
/ ``trace.record``) is appended to a bounded per-request event list.  That
list is what the tail sampler (:mod:`repro.obs.sampling`) keeps when a
request errors or lands in the latency tail — a complete per-request
timeline at a cost bounded by ``max_events``.

Stdlib only — importable before numpy/jax.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
import threading
import time
import uuid

__all__ = ["RequestContext", "current", "request", "request_id",
           "new_request_id", "clean_id"]

#: IDs a client may supply (anything else is replaced with a minted one):
#: URL/header/filename-safe, bounded length.
_ID_RE = re.compile(r"[A-Za-z0-9][A-Za-z0-9._-]{0,63}")

_REQUEST: contextvars.ContextVar["RequestContext | None"] = \
    contextvars.ContextVar("cz_request", default=None)


def new_request_id() -> str:
    """A fresh 16-hex-char request ID."""
    return uuid.uuid4().hex[:16]


def clean_id(value) -> str | None:
    """``value`` if it is a usable client-supplied request ID, else None.

    The HTTP front honors ``X-CZ-Request-Id`` from clients (so a caller can
    correlate its own logs with ours) but never echoes arbitrary bytes back
    into headers, traces, and event lines."""
    if isinstance(value, str) and _ID_RE.fullmatch(value):
        return value
    return None


class RequestContext:
    """One request's identity (+ optional span collection).

    ``events`` holds ``{"name", "ts_us", "dur_us", "args"}`` rows relative
    to the context's start, appended by ``repro.obs.trace`` while the
    context is active and ``collecting``; growth is capped at
    ``max_events`` (overflow counted in ``dropped``, never unbounded).
    ``finished`` is the tail sampler's once-only latch.
    """

    __slots__ = ("rid", "collecting", "max_events", "events", "dropped",
                 "started_ns", "wall_time", "finished", "_lock")

    def __init__(self, rid: str | None = None, collect: bool = False,
                 max_events: int = 512):
        self.rid = rid or new_request_id()
        self.collecting = bool(collect)
        self.max_events = int(max_events)
        self.events: list[dict] = []
        self.dropped = 0
        self.started_ns = time.perf_counter_ns()
        self.wall_time = time.time()
        self.finished = False
        self._lock = threading.Lock()

    def record(self, name: str, t0_ns: int, t1_ns: int,
               args: dict | None = None) -> None:
        """Append one complete span (perf-counter stamps) to this request's
        timeline.  No-op unless collecting; bounded by ``max_events``."""
        if not self.collecting:
            return
        ev = {"name": name,
              "ts_us": round((t0_ns - self.started_ns) / 1e3, 1),
              "dur_us": round((t1_ns - t0_ns) / 1e3, 1)}
        if args:
            ev["args"] = args
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append(ev)

    def instant(self, name: str, **args) -> None:
        """Mark a point in time on this request's timeline."""
        now = time.perf_counter_ns()
        self.record(name, now, now, args or None)

    def __repr__(self) -> str:
        return (f"RequestContext(rid={self.rid!r}, "
                f"events={len(self.events)}, collecting={self.collecting})")


def current() -> RequestContext | None:
    """The active request context, or None outside any request."""
    return _REQUEST.get()


def request_id() -> str | None:
    """The active request's ID, or None outside any request."""
    ctx = _REQUEST.get()
    return ctx.rid if ctx is not None else None


@contextlib.contextmanager
def request(rid: str | None = None, collect: bool = False,
            max_events: int = 512):
    """Enter a request scope: ``with context.request(rid) as ctx: ...``.

    Nested scopes shadow the outer one (the inner request gets its own ID
    and timeline) and restore it on exit.
    """
    ctx = RequestContext(rid, collect=collect, max_events=max_events)
    token = _REQUEST.set(ctx)
    try:
        yield ctx
    finally:
        _REQUEST.reset(token)
