"""Pallas TPU kernel: zfpx encode stage (block-float + int lifting + reorder).

Fuses the zfpx substage-1 pipeline for a VMEM-resident tile of blocks:
exponent extraction, fixed-point conversion, the ZFP integer lifting
transform along three axes, total-sequency reorder, and the eps-derived
bit-plane truncation.  Everything is elementwise / static-slice int32 work —
pure VPU, no divergent control flow (zero cells are handled by masking).

The decode kernel inverts: un-truncate (shift back), inverse reorder,
inverse lifting, dequantize.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import zfpx as _z

__all__ = ["zfpx_encode_pallas", "zfpx_decode_pallas"]

DEFAULT_TILE_BLOCKS = 4


def _encode_kernel(x_ref, perm_ref, emax_ref, q_ref, *, eps: float):
    x = x_ref[...]                                   # (tb, n, n, n) f32
    perm = perm_ref[...]
    cells = _z._to_cells(x)                          # (tb, nc, 4,4,4)
    amax = jnp.max(jnp.abs(cells), axis=(-3, -2, -1))
    _, e = jnp.frexp(amax)
    emax = jnp.where(amax > 0, e, _z._ZERO_EMAX).astype(jnp.int32)
    scale = jnp.exp2((_z.SCALE_BITS - emax).astype(jnp.float32))
    q = jnp.round(cells * scale[..., None, None, None]).astype(jnp.int32)
    q = _z.fwd_lift_cell(q)
    q = jnp.take(q.reshape(*q.shape[:-3], 64), perm, axis=-1)
    p = _z._drop_bits(emax, eps)[..., None]
    q = jnp.where(emax[..., None] == _z._ZERO_EMAX, 0, (q >> p) << p)
    emax_ref[...] = emax
    q_ref[...] = q


def _decode_kernel(emax_ref, q_ref, invperm_ref, o_ref, *, eps: float, n: int):
    emax, q = emax_ref[...], q_ref[...]
    inv = invperm_ref[...]
    cells = jnp.take(q, inv, axis=-1).reshape(*q.shape[:-1], 4, 4, 4)
    cells = _z.inv_lift_cell(cells)
    scale = jnp.exp2((emax - _z.SCALE_BITS).astype(jnp.float32))
    out = cells.astype(jnp.float32) * scale[..., None, None, None]
    out = jnp.where((emax == _z._ZERO_EMAX)[..., None, None, None], 0.0, out)
    o_ref[...] = _z._from_cells(out, n)


def zfpx_encode_pallas(blocks, eps: float = 1e-3,
                       tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    b, n = blocks.shape[0], blocks.shape[-1]
    nc = (n // 4) ** 3
    tb = min(tile_blocks, b)
    if b % tb:
        tb = 1
    return pl.pallas_call(
        functools.partial(_encode_kernel, eps=eps),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((64,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tb, nc), lambda i: (i, 0)),
            pl.BlockSpec((tb, nc, 64), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc), jnp.int32),
            jax.ShapeDtypeStruct((b, nc, 64), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.float32), jnp.asarray(_z.sequency_perm()))


def zfpx_decode_pallas(emax, q, eps: float = 1e-3, n: int = 32,
                       tile_blocks: int = DEFAULT_TILE_BLOCKS, interpret: bool = True):
    b, nc = emax.shape
    tb = min(tile_blocks, b)
    if b % tb:
        tb = 1
    return pl.pallas_call(
        functools.partial(_decode_kernel, eps=eps, n=n),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, nc), lambda i: (i, 0)),
            pl.BlockSpec((tb, nc, 64), lambda i: (i, 0, 0)),
            pl.BlockSpec((64,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, n, n, n), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(emax, jnp.int32), jnp.asarray(q, jnp.int32),
      jnp.asarray(np.argsort(_z.sequency_perm()).astype(np.int32)))
