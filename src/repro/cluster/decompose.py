"""3D domain decomposition into per-rank block-aligned subdomains.

The paper's cluster tier assigns each MPI rank a block-structured subdomain
of the global grid.  Three layouts (all cuts land on block boundaries, so a
subdomain blockifies independently of its neighbours — no halo exchange):

* ``slab``   — 1D split along x (the classic I/O decomposition),
* ``pencil`` — 2D split along x and y,
* ``brick``  — 3D split along x, y and z (most surface-balanced).

:func:`dims_for` balances the rank grid like ``MPI_Dims_create``;
:func:`scatter`/:func:`gather` move a parent-held field to/from subdomain
parts (the multiprocessing stand-in for a distributed allocation).

:func:`chunk_spans` is the second, 1-D decomposition the shared-file engine
uses: the serial chunk stream (one chunk per aggregation buffer, in global
block-raster order) is split into contiguous per-rank spans.  Rank cuts land
on *chunk* boundaries, which is what makes the parallel single-file assembly
bit-identical to the serial writer for any rank count.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.blocks import num_blocks

__all__ = ["Subdomain", "LAYOUTS", "dims_for", "decompose", "scatter",
           "gather", "chunk_spans"]

LAYOUTS = ("slab", "pencil", "brick")


@dataclasses.dataclass(frozen=True)
class Subdomain:
    """One rank's half-open box ``[lo, hi)`` of the global grid."""

    rank: int
    lo: tuple[int, int, int]
    hi: tuple[int, int, int]

    @property
    def shape(self) -> tuple[int, int, int]:
        return tuple(b - a for a, b in zip(self.lo, self.hi))

    @property
    def slices(self) -> tuple[slice, slice, slice]:
        return tuple(slice(a, b) for a, b in zip(self.lo, self.hi))

    @property
    def nvoxels(self) -> int:
        return int(np.prod(self.shape))

    def nblocks(self, block_size: int) -> int:
        return int(np.prod(num_blocks(self.shape, block_size)))


def _prime_factors_desc(n: int) -> list[int]:
    out, p = [], 2
    while p * p <= n:
        while n % p == 0:
            out.append(p)
            n //= p
        p += 1
    if n > 1:
        out.append(n)
    return sorted(out, reverse=True)


def dims_for(ranks: int, ndims: int) -> tuple[int, ...]:
    """Balanced rank-grid factorization (``MPI_Dims_create`` analogue).

    Greedily assigns prime factors (largest first) to the currently smallest
    dimension; returns dims sorted descending so the x axis gets the most
    parts.  ``dims_for(12, 3) == (3, 2, 2)``.
    """
    if ranks < 1 or ndims < 1:
        raise ValueError(f"need ranks >= 1 and ndims >= 1, got {ranks}, {ndims}")
    dims = [1] * ndims
    for p in _prime_factors_desc(ranks):
        dims[int(np.argmin(dims))] *= p
    return tuple(sorted(dims, reverse=True))


def _splits(n: int, parts: int) -> list[int]:
    """parts+1 monotone boundaries dividing ``n`` units as evenly as possible."""
    return [i * n // parts for i in range(parts + 1)]


def decompose(shape: tuple[int, int, int], ranks: int, block_size: int,
              layout: str = "slab") -> list[Subdomain]:
    """Split ``shape`` into ``ranks`` block-aligned subdomains.

    Subdomains are disjoint, cover the grid exactly, and are ordered by rank
    in C order over the rank grid.  Raises if an axis has fewer block layers
    than the layout wants parts (use a flatter layout or fewer ranks).
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; one of {LAYOUTS}")
    nb = num_blocks(tuple(shape), block_size)
    nd = {"slab": 1, "pencil": 2, "brick": 3}[layout]
    # match the biggest rank-grid factor to the axis with the most block
    # layers (among the layout's split axes) so short leading axes don't
    # spuriously reject feasible rank counts
    order = sorted(range(nd), key=lambda a: -nb[a])
    dims = [1, 1, 1]
    for ax, d in zip(order, dims_for(ranks, nd)):
        dims[ax] = d
    for d, n, ax in zip(dims, nb, "xyz"):
        if d > n:
            raise ValueError(
                f"layout {layout!r} cuts axis {ax} into {d} parts but it has "
                f"only {n} blocks of side {block_size}")
    cuts = [[b * block_size for b in _splits(n, d)] for n, d in zip(nb, dims)]
    subs, rank = [], 0
    for i in range(dims[0]):
        for j in range(dims[1]):
            for k in range(dims[2]):
                subs.append(Subdomain(
                    rank,
                    (cuts[0][i], cuts[1][j], cuts[2][k]),
                    (cuts[0][i + 1], cuts[1][j + 1], cuts[2][k + 1])))
                rank += 1
    return subs


def scatter(field: np.ndarray, subs: list[Subdomain]) -> list[np.ndarray]:
    """Extract each rank's contiguous subdomain part from a global field."""
    field = np.asarray(field)
    return [np.ascontiguousarray(field[s.slices]) for s in subs]


def gather(parts: list[np.ndarray], subs: list[Subdomain],
           shape: tuple[int, int, int] | None = None) -> np.ndarray:
    """Reassemble subdomain parts into the global field (inverse of scatter)."""
    if len(parts) != len(subs):
        raise ValueError(f"{len(parts)} parts for {len(subs)} subdomains")
    if shape is None:
        shape = tuple(max(s.hi[a] for s in subs) for a in range(3))
    out = np.empty(shape, np.asarray(parts[0]).dtype)
    for part, s in zip(parts, subs):
        part = np.asarray(part)
        if part.shape != s.shape:
            raise ValueError(
                f"rank {s.rank} part has shape {part.shape}, subdomain {s.shape}")
        out[s.slices] = part
    return out


def chunk_spans(nchunks: int, ranks: int) -> list[tuple[int, int]]:
    """Contiguous per-rank spans ``[lo, hi)`` over the serial chunk stream.

    Balanced to within one chunk; spans may be empty when ``ranks > nchunks``
    (those ranks simply contribute zero bytes to the shared file).
    """
    bounds = _splits(nchunks, max(1, int(ranks)))
    return list(zip(bounds, bounds[1:]))
