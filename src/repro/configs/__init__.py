"""Config registry: one module per assigned architecture (+ paper testbed)."""
from . import (
    chameleon_34b,
    granite_8b,
    jamba_v0_1_52b,
    llama4_scout_17b_a16e,
    olmoe_1b_7b,
    qwen25_32b,
    qwen3_32b,
    rwkv6_7b,
    smollm_135m,
    whisper_small,
)
from .base import SHAPES, ArchConfig, ShapeConfig, cell_applicable, reduced  # noqa: F401

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        chameleon_34b,
        llama4_scout_17b_a16e,
        olmoe_1b_7b,
        qwen25_32b,
        qwen3_32b,
        smollm_135m,
        granite_8b,
        rwkv6_7b,
        jamba_v0_1_52b,
        whisper_small,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
