"""Exclusive prefix-sum offsets for parallel writers (MPI_Exscan analogue).

The paper's cluster layer computes each rank's byte offset into the shared
per-quantity output file as an exclusive scan over the compressed buffer
sizes.  ``exclusive_offsets_np`` is the single-process reference;
``exclusive_offsets_sharded`` runs the same collective under ``shard_map``
(per-shard local cumsum + all-gathered base from preceding shards), which is
exactly the two-phase Exscan a multi-host fleet would execute.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["exclusive_offsets_np", "exclusive_offsets_sharded"]


def exclusive_offsets_np(sizes) -> np.ndarray:
    """offsets[i] = sum(sizes[:i]); offsets[0] = 0."""
    s = np.asarray(sizes, np.int64)
    out = np.zeros_like(s)
    if s.size > 1:
        np.cumsum(s[:-1], out=out[1:])
    return out


def exclusive_offsets_sharded(sizes, mesh, axis_name: str):
    """Exclusive scan of ``sizes`` sharded along ``axis_name`` of ``mesh``.

    Each shard computes its local exclusive cumsum and adds the total of all
    preceding shards (one all-gather of per-shard totals — O(devices) bytes).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _exscan(local):
        totals = jax.lax.all_gather(jnp.sum(local), axis_name)
        idx = jax.lax.axis_index(axis_name)
        base = jnp.sum(jnp.where(jnp.arange(totals.shape[0]) < idx, totals, 0))
        return jnp.cumsum(local) - local + base

    fn = shard_map(_exscan, mesh=mesh,
                   in_specs=P(axis_name), out_specs=P(axis_name))
    return fn(jnp.asarray(sizes))
