"""Serving, both kinds: (1) region queries against a compressed CZDataset
over HTTP (RegionHTTPServer + Client — the `cz-compress serve` stack on an
ephemeral loopback port), (2) batched LLM prefill + greedy decode with a KV
cache.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import tempfile

import numpy as np

from repro.core import CompressionSpec
from repro.fields import CloudConfig, cavitation_fields
from repro.serve import Client, RegionHTTPServer
from repro.store import CZDataset

# -- 1. compressed-field region serving over HTTP ----------------------------
root = os.path.join(tempfile.mkdtemp(), "ds")
with CZDataset(root, "a", spec=CompressionSpec(scheme="wavelet", eps=1e-3,
                                               block_size=16),
               workers=4) as ds:
    fields = cavitation_fields(CloudConfig(n=64), t=9.4)
    t = ds.append({"p": fields["p"], "rho": fields["rho"]}, time=9.4)

# port=0 binds an ephemeral loopback port; a real deployment runs
#   cz-compress serve DATASET --port 8423 --cache-mb 64 --workers 8
with RegionHTTPServer(root, port=0, cache_bytes=16 << 20).start() as srv:
    print(f"serving {root} at {srv.url}")
    client = Client(srv.url)
    print(f"manifest: {sorted(client.manifest()['quantities'])}")

    rng = np.random.default_rng(0)
    for _ in range(32):  # random 16^3 probes; hot regions cost zero decode
        lo = rng.integers(0, 48, 3)
        box = client.region("p", t, lo, lo + 16)
    print(f"last box: shape {box.shape} dtype {box.dtype} "
          f"mean {box.mean():.4f}")
    for line in client.metrics().splitlines():
        if line.startswith(("cz_serve_queries_total",
                            "cz_serve_region_cache_hits_total",
                            "cz_serve_chunks_decoded_total",
                            "cz_serve_bytes_served_total")):
            print(f"  {line}")
    client.close()

# -- 2. LLM decode serving ---------------------------------------------------
from repro.launch.serve import main

main(["--arch", "smollm-135m", "--reduced", "--batch", "4",
      "--prompt-len", "8", "--max-new", "16"])
