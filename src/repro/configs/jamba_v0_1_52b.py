"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2 every other
layer [arXiv:2403.19887]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_period=2,
    ssm_kind="mamba",
    attn_period=8,       # 1 attention layer per 8 (1:7 mamba:attn interleave)
    d_state=16,
    notes="decode: O(1) mamba state + KV cache on 4 attn layers -> long_500k runs",
)
