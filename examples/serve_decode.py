"""Batched serving: prefill + greedy decode with a KV cache.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

main(["--arch", "smollm-135m", "--reduced", "--batch", "4",
      "--prompt-len", "8", "--max-new", "16"])
