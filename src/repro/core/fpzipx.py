"""``fpzipx`` — FPZIP-style predictive float compression (lossless / precision).

FPZIP (Lindstrom & Isenburg 2006) maps floats to a monotone integer code,
predicts with the Lorenzo predictor and range-codes the residuals.  Our TPU
adaptation keeps the exact integer pipeline:

1. total-order map of fp32 bit patterns onto uint32 (monotone in the float
   ordering, including negatives);
2. optional precision truncation — keep ``precision`` most significant bits
   (FPZIP's lossy "bits of precision" knob; 32 = bit-exact lossless);
3. wrapping uint32 3D Lorenzo difference (block-local);
4. host stage 2: byte shuffle + ZLIB (replaces the serial range coder).

Decode inverts each step; the lossless path is bit-exact (tested).
Used by the checkpoint subsystem for restart snapshots (the paper reports
2.6-4.3x lossless FPZIP ratios for restart files).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .szx import lorenzo_fwd, lorenzo_inv

__all__ = ["encode", "decode", "float_to_ordered", "ordered_to_float"]


def float_to_ordered(x):
    """Monotone map fp32 -> uint32 (sign-aware total order)."""
    i = jnp.asarray(x, jnp.float32).view(jnp.int32)
    u = i.view(jnp.uint32)
    return jnp.where(i >= 0, u ^ jnp.uint32(0x80000000), ~u)


def ordered_to_float(u):
    i = jnp.where(
        u >= jnp.uint32(0x80000000), u ^ jnp.uint32(0x80000000), ~u
    ).view(jnp.int32)
    return i.view(jnp.float32)


def _truncate(u, precision: int):
    if precision >= 32:
        return u
    drop = 32 - precision
    return (u >> drop) << drop


@functools.partial(jax.jit, static_argnames=("precision",))
def encode(blocks, precision: int = 32):
    """blocks (B, n, n, n) f32 -> uint32 Lorenzo deltas (wrapping)."""
    u = float_to_ordered(blocks)
    u = _truncate(u, precision)
    return lorenzo_fwd(u.view(jnp.int32)).view(jnp.uint32)


@functools.partial(jax.jit, static_argnames=())
def decode(deltas):
    u = lorenzo_inv(deltas.view(jnp.int32)).view(jnp.uint32)
    return ordered_to_float(u)
