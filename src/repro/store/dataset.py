"""CZDataset: per-quantity/per-timestep CZ2 members over a byte store.

See :mod:`repro.store` for the store layout.  One object serves both ends
of the paper's workflow:

* **append mode** — an in-situ simulation opens the dataset once and calls
  :meth:`CZDataset.append` as snapshots are produced; every commit writes the
  member objects first and then atomically replaces the manifest, so readers
  never observe a half-written timestep.
* **random access** — :meth:`CZDataset.read_box` decodes only the chunks
  covering the requested sub-box through a pool of cached
  :class:`~repro.core.container.FieldReader` objects (each with its own LRU
  chunk cache); chunks are fetched from the store as byte ranges, and the
  full field is never inflated for a region query.

The backing store is pluggable (:mod:`repro.store.backends`): ``root`` is a
local path (the historical form), a store URL (``file://``, ``mem://``,
``range://``, anything registered), or a :class:`~repro.store.backends.Store`
instance.
"""
from __future__ import annotations

import collections
import threading

import numpy as np

from repro.core import container, metrics
from repro.core.container import FieldReader
from repro.core.pipeline import CompressionSpec

from .backends import Store, open_store
from .manifest import (
    MANIFEST_NAME,
    QUANTITY_RE,
    RANK_MANIFEST_RE,
    ManifestError,
    list_rank_manifests,
    new_manifest,
    read_manifest,
    read_rank_manifest,
    write_manifest,
)
from .writer import ShardWriter

__all__ = ["CZDataset"]

_QUANTITY_RE = QUANTITY_RE  # back-compat alias


def _member_stats(field: np.ndarray, dec: np.ndarray) -> dict:
    """Per-member quality record (PSNR is None when the member is lossless —
    JSON has no Infinity)."""
    p = metrics.psnr(field, dec)
    err = float(np.max(np.abs(np.asarray(field, np.float64)
                              - np.asarray(dec, np.float64))))
    return {"psnr": float(p) if np.isfinite(p) else None, "max_err": err}


class CZDataset:
    """Sharded multi-quantity dataset store over CZ2 member objects.

    Parameters
    ----------
    root:
        Dataset location: a local directory path, a store URL
        (``file:///data/run42``, ``mem://scratch``, ``range://sim``), or a
        :class:`~repro.store.backends.Store` instance.
    mode:
        ``"r"`` (read-only, manifest must exist) or ``"a"`` (append; the
        dataset is created on first use if ``root`` holds no manifest).
    spec:
        Dataset-default :class:`CompressionSpec` for newly created datasets
        (ignored when opening an existing one — the committed spec wins).
        The dtype tag is re-derived per quantity from the appended array.
    workers:
        Encode threads shared by all member writes of this dataset
        (``1`` = serial; output is byte-identical either way).
    stats:
        Record per-member quality stats (PSNR / max error vs. the appended
        field, via :mod:`repro.core.metrics`) in each committed timestep —
        the paper's testbed-of-comparison readout, shown by
        ``cz-compress inspect --stats``.  Costs one decode per append.
    """

    def __init__(self, root, mode: str = "r",
                 spec: CompressionSpec | None = None, workers: int = 1,
                 cache_readers: int = 8, cache_chunks: int = 8,
                 stats: bool = False, prefetch: int = 0):
        if mode not in ("r", "a"):
            raise ValueError(f"mode must be 'r' or 'a', got {mode!r}")
        self.store = open_store(root)
        self.root = (self.store.url if isinstance(root, Store) else str(root))
        self.mode = mode
        self._stats = bool(stats)
        self._lock = threading.RLock()
        self._cache_readers = cache_readers
        self._cache_chunks = cache_chunks
        #: chunks each reader fetches ahead during read_box (0 = off);
        #: worth turning on for remote (http://, latency-bearing) stores
        self._prefetch = max(0, int(prefetch))
        self._readers: collections.OrderedDict[tuple[str, int], FieldReader] = \
            collections.OrderedDict()
        self._retired_decoded = 0
        self._retired_hits = 0

        try:
            self._m = read_manifest(self.store)
        except ManifestError:
            if mode != "a" or self.store.exists(MANIFEST_NAME):
                raise  # corrupt, or missing in read-only mode: surface it
            self._m = new_manifest((spec or CompressionSpec()).validate().to_json())
            write_manifest(self.store, self._m)
        self.spec = CompressionSpec.from_json(self._m["spec"])
        self._writer = (ShardWriter(self.spec, workers=workers)
                        if mode == "a" else None)

    # -- introspection -----------------------------------------------------

    @property
    def quantities(self) -> list[str]:
        return sorted(self._m["quantities"])

    def timesteps(self, quantity: str) -> list[int]:
        """Committed timestep indices for one quantity, in append order."""
        return [ts["t"] for ts in self._entry(quantity)["timesteps"]]

    def timestep_info(self, quantity: str, t: int | None = None):
        """Committed timestep record(s) — ``{"t", "time", "file", "bytes",
        "raw_bytes"}`` dicts (copies).  ``t=None`` returns the full list."""
        if t is None:
            return [dict(ts) for ts in self._entry(quantity)["timesteps"]]
        return dict(self._timestep(quantity, int(t)))

    def shape(self, quantity: str) -> tuple[int, int, int]:
        return tuple(self._entry(quantity)["shape"])

    def dtype(self, quantity: str) -> np.dtype:
        return np.dtype(self._entry(quantity)["dtype"])

    @property
    def version(self) -> int:
        return int(self._m["version"])

    def _entry(self, quantity: str) -> dict:
        try:
            return self._m["quantities"][quantity]
        except KeyError:
            raise KeyError(
                f"quantity {quantity!r} not in dataset "
                f"(has: {', '.join(self.quantities) or 'none'})") from None

    def _timestep(self, quantity: str, t: int) -> dict:
        for ts in self._entry(quantity)["timesteps"]:
            if ts["t"] == t:
                return ts
        raise KeyError(f"quantity {quantity!r} has no timestep {t} "
                       f"(has: {self.timesteps(quantity)})")

    def describe(self) -> dict:
        """Machine-readable dataset summary: spec, version, and the full
        per-quantity timestep tables, as one JSON-able dict (deep copy).

        The single serializer behind both ``cz-compress inspect --json`` and
        the HTTP service's ``/v1/manifest`` — external tooling sees one
        schema however it asks.
        """
        with self._lock:
            return {
                "store": "CZDS",
                "format": int(self._m["format"]),
                "version": int(self._m["version"]),
                "spec": dict(self._m["spec"]),
                "quantities": {
                    q: {"shape": list(ent["shape"]),
                        "dtype": str(ent["dtype"]),
                        "timesteps": [dict(ts) for ts in ent["timesteps"]]}
                    for q, ent in self._m["quantities"].items()
                },
            }

    def refresh(self) -> None:
        """Re-read the manifest (pick up commits by a concurrent appender)."""
        with self._lock:
            self._m = read_manifest(self.store)

    # -- append mode -------------------------------------------------------

    def append(self, fields: dict[str, np.ndarray],
               time: float | None = None) -> int:
        """Commit one timestep of one or more quantities; returns its index.

        Member objects are written first (concurrently chunk-encoded through
        the shared pool), then the manifest is replaced atomically — a crash
        mid-append leaves at most orphaned member objects, never a timestep
        that is half-visible.
        """
        if self._writer is None:
            raise IOError("dataset opened read-only; reopen with mode='a'")
        if not fields:
            raise ValueError("append needs at least one quantity")
        with self._lock:
            # re-read before patching: merge_manifests (rank sidecars) may
            # have committed entries since this handle last saw the manifest
            # — a stale in-memory copy would clobber them and reuse their
            # timestep indices.  (Appending *concurrently* with a merge from
            # another process remains a documented single-coordinator
            # assumption; rank-parallel writers go through RankWriter.)
            self._m = read_manifest(self.store)
            t = int(self._m["next_t"])
            staged = []
            for q, field in fields.items():
                if not _QUANTITY_RE.match(q):
                    raise ValueError(f"invalid quantity name {q!r}")
                field = np.asarray(field)
                ent = self._m["quantities"].get(q)
                if ent is not None and tuple(ent["shape"]) != field.shape:
                    raise ValueError(
                        f"quantity {q!r} has shape {tuple(ent['shape'])}, "
                        f"append got {field.shape}")
                member_spec = self._writer.spec_for(field)
                if ent is not None and \
                        str(ent["dtype"]) != str(member_spec.np_dtype):
                    raise ValueError(
                        f"quantity {q!r} is {ent['dtype']}, append got "
                        f"{member_spec.np_dtype} — the quantity-level dtype "
                        "tag is fixed at first append")
                rel = f"{q}/t{t:06d}.cz"
                nbytes = self._writer.write(
                    rel, field, spec=member_spec,
                    extra_header={"quantity": q, "t": t, "time": time},
                    store=self.store)
                rec = {"t": t, "time": time, "file": rel, "bytes": int(nbytes),
                       "raw_bytes": int(field.nbytes)}
                if member_spec.scheme == "auto":
                    # surface the chunk-scheme mix in the manifest (and so in
                    # /v1/manifest + inspect --stats) without a decode pass
                    mix = container.describe(
                        rel, verify=False, store=self.store).get("schemes")
                    if mix:
                        rec["schemes"] = mix
                if self._stats:
                    rec.update(_member_stats(
                        field, container.read_field(rel, store=self.store)))
                staged.append((q, field, member_spec, rec))
            # all members stored -> patch the manifest in one atomic commit
            for q, field, member_spec, rec in staged:
                ent = self._m["quantities"].get(q)
                if ent is None:
                    ent = self._m["quantities"][q] = {
                        "shape": list(field.shape),
                        "dtype": str(member_spec.np_dtype),
                        "timesteps": [],
                    }
                ent["timesteps"].append(rec)
            self._m["next_t"] = t + 1
            self._m["version"] = int(self._m["version"]) + 1
            write_manifest(self.store, self._m)
            return t

    # -- random access -----------------------------------------------------

    def reader(self, quantity: str, t: int) -> FieldReader:
        """Cached (LRU) FieldReader for one member — the decode cache shared
        by every region query against that quantity/timestep.

        Eviction folds the reader's counters into the dataset totals and
        drops the reference; it does *not* close the reader (store-backed
        readers hold no OS resources), so an evicted reader a caller still
        holds keeps serving from its own cache.
        """
        key = (quantity, int(t))
        with self._lock:
            r = self._readers.get(key)
            if r is not None:
                self._readers.move_to_end(key)
                return r
            ts = self._timestep(quantity, int(t))
            r = FieldReader(ts["file"], cache_chunks=self._cache_chunks,
                            store=self.store, prefetch=self._prefetch)
            self._readers[key] = r
            while len(self._readers) > self._cache_readers:
                _, old = self._readers.popitem(last=False)
                self._retired_decoded += old.chunks_decoded
                self._retired_hits += old.cache_hits
            return r

    def read_box(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        """Decode the sub-box ``[lo, hi)`` of one quantity at one timestep,
        touching only the chunks that cover it."""
        return self.reader(quantity, t).read_box(lo, hi)

    def read_field(self, quantity: str, t: int) -> np.ndarray:
        """Decode one full field (through the same chunk cache)."""
        return self.reader(quantity, t).read_all()

    def stats(self) -> dict:
        """Aggregate decode-cache counters across member readers (retired
        readers' counts are folded in at eviction/close, so totals are
        monotonic).  ``chunks_decoded == cache_misses`` by construction —
        a FieldReader inflates a chunk exactly when its LRU misses — but
        both names are exposed so cache consumers (``/metrics``,
        ``bench_serve``) can report true hit rates without knowing that."""
        with self._lock:
            live = list(self._readers.values())
            decoded = self._retired_decoded + sum(r.chunks_decoded for r in live)
            hits = self._retired_hits + sum(r.cache_hits for r in live)
            return {
                "open_readers": len(live),
                "chunks_decoded": decoded,
                "cache_hits": hits,
                "cache_misses": decoded,
                "cache_hit_rate": hits / (hits + decoded) if hits + decoded else None,
            }

    # -- retention ---------------------------------------------------------

    def gc(self, dry_run: bool = False) -> list[str]:
        """Delete orphaned objects: members in the store but absent from the
        manifest (a torn append or an aborted rank merge) and stale
        ``.tmp``/``.part`` leftovers.  Returns the orphans' keys, sorted.

        Orphans are enumerated through ``Store.list`` — the same sweep on
        every backend.  Members referenced by an unmerged rank sidecar
        (``manifest.rank{r}.json``) are *live* — they are committed data
        awaiting :func:`repro.cluster.multiwriter.merge_manifests` — and are
        never collected.  Run gc quiesced (no concurrent appenders).
        ``dry_run=True`` only lists; actual deletion needs ``mode='a'``.
        """
        with self._lock:
            self._m = read_manifest(self.store)
            live = {ts["file"]
                    for ent in self._m["quantities"].values()
                    for ts in ent["timesteps"]}
            for rank in list_rank_manifests(self.store):
                side = read_rank_manifest(self.store, rank)
                live |= {e["file"] for e in side["entries"]}
            orphans = []
            for key in self.store.list(""):
                if key == MANIFEST_NAME or RANK_MANIFEST_RE.match(key):
                    continue
                if key.endswith((".tmp", ".part")):
                    orphans.append(key)
                elif key.endswith(".cz") and key not in live:
                    orphans.append(key)
            orphans.sort()
            if dry_run or not orphans:
                return orphans
            if self.mode != "a":
                raise IOError("dataset opened read-only; gc deletion needs "
                              "mode='a' (or use dry_run=True)")
            for key in orphans:
                self.store.delete(key)  # FileStore prunes emptied quantity dirs
            return orphans

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        with self._lock:
            for r in self._readers.values():
                self._retired_decoded += r.chunks_decoded
                self._retired_hits += r.cache_hits
                r.close()
            self._readers.clear()
            if self._writer is not None:
                self._writer.close()
            # backends holding OS resources (HttpStore's keep-alive pool)
            # expose close(); local dict/dir backends don't need one
            store_close = getattr(self.store, "close", None)
            if callable(store_close):
                store_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __repr__(self) -> str:
        qs = {q: len(self._m["quantities"][q]["timesteps"])
              for q in self.quantities}
        return (f"CZDataset({self.root!r}, mode={self.mode!r}, "
                f"quantities={qs}, version={self.version})")
