"""Stage-2 lossless coders (host side — the I/O boundary, as in CubismZ).

ZLIB at its default level is the paper's production choice; LZMA trades speed
for ~14% CR; BZ2 stands in for the heavier entropy coders.  ``spdp`` is a
light SPDP-style pipeline (byte shuffle + byte-delta + zlib) used for the
Table 2 comparison of coefficient compressors.
"""
from __future__ import annotations

import bz2
import lzma
import zlib

import numpy as np

__all__ = ["METHODS", "encode", "decode"]


def _spdp_encode(buf: bytes) -> bytes:
    a = np.frombuffer(buf, np.uint8).astype(np.int16)
    d = np.diff(a, prepend=np.int16(0)).astype(np.int8).tobytes()
    return zlib.compress(d, 6)


def _spdp_decode(buf: bytes) -> bytes:
    d = np.frombuffer(zlib.decompress(buf), np.int8).astype(np.int16)
    return (np.cumsum(d, dtype=np.int16) & 0xFF).astype(np.uint8).tobytes()


METHODS = {
    "none": (lambda b: b, lambda b: b),
    "zlib": (lambda b: zlib.compress(b, 6), zlib.decompress),
    "zlib1": (lambda b: zlib.compress(b, 1), zlib.decompress),
    "zlib9": (lambda b: zlib.compress(b, 9), zlib.decompress),
    "lzma": (
        lambda b: lzma.compress(b, preset=6),
        lzma.decompress,
    ),
    "lzma9": (
        lambda b: lzma.compress(b, preset=9),
        lzma.decompress,
    ),
    "bz2": (lambda b: bz2.compress(b, 9), bz2.decompress),
    "spdp": (_spdp_encode, _spdp_decode),
}


def encode(buf: bytes, method: str = "zlib") -> bytes:
    return METHODS[method][0](buf)


def decode(buf: bytes, method: str = "zlib") -> bytes:
    return METHODS[method][1](buf)
