"""Storage-backend benchmark: append + region-read cost per backend.

Same dataset, same spec, three stores — what does the byte-store layer
cost, and what does the read path ask of an object store?

* **append** — timesteps/s through FileStore (streaming file writer),
  MemoryStore (buffered put), and RangeStore (whole-object put);
* **read_box cold** — per-query latency with an empty chunk cache (every
  query pays ranged gets + decode);
* **read_box warm** — the same queries again through a warm cache (the
  backend drops out entirely — this row should be backend-independent);
* **amplification** — RangeStore's request counters over the cold pass:
  bytes fetched vs bytes stored, and requests per query.  This is the
  honesty check that region reads stay byte-ranged on S3-style backends.
* **http** — the same cold/warm queries through ``HttpStore`` against a
  loopback :class:`StaticFileServer` over the file backend's directory,
  with its own amplification readout.  The requests-per-query figure is
  hard-asserted equal to the range row: going remote must not change what
  the read path asks of the store.
* **prefetch** — the cold pass on a latency-injected RangeStore with
  ``prefetch`` off vs on.  Request and byte counts are hard-asserted
  identical (prefetch reorders fetches, it must never add any); the
  wall-clock speedup is emitted but not asserted (CI machines jitter).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import CompressionSpec
from repro.store import CZDataset, FileStore, MemoryStore, RangeStore
from repro.store.backends import HttpStore, StaticFileServer

from .common import dataset, emit, save_json


class _SlowRangeStore(RangeStore):
    """RangeStore with injected per-get latency — a stand-in for a remote
    object store, so prefetch has real round-trips to overlap."""

    def __init__(self, latency_s: float = 0.002):
        super().__init__()
        self.latency_s = latency_s

    def get(self, key, byte_range=None):
        time.sleep(self.latency_s)
        return super().get(key, byte_range)


def _queries(n: int, box: int, k: int, seed: int = 11) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n - box, (k, 3))


def run(quick: bool = True):
    steps = 2 if quick else 6
    box = 24
    n_queries = 16 if quick else 64
    qois = ["p"] if quick else ["p", "rho"]

    fields = {q: f for q, f in dataset("10k").items() if q in qois}
    n = next(iter(fields.values())).shape[0]
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                           block_size=16, buffer_bytes=1 << 18)
    lows = _queries(n, box, n_queries)

    tmp = tempfile.mkdtemp()
    backends = {
        "file": FileStore(f"{tmp}/ds"),
        "mem": MemoryStore(),
        "range": RangeStore(),
    }
    results = {"n": n, "box": box, "steps": steps, "queries": n_queries,
               "backends": {}}
    for name, store in backends.items():
        t0 = time.perf_counter()
        with CZDataset(store, "a", spec=spec, workers=4) as ds:
            for k in range(steps):
                ds.append({q: f + np.float32(k) for q, f in fields.items()},
                          time=float(k))
        append_s = time.perf_counter() - t0

        # cold: fresh handle, tiny chunk cache -> every query hits the store
        before = store.stats() if name == "range" else None
        t0 = time.perf_counter()
        with CZDataset(store, cache_chunks=4) as ds:
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            cold_s = time.perf_counter() - t0
            amp = None
            if before is not None:
                after = store.stats()
                amp = {
                    "range_requests": after["range_requests"] - before["range_requests"],
                    "bytes_fetched": after["bytes_fetched"] - before["bytes_fetched"],
                    "bytes_stored": after["bytes_stored"],
                }
            # warm: same handle, same queries -> served from the chunk LRU
            ds.read_box(qois[0], 0, lows[0], lows[0] + box)  # prime
            t0 = time.perf_counter()
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            warm_s = time.perf_counter() - t0

        row = {
            "append_s": append_s,
            "steps_per_s": steps / append_s,
            "cold_us_per_query": cold_s / n_queries * 1e6,
            "warm_us_per_query": warm_s / n_queries * 1e6,
        }
        if amp is not None:
            row["amplification"] = amp
            row["fetched_over_stored"] = amp["bytes_fetched"] / amp["bytes_stored"]
            row["requests_per_query"] = amp["range_requests"] / n_queries
        results["backends"][name] = row

        emit(f"backends_append_{name}", append_s / steps * 1e6,
             f"{steps / append_s:.2f}steps_per_s")
        emit(f"backends_cold_{name}", row["cold_us_per_query"],
             f"{n_queries}q_box{box}")
        emit(f"backends_warm_{name}", row["warm_us_per_query"],
             f"{n_queries}q_box{box}")
    amp = results["backends"]["range"]["amplification"]
    emit("backends_range_amplification",
         results["backends"]["range"]["requests_per_query"] * 1e6,
         f"fetched{amp['bytes_fetched']}_stored{amp['bytes_stored']}")

    # -- http: the file backend's directory, served over loopback ----------
    stored = sum(os.path.getsize(os.path.join(dp, f))
                 for dp, _, fs in os.walk(f"{tmp}/ds") for f in fs)
    with StaticFileServer(f"{tmp}/ds") as srv, HttpStore(srv.url) as store:
        before = store.stats()
        t0 = time.perf_counter()
        with CZDataset(store, cache_chunks=4) as ds:
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            cold_s = time.perf_counter() - t0
            after = store.stats()
            ds.read_box(qois[0], 0, lows[0], lows[0] + box)
            t0 = time.perf_counter()
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
            warm_s = time.perf_counter() - t0
    http_amp = {
        "range_requests": after["range_requests"] - before["range_requests"],
        "bytes_fetched": after["bytes_fetched"] - before["bytes_fetched"],
        "bytes_stored": stored,
    }
    http_row = {
        "cold_us_per_query": cold_s / n_queries * 1e6,
        "warm_us_per_query": warm_s / n_queries * 1e6,
        "amplification": http_amp,
        "fetched_over_stored": http_amp["bytes_fetched"] / stored,
        "requests_per_query": http_amp["range_requests"] / n_queries,
    }
    results["backends"]["http"] = http_row
    emit("backends_cold_http", http_row["cold_us_per_query"],
         f"{n_queries}q_box{box}")
    emit("backends_warm_http", http_row["warm_us_per_query"],
         f"{n_queries}q_box{box}")
    # parity check: a remote root asks exactly what an object store does
    assert http_row["requests_per_query"] == \
        results["backends"]["range"]["requests_per_query"], \
        f"http amplification drifted from range: {http_row} vs " \
        f"{results['backends']['range']}"

    # -- prefetch: overlap round-trips on a latency-injected store ---------
    prefetch_rows = {}
    for depth in (0, 4):
        store = _SlowRangeStore()
        with CZDataset(store, "a", spec=spec, workers=4) as ds:
            for k in range(steps):
                ds.append({q: f + np.float32(k) for q, f in fields.items()},
                          time=float(k))
        before = store.stats()
        t0 = time.perf_counter()
        with CZDataset(store, cache_chunks=4, prefetch=depth) as ds:
            for lo in lows:
                ds.read_box(qois[0], 0, lo, lo + box)
        cold_s = time.perf_counter() - t0
        after = store.stats()
        prefetch_rows[depth] = {
            "cold_us_per_query": cold_s / n_queries * 1e6,
            "range_requests": after["range_requests"] - before["range_requests"],
            "bytes_fetched": after["bytes_fetched"] - before["bytes_fetched"],
        }
    r0, r4 = prefetch_rows[0], prefetch_rows[4]
    # hard invariant: prefetch reorders fetches but never adds any
    assert (r4["range_requests"], r4["bytes_fetched"]) == \
        (r0["range_requests"], r0["bytes_fetched"]), \
        f"prefetch changed request amplification: {prefetch_rows}"
    speedup = r0["cold_us_per_query"] / r4["cold_us_per_query"]
    results["prefetch"] = {"rows": prefetch_rows, "cold_speedup": speedup}
    emit("backends_prefetch_cold", r4["cold_us_per_query"],
         f"speedup{speedup:.2f}x_{r4['range_requests']}req")

    shutil.rmtree(tmp, ignore_errors=True)
    path = save_json("backends", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (also the default under benchmarks.run)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
