"""Synthetic cloud-cavitation QoI fields (p, rho, E, alpha2).

Parametric stand-in for the Cubism-MPCF datasets the paper compresses: a
cloud of bubbles with lognormal radii uniformly placed in a sphere inside a
cubic domain, evolved through collapse (bubbles shrink, pressure shocks are
emitted around t_c ~ 7 us) and rebound.  Field statistics are calibrated to
the paper's Table 1 (p in [49, ~1e4], rho in [16, 1000], E in [1.2e2, ~1e5],
alpha2 in [0, 1]) and the fields reproduce the paper's compression phenomena:
smooth away from interfaces, sharp discontinuities at bubble walls and shock
fronts, CR rising while bubbles shrink and dropping when shocks propagate.

All fields are band-limited (low-pass filtered background perturbations), so
fine-scale wavelet details behave like real finite-volume output rather than
white noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CloudConfig", "cavitation_fields", "QOIS", "PAPER_TIMES"]

QOIS = ("p", "rho", "E", "a2")
# Paper snapshots: 5k steps (pre-collapse) and 10k steps (post-collapse peak).
PAPER_TIMES = {"5k": 4.7, "10k": 9.4}
_T_COLLAPSE = 7.0  # us, paper: "peak of the collapse happens around t = 7 us"


@dataclasses.dataclass(frozen=True)
class CloudConfig:
    n: int = 128                # grid points per side
    n_bubbles: int = 70         # paper: 70-bubble cloud for 512^3
    cloud_radius: float = 0.35  # fraction of domain side
    r_mean: float = 0.035       # lognormal mean bubble radius (domain units)
    r_sigma: float = 0.35       # lognormal sigma
    seed: int = 1234
    gamma: float = 1.4
    p_ambient: float = 100.0
    p_min: float = 49.0
    rho_liquid: float = 1000.0
    rho_gas: float = 16.0
    sound_speed: float = 0.12   # domain units / us
    shock_amp: float = 1500.0


def _lowpass_noise(n: int, rng: np.random.Generator, cutoff: float = 0.08) -> np.ndarray:
    """Band-limited unit-variance noise via spectral truncation."""
    white = rng.standard_normal((n, n, n)).astype(np.float32)
    F = np.fft.rfftn(white)
    kx = np.fft.fftfreq(n)[:, None, None]
    ky = np.fft.fftfreq(n)[None, :, None]
    kz = np.fft.rfftfreq(n)[None, None, :]
    k = np.sqrt(kx**2 + ky**2 + kz**2)
    F *= np.exp(-((k / cutoff) ** 2))
    out = np.fft.irfftn(F, s=(n, n, n), axes=(0, 1, 2)).astype(np.float32)
    return out / (out.std() + 1e-12)


def _bubbles(cfg: CloudConfig) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    # uniform in a sphere
    u = rng.standard_normal((cfg.n_bubbles, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    rad = cfg.cloud_radius * rng.uniform(0, 1, cfg.n_bubbles) ** (1 / 3)
    centers = 0.5 + u * rad[:, None]
    radii = rng.lognormal(np.log(cfg.r_mean), cfg.r_sigma, cfg.n_bubbles)
    return centers.astype(np.float32), radii.astype(np.float32)


def _radius_at(r0: np.ndarray, dist_c: np.ndarray, t: float) -> tuple[np.ndarray, np.ndarray]:
    """Rayleigh-like collapse + rebound; outer bubbles collapse first.

    Returns (R(t), t_collapse per bubble)."""
    tc = _T_COLLAPSE * (0.75 + 0.5 * (1.0 - dist_c))  # outer (dist_c~1) earlier
    x = np.clip(1.0 - (t / tc) ** 2, 0.0, None) ** (1.0 / 3.0)
    rebound = 0.35 * np.clip((t - tc) / (0.45 * tc), 0.0, 1.0) ** 0.5
    R = r0 * np.maximum(x, rebound)
    return np.maximum(R, 0.02 * r0), tc


def cavitation_fields(cfg: CloudConfig = CloudConfig(), t: float = 4.7) -> dict[str, np.ndarray]:
    """QoI snapshot at time ``t`` (microseconds). Returns float32 (n,n,n) fields."""
    n = cfg.n
    rng = np.random.default_rng(cfg.seed + int(t * 1000))
    centers, radii = _bubbles(cfg)
    dist_c = np.linalg.norm(centers - 0.5, axis=1) / cfg.cloud_radius
    R, tc = _radius_at(radii, np.clip(dist_c, 0, 1), t)

    ax = (np.arange(n, dtype=np.float32) + 0.5) / n
    X = ax[:, None, None]
    Y = ax[None, :, None]
    Z = ax[None, None, :]
    iw = 1.5 / n  # interface width

    a2 = np.zeros((n, n, n), np.float32)
    p_gas = np.zeros((n, n, n), np.float32)
    shock = np.zeros((n, n, n), np.float32)
    cs_t = cfg.sound_speed

    for c, r0, r, tci in zip(centers, radii, R, tc):
        d = np.sqrt((X - c[0]) ** 2 + (Y - c[1]) ** 2 + (Z - c[2]) ** 2)
        chi = 0.5 * (1.0 - np.tanh((d - r) / iw))          # 1 inside bubble
        a2 = a2 + chi - a2 * chi                            # fuzzy union
        # adiabatic gas pressure rises as the bubble shrinks
        pg = (cfg.p_min * 0.5) * (r0 / r) ** (3 * (cfg.gamma - 1) * 0.35)
        p_gas += chi * pg
        # outward shock annulus after this bubble's collapse; the front fades
        # as it propagates and leaves a smooth elevated-pressure wake behind
        if t > tci:
            front = (t - tci) * cs_t
            strength = cfg.shock_amp * (r0 / cfg.r_mean) ** 1.5
            fade = np.exp(-(((t - tci) / 1.0) ** 2))
            amp = strength * fade / (1.0 + 12.0 * front)
            if amp > 1e-3:
                shock += amp * np.exp(-(((d - front) / (2.5 * iw)) ** 2)).astype(np.float32)
            wake = 0.04 * strength / (1.0 + 30.0 * (t - tci) ** 2)
            if wake > 1e-4:
                shock += wake * np.exp(-((d / (front + 0.08)) ** 2)).astype(np.float32)

    a2 = np.clip(a2, 0.0, 1.0)
    bg = _lowpass_noise(n, rng)
    p = cfg.p_ambient * (1.0 + 2e-5 * bg) - (cfg.p_ambient - cfg.p_min) * a2 + p_gas * a2 + shock
    p = np.maximum(p, cfg.p_min).astype(np.float32)

    rho = cfg.rho_liquid * (1.0 + 2e-5 * bg) * (1.0 - a2) + cfg.rho_gas * a2 * (
        1.0 + 0.5 * np.clip(shock / cfg.shock_amp, 0, 1)
    )
    rho = rho.astype(np.float32)

    # stiffened-gas-flavoured total energy + kinetic contribution near shocks
    kin = 0.5 * rho * (0.02 * cfg.sound_speed * shock / (cfg.p_ambient)) ** 2
    E = (p / (cfg.gamma - 1.0) + 0.12 * rho + kin).astype(np.float32)

    return {"p": p, "rho": rho, "E": E, "a2": a2.astype(np.float32)}
