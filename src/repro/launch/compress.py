"""Ex-situ compression tool (the paper's standalone CubismZ CLI).

Compresses 3D fields — from the cavitation generator, the Euler solver, or
a raw .npy file — into CZ containers, reports CR/PSNR per quantity, and can
decompress/verify.

Examples:
  python -m repro.launch.compress --source cavitation --t 9.4 --n 128 \
      --scheme wavelet --wavelet w3ai --eps 1e-3 --out /tmp/fields
  python -m repro.launch.compress --scheme lorenzo --device jax --out /tmp/fields
  python -m repro.launch.compress --decompress /tmp/fields/p.cz --verify-against /tmp/p.npy
  cz-compress parallel --ranks 4 --n 128 --out /tmp/fields  # rank-parallel engine
  cz-compress inspect /tmp/fields/p.cz          # header + chunk table + CRCs
  cz-compress inspect artifacts/example_dataset # CZDataset manifest summary
  cz-compress inspect --stats DATASET           # per-member CR/PSNR table
  cz-compress inspect --json DATASET            # machine-readable tables
  cz-compress gc --dry-run DATASET              # list orphaned members
  cz-compress serve DATASET --port 8423         # HTTP region-query service
  cz-compress serve http://fileserver/run42 --prefetch 4  # remote dataset root
  cz-compress parallel --ranks 4 --trace t.json # merged per-rank Chrome trace
  cz-compress stats http://127.0.0.1:8423       # pretty-print live /metrics

DATASET is a directory path or a store URL (``file:///data/run42``,
``mem://scratch``, ``http://host/ds`` — see repro.store.backends): inspect,
gc, and serve work over any registered backend; http(s):// roots are
read-only (any static file server exporting a dataset directory, e.g.
``python -m repro.store.backends.http DIR``) and get retry/backoff by
default (``--retries``/``--timeout`` on serve).  ``--trace OUT.json`` on
compress/parallel/serve collects repro.obs spans and writes a Chrome
trace-event file — open it at https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time

import numpy as np

from repro.core import DEVICES, SCHEMES, CompressionSpec, compression_ratio, psnr
from repro.core import container


@contextlib.contextmanager
def _trace_scope(out_path: str | None):
    """Collect repro.obs spans for the duration and write a Chrome trace
    file on exit (no-op when ``out_path`` is falsy)."""
    if not out_path:
        yield
        return
    from repro.obs import trace

    trace.enable()
    try:
        yield
    finally:
        trace.disable()
        print(f"trace written to {trace.save(out_path)}")


def _validated_spec(ap: argparse.ArgumentParser,
                    spec: CompressionSpec) -> CompressionSpec:
    """Validate a CLI-built spec; an unknown scheme/device/dtype/... must be
    a clear usage error (exit 2), never a silent fallback to the host path."""
    try:
        return spec.validate()
    except ValueError as e:
        ap.error(str(e))


def _add_tune_args(ap: argparse.ArgumentParser) -> None:
    """The auto-tuning knobs, shared by the serial and parallel writers."""
    ap.add_argument("--target", default="", metavar="MODE=VALUE",
                    help="quality target for --scheme auto: abs=1e-3 "
                    "(max abs error), rel=1e-4 (x value range), or psnr=80 "
                    "(dB); default abs=<--eps>")
    ap.add_argument("--tune-cache", type=int, default=0, metavar="K",
                    help="with --scheme auto: reuse tuning decisions for "
                    "chunks with matching stats, re-trialling every K-th "
                    "occurrence (0 = trial every chunk, the default)")


def _tune_extra(ap: argparse.ArgumentParser, args) -> dict:
    """Fold the tuning flags into ``spec.extra``; reject them for fixed
    schemes so a typo'd --scheme never silently drops the quality target."""
    extra = {}
    if args.target:
        extra["target"] = args.target
    if args.tune_cache:
        extra["tune_cache"] = args.tune_cache
    if extra and args.scheme != "auto":
        ap.error("--target/--tune-cache only apply to --scheme auto")
    return extra


def _is_dataset_root(path: str) -> bool:
    """Store URLs are always dataset roots; plain paths are roots iff they
    are directories (a file path is a single .cz container)."""
    return "://" in path or os.path.isdir(path)


def _local_out_dir(ap: argparse.ArgumentParser, out: str) -> str:
    """Resolve --out for the ex-situ writers, which produce real local files
    (the rank-parallel engine's processes seek into ONE shared file): plain
    paths pass through, file:// URLs resolve to their directory, any other
    store scheme is a usage error."""
    if "://" not in out:
        return out
    from repro.store.backends import FileStore, open_store

    store = open_store(out)
    if isinstance(store, FileStore):
        return store.root
    ap.error(f"--out {out!r}: the ex-situ/parallel writers emit local files "
             "(rank processes share one seekable file); use a plain path or "
             "a file:// URL")


def _inspect_container(path: str, verify: bool = True, store=None,
                       label: str | None = None) -> bool:
    """Print a CZ container's self-description; returns CRC verdict.
    ``store`` reads the container from a byte store (``path`` is then a
    store key); ``label`` overrides the printed heading."""
    d = container.describe(path, verify=verify, store=store)
    magic = container.MAGIC_V1 if d["container"] == "CZ1" else container.MAGIC
    print(f"{label or path}")
    print(f"  magic        {magic!r}  (container "
          f"{'CZ1 legacy' if d['container'] == 'CZ1' else 'CZ2'}, "
          f"chunk format {d['format']})")
    print(f"  scheme       {d['scheme']}  params {d['scheme_params']}")
    if d.get("schemes"):
        mix = "  ".join(f"{name} x{cnt}" for name, cnt in d["schemes"].items())
        print(f"  chunk mix    {mix}")
    print(f"  dtype        {d['dtype']}")
    shape = d["field_shape"] if d["field_shape"] is not None else "(block batch)"
    print(f"  field_shape  {shape}  "
          f"nblocks {d['nblocks']}  block_size {d['block_size']}")
    if d["raw_bytes"]:
        print(f"  bytes        {d['compressed_bytes']} compressed / "
              f"{d['raw_bytes']} raw "
              f"(CR {d['raw_bytes']/max(1, d['compressed_bytes']):.2f}x)")
    ok = True
    mixed = bool(d.get("schemes"))
    scheme_col = f" {'scheme':>8}" if mixed else ""
    print(f"  {'chunk':>5} {'blocks':>7} {'bytes':>10}{scheme_col}  crc32")
    for row in d["chunks"]:
        crc = row["crc32"]
        if crc is None:
            verdict = "-"
        elif not verify:
            verdict = f"{crc:08x}"
        else:
            good = row["crc_ok"]
            ok &= good
            verdict = f"{crc:08x} {'ok' if good else 'MISMATCH'}"
        col = f" {row.get('scheme', '?'):>8}" if mixed else ""
        print(f"  {row['index']:>5} {row['blocks']:>7} {row['bytes']:>10}"
              f"{col}  {verdict}")
    print(f"  CRC verify   {'ok' if ok else 'FAILED'}")
    return ok


def _inspect_dataset(root: str, verify: bool) -> bool:
    from repro.store import CZDataset

    ok = True
    with CZDataset(root) as ds:
        print(f"{root}: CZDataset v{ds.version}, spec {ds.spec.to_json()}")
        for q in ds.quantities:
            print(f"  {q}: shape {list(ds.shape(q))} dtype {ds.dtype(q)} "
                  f"timesteps {ds.timesteps(q)}")
            for ts in ds.timestep_info(q):
                ok &= _inspect_container(
                    ts["file"], verify, store=ds.store,
                    label=f"{root.rstrip('/')}/{ts['file']}")
    return ok


def _stats_table(root: str) -> int:
    """Per-member compression factor + PSNR table (the paper's testbed-of-
    comparison readout).  PSNR/max_err come from append-time stats
    (``CZDataset(..., stats=True)`` or ``RankWriter(..., stats=True)``);
    members appended without them show '-'."""
    from repro.store import CZDataset

    with CZDataset(root) as ds:
        print(f"{root}: CZDataset v{ds.version}, "
              f"scheme {ds.spec.scheme}, eps {ds.spec.eps}")
        print(f"  {'quantity':<12} {'t':>4} {'bytes':>12} {'raw':>12} "
              f"{'CR':>8} {'PSNR(dB)':>9} {'max_err':>10}")
        for q in ds.quantities:
            for ts in ds.timestep_info(q):
                cr = compression_ratio(ts["raw_bytes"], ts["bytes"])
                p = ts.get("psnr", "-")
                if p is None:
                    p = "exact"     # bit-exact member (recorded as null)
                elif isinstance(p, float):
                    p = f"{p:.2f}"
                e = ts.get("max_err", "-")
                if isinstance(e, float):
                    e = f"{e:.3e}"
                print(f"  {q:<12} {ts['t']:>4} {ts['bytes']:>12} "
                      f"{ts['raw_bytes']:>12} {cr:>8.2f} {p:>9} {e:>10}")
    return 0


def _inspect_json(path: str, verify: bool) -> int:
    """Machine-readable inspect: the same serializers the HTTP service uses
    (``CZDataset.describe`` for ``/v1/manifest``, ``container.describe`` for
    the per-member chunk tables), so external tooling and the server can't
    drift apart."""
    if _is_dataset_root(path):
        from repro.store import CZDataset

        with CZDataset(path) as ds:
            out = ds.describe()
            out["root"] = path
            out["members"] = {
                ts["file"]: container.describe(
                    ts["file"], verify=verify, store=ds.store)
                for q in ds.quantities for ts in ds.timestep_info(q)}
    else:
        out = container.describe(path, verify=verify)
    json.dump(out, sys.stdout, indent=1)
    print()
    members = out.get("members", {path: out} if "chunks" in out else {})
    bad = [m for m in members.values()
           if verify and m.get("crc_ok") is False]
    return 1 if bad else 0


def inspect_main(argv) -> int:
    ap = argparse.ArgumentParser(prog="cz-compress inspect")
    ap.add_argument("path", help="a .cz container, a CZDataset directory, or "
                    "a store URL (file://, mem://, any registered scheme)")
    ap.add_argument("--no-verify", action="store_true",
                    help="print CRCs without re-reading chunk data")
    ap.add_argument("--stats", action="store_true",
                    help="per-member CR/PSNR table for a dataset root")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output: manifest + member/chunk "
                    "tables as one JSON document on stdout")
    args = ap.parse_args(argv)
    if args.stats:
        if not _is_dataset_root(args.path):
            ap.error("--stats needs a CZDataset directory or store URL")
        return _stats_table(args.path)
    if args.json:
        return _inspect_json(args.path, not args.no_verify)
    if _is_dataset_root(args.path):
        ok = _inspect_dataset(args.path, not args.no_verify)
    else:
        ok = _inspect_container(args.path, not args.no_verify)
    return 0 if ok else 1


def gc_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="cz-compress gc",
        description="Delete orphaned dataset members (on disk but absent "
                    "from the manifest, e.g. after a torn append or an "
                    "aborted rank merge).  Members pending in rank sidecars "
                    "are never touched.")
    ap.add_argument("root", help="CZDataset directory or store URL "
                    "(file://, mem://)")
    ap.add_argument("--dry-run", action="store_true",
                    help="list orphans without deleting")
    args = ap.parse_args(argv)
    from repro.store import CZDataset, MANIFEST_NAME, open_store

    if not open_store(args.root).exists(MANIFEST_NAME):
        print(f"error: no {MANIFEST_NAME} in {args.root}", file=sys.stderr)
        return 1
    with CZDataset(args.root, "r" if args.dry_run else "a") as ds:
        orphans = ds.gc(dry_run=args.dry_run)
    verb = "would delete" if args.dry_run else "deleted"
    for rel in orphans:
        print(f"{verb} {rel}")
    if orphans:
        print(f"{len(orphans)} orphan(s) "
              f"{'found' if args.dry_run else 'deleted'}")
    else:
        print("dataset clean — no orphans")
    return 0


def parallel_main(argv) -> int:
    """Rank-parallel single-shared-file compression (repro.cluster.engine)."""
    from repro.cluster import ParallelCompressor
    from repro.fields import CloudConfig, cavitation_fields

    ap = argparse.ArgumentParser(prog="cz-compress parallel")
    ap.add_argument("--ranks", type=int, default=4,
                    help="worker processes (the MPI-rank stand-in)")
    ap.add_argument("--source", default="cavitation",
                    choices=["cavitation", "npy"])
    ap.add_argument("--npy", default="", help="input .npy for --source npy")
    ap.add_argument("--t", type=float, default=9.4)
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--qoi", default="p,rho,E,a2")
    ap.add_argument("--scheme", default="wavelet")
    ap.add_argument("--wavelet", default="w3ai")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--shuffle", default="byte")
    ap.add_argument("--zero-bits", type=int, default=0)
    ap.add_argument("--stage2", default="zlib")
    ap.add_argument("--precision", type=int, default=32)
    ap.add_argument("--device", default="host",
                    help=f"stage-1 routing, one of {DEVICES} (jax = the "
                    "jit'd Pallas kernel wrappers)")
    ap.add_argument("--buffer-bytes", type=int, default=1 << 20)
    _add_tune_args(ap)
    ap.add_argument("--out", default="artifacts/fields",
                    help="output directory (plain path or file:// URL)")
    ap.add_argument("--check-identical", action="store_true",
                    help="also write serially and verify the shared file is "
                    "bit-identical (the engine's core guarantee)")
    ap.add_argument("--trace", metavar="OUT.json",
                    help="write one merged Chrome trace (parent phases + a "
                         "track per rank) — view in Perfetto")
    args = ap.parse_args(argv)
    args.out = _local_out_dir(ap, args.out)

    spec = _validated_spec(ap, CompressionSpec(
        scheme=args.scheme, wavelet=args.wavelet, eps=args.eps,
        block_size=args.block_size, shuffle=args.shuffle,
        zero_bits=args.zero_bits, stage2=args.stage2,
        precision=args.precision, device=args.device,
        buffer_bytes=args.buffer_bytes, extra=_tune_extra(ap, args)))
    if args.source == "npy":
        fields = {"field": np.load(args.npy).astype(np.float32)}
    else:
        fields = cavitation_fields(CloudConfig(n=args.n), args.t)
        fields = {k: v for k, v in fields.items() if k in args.qoi.split(",")}
    os.makedirs(args.out, exist_ok=True)

    ok = True
    with _trace_scope(args.trace), ParallelCompressor(args.ranks) as pc:
        for name, f in fields.items():
            path = os.path.join(args.out, f"{name}.cz")
            t0 = time.time()
            nbytes = pc.compress(path, f, spec)
            dt = time.time() - t0
            dec = container.read_field(path)
            line = (f"{name:5s} ranks={args.ranks} "
                    f"CR={compression_ratio(f.nbytes, nbytes):8.2f} "
                    f"PSNR={psnr(f, dec):7.2f} dB "
                    f"{f.nbytes / 2**20 / dt:6.1f} MB/s -> {path}")
            if args.check_identical:
                ref = path + ".serial"
                container.write_field(ref, f, spec)
                with open(path, "rb") as a, open(ref, "rb") as b:
                    same = a.read() == b.read()
                os.unlink(ref)
                ok &= same
                line += f"  [{'bit-identical' if same else 'MISMATCH'}]"
            print(line)
    return 0 if ok else 1


def serve_main(argv) -> int:
    """HTTP region-query service over a CZDataset (repro.serve.http)."""
    from repro.serve.http import main as http_main

    return http_main(argv)


def _stats_fetch(source: str | None) -> str:
    """One metrics snapshot as Prometheus text, from any stats source."""
    from repro import obs

    if source is None:
        return obs.render()
    if source.startswith(("http://", "https://")):
        from urllib.request import urlopen

        with urlopen(source.rstrip("/") + "/metrics") as r:
            return r.read().decode()
    if source == "-":
        return sys.stdin.read()
    with open(source) as f:
        return f.read()


def _metrics_table(samples: dict, buckets: bool) -> str:
    width = max((len(n) for n in samples), default=10)
    lines = []
    for name, rows in samples.items():
        if not buckets and name.endswith("_bucket"):
            continue
        for lbl, val in rows:
            ls = ",".join(f"{k}={v}" for k, v in lbl.items())
            ls = f"{{{ls}}}" if ls else ""
            v = int(val) if float(val).is_integer() else round(val, 6)
            lines.append(f"{name:<{width}} {ls:<28} {v}")
    return "\n".join(lines)


def _stats_flatten(doc: dict) -> dict:
    """Normalize any saved snapshot shape into ``{(name, labelstr): value}``.

    Accepts all three JSON shapes this repo writes: ``cz-compress stats
    --json`` output, a raw :func:`repro.obs.snapshot` dump, and a bench
    record (``BENCH_*.json``, whose registry dump sits under ``"registry"``).
    Histogram samples flatten to ``name_count`` / ``name_sum`` entries.
    """
    if isinstance(doc.get("registry"), dict) and "schema" in doc:
        doc = doc["registry"]  # a BENCH_*.json record
    out: dict[tuple[str, str], float] = {}
    for name, val in doc.items():
        rows = val.get("samples") if isinstance(val, dict) else val
        if not isinstance(rows, list):
            continue
        for row in rows:
            if not isinstance(row, dict):
                continue
            lbl = row.get("labels") or {}
            key = ",".join(f"{k}={v}" for k, v in sorted(lbl.items()))
            if "value" in row:
                out[(name, key)] = float(row["value"])
            else:  # histogram sample: count + sum are the comparable scalars
                out[(f"{name}_count", key)] = float(row.get("count", 0))
                out[(f"{name}_sum", key)] = float(row.get("sum", 0.0))
    return out


def _stats_diff(path_a: str, path_b: str, as_json: bool) -> int:
    """``cz-compress stats --diff A.json B.json``: what changed between two
    snapshots (e.g. two bench records, or before/after of one serve run)."""
    with open(path_a) as f:
        a = _stats_flatten(json.load(f))
    with open(path_b) as f:
        b = _stats_flatten(json.load(f))
    rows = []
    for key in sorted(set(a) | set(b)):
        va, vb = a.get(key), b.get(key)
        delta = (vb or 0.0) - (va or 0.0)
        if delta == 0.0 and va is not None and vb is not None:
            continue  # unchanged — noise in a delta report
        rows.append({"name": key[0], "labels": key[1], "a": va, "b": vb,
                     "delta": delta})
    if as_json:
        json.dump({"a": path_a, "b": path_b, "changed": rows},
                  sys.stdout, indent=1)
        print()
        return 0
    if not rows:
        print("no differences")
        return 0
    width = max(len(r["name"]) for r in rows)

    def fmt(v):
        if v is None:
            return "-"
        return str(int(v)) if float(v).is_integer() else f"{v:.6g}"

    for r in rows:
        ls = f"{{{r['labels']}}}" if r["labels"] else ""
        sign = "+" if r["delta"] >= 0 else ""
        print(f"{r['name']:<{width}} {ls:<28} "
              f"{fmt(r['a'])} -> {fmt(r['b'])}  ({sign}{fmt(r['delta'])})")
    return 0


def stats_main(argv) -> int:
    """Pretty-print a metrics snapshot: a running serve endpoint's
    ``/metrics``, saved exposition text, or this process's registry —
    optionally live (``--watch``) or as a delta of two saved snapshots
    (``--diff``)."""
    from repro import obs

    ap = argparse.ArgumentParser(
        prog="cz-compress stats",
        description="Pretty-print a cz_* metrics snapshot.  SOURCE is an "
                    "http(s)://host:port of a running `cz-compress serve` "
                    "(its /metrics is fetched), a file of Prometheus text, "
                    "or '-' for stdin; omitted = this process's registry.")
    ap.add_argument("source", nargs="?")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable JSON instead of the table")
    ap.add_argument("--buckets", action="store_true",
                    help="include histogram bucket rows")
    ap.add_argument("--watch", type=float, metavar="SECS",
                    help="redraw the table every SECS seconds until Ctrl-C "
                         "(live view of a serve endpoint)")
    ap.add_argument("--diff", nargs=2, metavar=("A.json", "B.json"),
                    help="print the metric delta between two JSON snapshots "
                         "(stats --json output or BENCH_*.json records) "
                         "and exit")
    args = ap.parse_args(argv)

    if args.diff:
        return _stats_diff(args.diff[0], args.diff[1], args.json)

    if args.watch:
        if args.source == "-":
            ap.error("--watch cannot re-read stdin; give a URL or file")
        if args.watch <= 0:
            ap.error("--watch needs a positive interval")
        try:
            while True:
                samples = obs.parse_prometheus(_stats_fetch(args.source))
                table = _metrics_table(samples, args.buckets)
                # clear screen + home, then one coherent frame
                sys.stdout.write(
                    f"\x1b[2J\x1b[H{args.source or '(process registry)'}  "
                    f"every {args.watch:g}s  (Ctrl-C to stop)\n{table}\n")
                sys.stdout.flush()
                time.sleep(args.watch)
        except KeyboardInterrupt:
            return 0

    samples = obs.parse_prometheus(_stats_fetch(args.source))
    if args.json:
        json.dump({name: [{"labels": lbl, "value": val}
                          for lbl, val in rows]
                   for name, rows in samples.items()}, sys.stdout, indent=1)
        print()
        return 0
    print(_metrics_table(samples, args.buckets))
    return 0


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "inspect":
        raise SystemExit(inspect_main(argv[1:]))
    if argv and argv[0] == "gc":
        raise SystemExit(gc_main(argv[1:]))
    if argv and argv[0] == "parallel":
        raise SystemExit(parallel_main(argv[1:]))
    if argv and argv[0] == "serve":
        raise SystemExit(serve_main(argv[1:]))
    if argv and argv[0] == "stats":
        raise SystemExit(stats_main(argv[1:]))

    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="cavitation",
                    choices=["cavitation", "npy"])
    ap.add_argument("--npy", default="", help="input .npy for --source npy")
    ap.add_argument("--t", type=float, default=9.4, help="snapshot time (us)")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--qoi", default="p,rho,E,a2")
    ap.add_argument("--scheme", default="wavelet",
                    help=f"any registered scheme ({', '.join(sorted(SCHEMES))})")
    ap.add_argument("--list-schemes", action="store_true",
                    help="print the scheme registry and exit")
    ap.add_argument("--wavelet", default="w3ai")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--shuffle", default="byte")
    ap.add_argument("--zero-bits", type=int, default=0)
    ap.add_argument("--stage2", default="zlib")
    ap.add_argument("--precision", type=int, default=32)
    _add_tune_args(ap)
    ap.add_argument("--device", default=None,
                    help=f"stage-1 routing, one of {DEVICES} (jax = the "
                    "jit'd Pallas kernel wrappers).  With --decompress, "
                    "overrides the routing recorded in the container "
                    "(default: decode as recorded)")
    ap.add_argument("--out", default="artifacts/fields",
                    help="output directory (plain path or file:// URL)")
    ap.add_argument("--decompress", default="")
    ap.add_argument("--verify-against", default="")
    ap.add_argument("--trace", metavar="OUT.json",
                    help="collect repro.obs spans (stage1/encode/decode) and "
                         "write a Chrome trace — view in Perfetto")
    args = ap.parse_args(argv)
    args.out = _local_out_dir(ap, args.out)
    if args.device is not None and args.device not in DEVICES:
        ap.error(f"unknown device {args.device!r}; one of {DEVICES}")

    with _trace_scope(args.trace):
        return _serial_body(ap, args)


def _serial_body(ap: argparse.ArgumentParser, args) -> None:
    from repro.fields import CloudConfig, cavitation_fields

    if args.list_schemes:
        for name in sorted(SCHEMES):
            print(f"{name:10s} {type(SCHEMES[name]).__module__}")
        return

    if args.decompress:
        t0 = time.time()
        field = container.read_field(args.decompress, device=args.device)
        print(f"decompressed {field.shape} in {time.time()-t0:.2f}s")
        if args.verify_against:
            ref = np.load(args.verify_against)
            print(f"PSNR vs reference: {psnr(ref, field):.2f} dB "
                  f"maxerr {np.max(np.abs(ref-field)):.3e}")
        return

    spec = _validated_spec(ap, CompressionSpec(
        scheme=args.scheme, wavelet=args.wavelet, eps=args.eps,
        block_size=args.block_size, shuffle=args.shuffle,
        zero_bits=args.zero_bits, stage2=args.stage2,
        precision=args.precision, device=args.device or "host",
        extra=_tune_extra(ap, args)))
    os.makedirs(args.out, exist_ok=True)

    if args.source == "npy":
        fields = {"field": np.load(args.npy).astype(np.float32)}
    else:
        fields = cavitation_fields(CloudConfig(n=args.n), args.t)
        fields = {k: v for k, v in fields.items() if k in args.qoi.split(",")}

    report = {}
    for name, f in fields.items():
        t0 = time.time()
        path = os.path.join(args.out, f"{name}.cz")
        nbytes = container.write_field(path, f, spec)
        dt = time.time() - t0
        dec = container.read_field(path)
        report[name] = {
            "cr": compression_ratio(f.nbytes, nbytes),
            "psnr_db": psnr(f, dec),
            "comp_MBps": f.nbytes / 2**20 / dt,
            "bytes": nbytes,
        }
        print(f"{name:5s} CR={report[name]['cr']:8.2f} "
              f"PSNR={report[name]['psnr_db']:7.2f} dB "
              f"{report[name]['comp_MBps']:6.1f} MB/s -> {path}")
    with open(os.path.join(args.out, "report.json"), "w") as f:
        json.dump({"spec": spec.to_json(), "fields": report}, f, indent=1)


if __name__ == "__main__":
    main()
