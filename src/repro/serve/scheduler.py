"""Single-flight decode scheduling for concurrent region queries.

The sglang-style batching analog for a *decompression* server: when many
request threads need the same CZ2 chunk at the same time, exactly one of
them (the *leader*) decodes it; the rest park on a future and share the
result.  Without this, N concurrent cold requests for a hot region decode
every covering chunk up to N times — the store's per-reader LRU only
dedupes *sequential* repeats, and under eviction pressure (small
``cache_chunks``) not even those.

Flights are keyed by ``(member path, chunk index)``: the member path is
stable across the dataset's reader pool (a reader evicted and re-created
mid-flight still coalesces), and chunk granularity means two requests for
*different* boxes that merely share one chunk still split the decode work.
"""
from __future__ import annotations

import concurrent.futures
import threading
import time

import numpy as np

from repro.obs import context as _context
from repro.obs import trace

__all__ = ["SingleFlight", "ChunkScheduler"]


class _Flight:
    """One in-flight computation: the shared future plus the request
    correlation needed for coalescing-aware traces — the leader's request
    ID, and the IDs of every request that parked on this flight instead of
    doing the work itself."""

    __slots__ = ("future", "leader_rid", "followers")

    def __init__(self, leader_rid: str | None):
        self.future: concurrent.futures.Future = concurrent.futures.Future()
        self.leader_rid = leader_rid
        self.followers: list[str] = []


class SingleFlight:
    """Generic duplicate-call suppressor: concurrent :meth:`do` calls with
    the same key run ``fn`` once and all observe its result (or its
    exception).  Calls that arrive after the flight lands run ``fn`` again —
    long-term memory is the *cache's* job, not the scheduler's.

    Coalescing is request-correlated: a follower's request ID is appended
    to the flight (under the lock) and lands on the **leader's**
    ``serve.flight`` span, so a kept tail trace of the leader shows exactly
    which other requests drafted behind it; each follower's own timeline
    gets a ``serve.flight.wait`` span naming the leader it parked on."""

    def __init__(self):
        self._lock = threading.Lock()
        self._flights: dict[object, _Flight] = {}
        self.led = 0        # calls that executed fn
        self.joined = 0     # calls coalesced onto an existing flight

    def in_flight(self, key) -> bool:
        """Whether a flight for ``key`` is currently airborne — the veto the
        reader's prefetcher consults so it never issues a byte-range fetch
        another request's decode is already performing."""
        with self._lock:
            return key in self._flights

    def do(self, key, fn):
        rid = _context.request_id()
        with self._lock:
            flight = self._flights.get(key)
            leader = flight is None
            if leader:
                flight = self._flights[key] = _Flight(rid)
                self.led += 1
            else:
                self.joined += 1
                if rid is not None:
                    flight.followers.append(rid)
        if leader:
            t0 = time.perf_counter_ns()
            try:
                flight.future.set_result(fn())
            except BaseException as e:
                flight.future.set_exception(e)
            finally:
                # land the flight *after* the result is set: late arrivals
                # start a fresh flight (and hit the cache) instead of joining
                # a completed one.  Popping under the lock also freezes the
                # follower list — nobody can join a landed flight.
                with self._lock:
                    self._flights.pop(key, None)
                    followers = list(flight.followers)
                if followers:
                    trace.record("serve.flight", t0, time.perf_counter_ns(),
                                 key=str(key), followers=followers)
            return flight.future.result()
        t0 = time.perf_counter_ns()
        try:
            return flight.future.result()
        finally:
            trace.record("serve.flight.wait", t0, time.perf_counter_ns(),
                         key=str(key), leader=flight.leader_rid)


class ChunkScheduler:
    """Coalesces chunk decodes across all request threads of one dataset.

    Wraps :meth:`FieldReader.read_box` with a ``chunk_getter`` that routes
    every chunk fetch through a :class:`SingleFlight`, so each chunk is
    decoded **once per cache miss** no matter how many requests need it
    concurrently.  Chunk *caching* stays where it was — in the reader's LRU
    (and the region LRU above) — the scheduler only owns in-flight work.
    """

    def __init__(self, dataset):
        self.ds = dataset
        self._sf = SingleFlight()
        self._lock = threading.Lock()
        self.bytes_decoded = 0

    @property
    def flights_led(self) -> int:
        return self._sf.led

    @property
    def flights_joined(self) -> int:
        return self._sf.joined

    def read_box(self, quantity: str, t: int, lo, hi) -> np.ndarray:
        reader = self.ds.reader(quantity, int(t))
        # pin each covering chunk for the duration of this request: under
        # LRU pressure (small cache_chunks + concurrent cross-traffic) the
        # reader's cache alone would let one box re-decode its own chunk
        pinned: dict[int, np.ndarray] = {}

        def get(ci: int) -> np.ndarray:
            out = pinned.get(ci)
            if out is None:
                out = pinned[ci] = self._chunk(reader, ci)
            return out

        return reader.read_box(
            lo, hi, chunk_getter=get,
            prefetch_skip=lambda ci: self._sf.in_flight((reader.path, ci)))

    def _chunk(self, reader, ci: int) -> np.ndarray:
        return self._sf.do((reader.path, ci),
                           lambda: self._fetch(reader, ci))

    def _fetch(self, reader, ci: int) -> np.ndarray:
        out, decoded = reader.fetch_chunk(ci)
        if decoded:  # a real decode, not an LRU hit
            with self._lock:
                self.bytes_decoded += out.nbytes
        return out

    def stats(self) -> dict:
        return {
            "flights_led": self._sf.led,
            "flights_joined": self._sf.joined,
            "bytes_decoded": self.bytes_decoded,
        }
