"""SZ-style scheme: Lorenzo-predicted residuals, i8 stream + i32 outliers.

Byte layout per chunk: outlier count (u32), the i8 residual stream (value
-128 marks an escaped outlier), then the shuffled i32 outlier values.

Format note: container format 1 wrote the outlier stream *unshuffled*
(``spec.shuffle`` was silently ignored for szx); format 2 shuffles it like
every other scheme.  :meth:`decode_spec` keeps v1 payloads reading bit-exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from .. import szx as _szx
from . import Scheme, register_scheme, shuffle_bytes, unshuffle_bytes


@register_scheme
class SzxScheme(Scheme):
    name = "szx"

    def params(self, spec) -> dict:
        return {"eps": spec.eps, **super().params(spec)}

    def error_bound(self, spec) -> float:
        return spec.eps

    def decode_spec(self, spec, fmt: int):
        if fmt < 2 and spec.shuffle != "none":
            return dataclasses.replace(spec, shuffle="none")
        return spec

    def stage1(self, blocks_np, spec):
        x = jnp.asarray(blocks_np, jnp.float32)
        _szx.check_eps(float(jnp.max(jnp.abs(x))), spec.eps)
        return {"res": np.asarray(_szx.encode(x, eps=spec.eps))}

    def serialize(self, s1, lo, hi, spec) -> bytes:
        r = s1["res"][lo:hi].reshape(-1)
        small = np.abs(r) <= 127
        stream = np.where(small, r, -128).astype(np.int8)
        outliers = r[~small].astype(np.int32)
        return (
            np.uint32(outliers.size).tobytes()
            + stream.tobytes()
            + shuffle_bytes(outliers.tobytes(), spec.shuffle, 4)
        )

    def deserialize(self, payload, nblk, spec):
        n = spec.block_size
        n_out = int(np.frombuffer(payload[:4], np.uint32)[0])
        nvals = nblk * n * n * n
        stream = np.frombuffer(payload[4 : 4 + nvals], np.int8)
        outliers = np.frombuffer(
            unshuffle_bytes(payload[4 + nvals : 4 + nvals + 4 * n_out],
                            spec.shuffle, 4),
            np.int32,
        )
        r = stream.astype(np.int32)
        esc = stream == -128
        r[esc] = outliers
        r = r.reshape(nblk, n, n, n)
        return np.asarray(_szx.decode(jnp.asarray(r), eps=spec.eps))
