"""Table 2 — compressing the wavelet detail coefficients with FP codecs
(fpzip-style, sz-style, spdp) vs plain ZLIB vs byte-shuffle+ZLIB.

Expected reproduction: none of the FP coders beats SHUF+ZLIB on the
aggregate payload (the paper's conclusion)."""
from __future__ import annotations

import time
import zlib

import numpy as np
import jax.numpy as jnp

from repro.core import CompressionSpec
from repro.core import lossless
from repro.core import shuffle as shuf
from repro.core import threshold, wavelets
from repro.core.blocks import blockify
from repro.core.fpzipx import float_to_ordered
from repro.core.metrics import psnr

from .common import dataset, emit, save_json


def _wavelet_payload(field, eps):
    blocks = jnp.asarray(blockify(field, 32))
    co = wavelets.forward3d(blocks, "w3ai")
    mask = np.asarray(threshold.significant_mask(co, eps))
    c = wavelets.coarse_side(32)
    coarse = np.asarray(co[..., :c, :c, :c]).astype(np.float32)
    details = np.asarray(co)[mask].astype(np.float32)
    fixed = np.packbits(mask.reshape(-1)).tobytes() + coarse.tobytes()
    # PSNR is set by substage 1 only
    from repro.core import codec as _codec

    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=eps)
    comp = _codec.compress_field(field, spec)
    dec = _codec.decompress_field(comp)
    return fixed, details, psnr(field, dec)


def _code_details(details: np.ndarray, how: str) -> bytes:
    raw = details.tobytes()
    if how == "zlib":
        return zlib.compress(raw, 6)
    if how == "shuf+zlib":
        return zlib.compress(shuf.byte_shuffle(raw, 4), 6)
    if how == "fpzip1d+zlib":
        u = np.asarray(float_to_ordered(jnp.asarray(details))).astype(np.uint32)
        d = np.diff(u, prepend=np.uint32(0))
        return zlib.compress(shuf.byte_shuffle(d.tobytes(), 4), 6)
    if how == "sz1d+zlib":
        # error-free here: delta of the fp32 bit patterns (predictive, lossless)
        u = details.view(np.uint32)
        d = np.diff(u, prepend=np.uint32(0))
        return zlib.compress(d.tobytes(), 6)
    if how == "spdp+zlib":
        return lossless.encode(shuf.byte_shuffle(raw, 4), "spdp")
    raise ValueError(how)


def run(quick: bool = True):
    field = dataset("10k")["p"]
    eps_list = [1e-3] if quick else [1e-4, 1e-3, 1e-2]
    rows = []
    t0 = time.time()
    for eps in eps_list:
        fixed, details, p = _wavelet_payload(field, eps)
        raw_bytes = field.nbytes
        for how in ("zlib", "shuf+zlib", "fpzip1d+zlib", "sz1d+zlib", "spdp+zlib"):
            coded = _code_details(details, how)
            total = len(zlib.compress(fixed, 6)) + len(coded)
            rows.append({"eps": eps, "coder": how, "psnr": p,
                         "cr": raw_bytes / total})
    dt = time.time() - t0
    save_json("table2_coeff_coders", rows)
    by = {r["coder"]: r["cr"] for r in rows if r["eps"] == eps_list[-1]}
    best = max(by, key=by.get)
    emit("table2_best_coder", dt * 1e6 / max(len(rows), 1), best)
    emit("table2_shuf_zlib_cr", dt * 1e6 / max(len(rows), 1),
         f"{by['shuf+zlib']:.2f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
