"""HTTP region-serving load benchmark (ISSUE 5 acceptance).

N client threads hammer a loopback :class:`RegionHTTPServer` with a
zipf-hot region mix (a few regions take most of the traffic — the analyst
returning to the same vortex core) and report p50/p99 request latency,
throughput, and where the queries were answered: decoded-region LRU vs
chunk LRU vs cold decode.

The dataset lives in a ``mem://`` store — no scratch directory, and the
serve tier is exercised end-to-end over a non-file backend (URL root ->
CZDataset -> byte-ranged reads).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import CompressionSpec
from repro.serve import Client, RegionHTTPServer
from repro.store import CZDataset, MemoryStore

from .common import dataset, emit, save_json


def _zipf_weights(k: int, a: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, k + 1) ** a
    return w / w.sum()


def run(quick: bool = True):
    n_threads = 4 if quick else 8
    n_req = 60 if quick else 400         # per thread
    box = 24
    n_regions = 24 if quick else 96      # candidate pool, zipf-weighted
    qois = ["p"] if quick else ["p", "rho"]

    fields = {q: f for q, f in dataset("10k").items() if q in qois}
    n = next(iter(fields.values())).shape[0]
    spec = CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                           block_size=16, buffer_bytes=1 << 18)
    root = "mem://bench_serve"
    with CZDataset(root, "a", spec=spec, workers=4) as ds:
        ds.append(fields, time=0.0)

    rng = np.random.default_rng(7)
    lows = rng.integers(0, n - box, (n_regions, 3))
    weights = _zipf_weights(n_regions)

    lats: list[list[float]] = [[] for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads)

    with RegionHTTPServer(root, port=0, cache_bytes=32 << 20,
                          cache_chunks=32, max_inflight=n_threads) as srv:
        srv.start()

        # cold pass: one client walks every candidate region once, so the
        # timed phase below measures the steady state (and this measures the
        # decode-bound worst case)
        cold = []
        with Client(srv.url) as c:
            for q in qois:
                for lo in lows:
                    t1 = time.perf_counter()
                    c.region(q, 0, lo, lo + box)
                    cold.append(time.perf_counter() - t1)
        cold_ms = np.asarray(cold) * 1e3

        def worker(i: int) -> None:
            c = Client(srv.url)
            trng = np.random.default_rng(100 + i)
            barrier.wait()
            for k in range(n_req):
                lo = lows[trng.choice(n_regions, p=weights)]
                t1 = time.perf_counter()
                c.region(qois[k % len(qois)], 0, lo, lo + box)
                lats[i].append(time.perf_counter() - t1)
            c.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = srv.region.stats()

    lat_ms = np.concatenate([np.asarray(ts) for ts in lats]) * 1e3
    p50, p99 = np.percentile(lat_ms, [50, 99])
    total = n_threads * n_req
    rps = total / wall
    region_hr = stats["region_cache_hit_rate"] or 0.0
    chunk_hr = stats["cache_hit_rate"] or 0.0
    amplification = stats["bytes_decoded"] / max(1, stats["bytes_served"])

    results = {
        "n": n, "box": box, "threads": n_threads, "requests": total,
        "n_regions": n_regions, "wall_s": wall, "rps": rps,
        "p50_ms": float(p50), "p99_ms": float(p99),
        "cold_p50_ms": float(np.percentile(cold_ms, 50)),
        "cold_p99_ms": float(np.percentile(cold_ms, 99)),
        "region_cache_hit_rate": region_hr,
        "chunk_cache_hit_rate": chunk_hr,
        "decode_amplification": amplification,
        "server_stats": stats,
    }
    emit("serve_p50", p50 * 1e3, f"{rps:.0f}rps")
    emit("serve_p99", p99 * 1e3, f"{total}req_x{n_threads}thr")
    emit("serve_cold_p50", float(np.percentile(cold_ms, 50)) * 1e3,
         f"{len(cold_ms)}regions")
    emit("serve_hit_rate", region_hr * 1e6,
         f"region{region_hr:.2f}_chunk{chunk_hr:.2f}")
    MemoryStore.drop("bench_serve")
    path = save_json("serve", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (also the default under benchmarks.run)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
