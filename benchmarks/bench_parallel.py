"""Rank-scaling throughput of the cluster engine (ISSUE 3 acceptance).

Measures single-shared-file encode throughput of
:class:`repro.cluster.ParallelCompressor` at 1/2/4(/8) ranks against the two
one-process baselines: the serial writer and the ``workers=4`` thread path.
The synthetic cavitation field is the paper's workload.

Process scaling is bounded by the host: the script first calibrates
*effective cores* (aggregate throughput of concurrent CPU-bound processes
vs. one) and reports every speedup next to that ceiling — on a shared/
throttled 2-vCPU CI box the ceiling itself can sit below 1.5x, while the
same script on a real node shows near-linear rank scaling.
"""
from __future__ import annotations

import multiprocessing
import os
import shutil
import tempfile
import time
import zlib

from repro.core import CompressionSpec, container
from repro.cluster import ParallelCompressor

from .common import dataset, emit, save_json


def _busy(_arg: int) -> float:
    buf = os.urandom(1 << 20) * 4
    t0 = time.time()
    for _ in range(3):
        zlib.compress(buf, 6)
    return time.time() - t0


def effective_cores(procs: int = 4) -> float:
    """Aggregate CPU throughput of ``procs`` concurrent workers vs. one —
    the hard ceiling on any process-parallel speedup on this host."""
    serial = _busy(0)
    with multiprocessing.get_context("spawn").Pool(procs) as pool:
        pool.map(_busy, range(procs))  # exclude worker spawn from the window
        t0 = time.time()
        pool.map(_busy, range(procs))
        wall = time.time() - t0
    return procs * serial / wall


def _timed(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.time()
        fn()
        ts.append(time.time() - t0)
    return min(ts)


def run(quick: bool = True):
    n = 96
    reps = 2 if quick else 3
    ranks_list = (1, 2, 4) if quick else (1, 2, 4, 8)
    field = dataset("10k", n=n)["p"]
    specs = {
        # the paper's flagship lossy scheme ...
        "wavelet": CompressionSpec(scheme="wavelet", wavelet="w3ai", eps=1e-3,
                                   block_size=16, buffer_bytes=1 << 17),
        # ... and the restart-file lossless path, whose stage 2 dominates
        # (the best showcase for rank scaling)
        "fpzipx": CompressionSpec(scheme="fpzipx", block_size=16,
                                  buffer_bytes=1 << 17, stage2="zlib9"),
    }

    cores = effective_cores(max(ranks_list))
    results = {"n": n, "ranks": list(ranks_list),
               "effective_cores": cores, "schemes": {}}
    emit("parallel_effective_cores", cores * 1e6, f"x{cores:.2f}_ceiling")

    out = tempfile.mkdtemp()
    with ParallelCompressor(max(ranks_list)) as pc:
        for label, spec in specs.items():
            s_path = os.path.join(out, f"{label}.serial.cz")
            t_path = os.path.join(out, f"{label}.threads.cz")
            p_path = os.path.join(out, f"{label}.par.cz")
            t_serial = _timed(lambda: container.write_field(s_path, field, spec),
                              reps)
            t_thread = _timed(
                lambda: container.write_field(t_path, field, spec, workers=4),
                reps)
            # warm the pool and every worker's jit cache for each rank
            # count's batch shape (map may hand a span to any idle worker)
            for r in ranks_list:
                for _ in range(2):
                    pc.compress(p_path, field, spec, ranks=r)
            rows = {"serial_s": t_serial, "threads4_s": t_thread,
                    "threads4_speedup": t_serial / t_thread, "ranks": {}}
            mb = field.nbytes / 2**20
            emit(f"parallel_{label}_serial", t_serial * 1e6,
                 f"{mb / t_serial:.0f}MBps")
            emit(f"parallel_{label}_threads4", t_thread * 1e6,
                 f"x{t_serial / t_thread:.2f}")
            for r in ranks_list:
                tr = _timed(
                    lambda: pc.compress(p_path, field, spec, ranks=r), reps)
                sp = t_serial / tr
                rows["ranks"][r] = {"time_s": tr, "MBps": mb / tr,
                                    "speedup_vs_serial": sp}
                emit(f"parallel_{label}_r{r}", tr * 1e6,
                     f"x{sp:.2f}_of_x{cores:.2f}_ceiling")
            # identical output is the engine's contract — cheap to re-assert
            with open(s_path, "rb") as a, open(p_path, "rb") as b:
                assert a.read() == b.read(), f"{label}: parallel != serial"
            results["schemes"][label] = rows

    r4 = {lbl: rows["ranks"].get(4, {}).get("speedup_vs_serial")
          for lbl, rows in results["schemes"].items()}
    results["speedup_r4"] = r4
    shutil.rmtree(out, ignore_errors=True)
    path = save_json("parallel", results)
    print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    run()
