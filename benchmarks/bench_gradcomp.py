"""Beyond-paper: error-feedback compressed cross-pod gradient reduction.

Subprocess (needs >1 fake device): tiny 2-pod mesh; compares
(a) collective bytes on the pod axis, dense vs topk-compressed (from the
    loop-aware HLO analysis of both compiled train steps), and
(b) loss after N steps, dense vs compressed (error feedback keeps parity).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from .common import emit, save_json

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.data.tokens import DataConfig, batch_at
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import ModelSettings, input_batch_specs
from repro.train.step import build_train_step, train_state_specs, init_train_state

cfg = reduced(ARCHS["smollm-135m"])
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4, 1), ("pod", "data", "model"))
st = ModelSettings(q_chunk=16, kv_chunk=16, ce_chunk=32, remat="none",
                   compute_dtype=jnp.float32)
shape = ShapeConfig("tiny", 64, 8, "train")
batch_specs = input_batch_specs(cfg, shape)
out = {}
steps = int(sys.argv[1])

for mode, gc in (("dense", None), ("topk32", "topk32")):
    _, jit_for, _ = build_train_step(cfg, mesh, settings=st, grad_compress=gc,
                                     donate=False)
    jitted = jit_for(batch_specs)
    sspecs = train_state_specs(cfg, grad_compress=gc)
    with mesh:
        comp = jitted.lower(sspecs, batch_specs).compile()
    text = comp.as_text()
    la = analyze_hlo(text)
    out[f"{mode}_coll_bytes"] = la.collective_bytes
    out[f"{mode}_coll_by_op"] = {k: v["bytes"] for k, v in la.collectives.items()}

    # cross-pod bytes: collectives whose replica groups span both pods
    # (mesh (2,4,1): device ids 0-3 = pod0, 4-7 = pod1)
    import re as _re
    pod_bytes = 0
    for line in text.splitlines():
        m = _re.search(r"= (\S+|\([^=]*?\)) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(", line)
        if not m:
            continue
        g = _re.search(r"replica_groups=\{\{([\d,]+)\}", line)
        g2 = _re.search(r"replica_groups=\[\d+,\d+\]<=\[([\d,]+)\]", line)
        spans = False
        if g:
            ids = [int(x) for x in g.group(1).split(",")]
            spans = any(i < 4 for i in ids) and any(i >= 4 for i in ids)
        elif g2:
            # iota groups: conservatively treat groups of size >4 as spanning
            dims = [int(x) for x in g2.group(1).split(",")]
            spans = (dims and dims[0] * (dims[1] if len(dims) > 1 else 1) >= 8) or "T(" in line
        if spans:
            from repro.launch.hlo_analysis import _shape_bytes
            pod_bytes += _shape_bytes(m.group(1))
    out[f"{mode}_pod_coll_bytes_static"] = pod_bytes

    # short real training run for loss parity
    state = init_train_state(cfg, jax.random.PRNGKey(0), grad_compress=gc)
    dc = DataConfig(vocab=cfg.vocab, batch=8, seq=64)
    losses = []
    with mesh:
        for s in range(steps):
            batch = {k: jnp.asarray(v) for k, v in batch_at(dc, s).items()}
            state, m = jitted(state, batch)
            losses.append(float(m["loss"]))
    out[f"{mode}_loss_first"] = float(np.mean(losses[:3]))
    out[f"{mode}_loss_last"] = float(np.mean(losses[-3:]))
print(json.dumps(out))
"""


def run(quick: bool = True):
    t0 = time.time()
    steps = 25 if quick else 60
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SUB, str(steps)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    out = json.loads(r.stdout.strip().splitlines()[-1])
    dt = time.time() - t0
    save_json("gradcomp", out)
    ratio = out["dense_coll_bytes"] / max(out["topk32_coll_bytes"], 1)
    emit("gradcomp_coll_bytes_ratio", dt * 1e6, f"{ratio:.2f}")
    emit("gradcomp_loss_dense", dt * 1e6, f"{out['dense_loss_last']:.4f}")
    emit("gradcomp_loss_topk32", dt * 1e6, f"{out['topk32_loss_last']:.4f}")
    return out


if __name__ == "__main__":
    run(quick=False)
