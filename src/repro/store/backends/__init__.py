"""``repro.store.backends`` — pluggable byte stores under CZDataset.

Zarr names the design goal: a "pluggable storage subsystem with support for
file systems, key-value databases and cloud object stores".  Everything
above this package (the CZ2 container reader, CZDataset, the serve tier)
talks to a :class:`Store` — *what* is stored (chunk streams + footers) is
decoupled from *where* it lives.

Built-in backends:

========== ===================== =========================================
URL scheme class                 semantics
========== ===================== =========================================
``file://`` :class:`FileStore`   local directory; bit-compatible with
                                 pre-backend datasets on disk (plain paths
                                 resolve here)
``mem://``  :class:`MemoryStore` process-local dict; named URLs share one
                                 instance per process (tests, ephemeral
                                 in-situ runs)
``range://`` :class:`RangeStore` object-store semantics: whole-object put,
                                 byte-range get, request counters — keeps
                                 the read path honest
``http://``  :class:`HttpStore`  read-only ranged gets against any static
``https://``                     file server (keep-alive pooled; wrapped in
                                 :class:`RetryStore` by default)
========== ===================== =========================================

Third-party backends subclass :class:`Store` and register a URL scheme with
:func:`register_store_scheme`; every ``CZDataset(root)``, CLI entry point,
and serve tier then accepts their URLs.
"""
from __future__ import annotations

import os

from .base import (Store, StoreKeyError, StoreRangeError,  # noqa: F401
                   check_key, check_range)
from .file import FileStore  # noqa: F401
from .flaky import FlakyStore, InjectedFault  # noqa: F401
from .http import HttpStore, StaticFileServer  # noqa: F401
from .instrument import InstrumentedStore, StoreMeter  # noqa: F401
from .memory import MemoryStore  # noqa: F401
from .object import RangeStore  # noqa: F401
from .retry import RetryStore, StoreDeadlineError  # noqa: F401

__all__ = ["Store", "StoreKeyError", "StoreRangeError", "StoreDeadlineError",
           "check_key", "check_range", "FileStore", "MemoryStore",
           "RangeStore", "HttpStore", "StaticFileServer", "RetryStore",
           "FlakyStore", "InjectedFault", "InstrumentedStore",
           "StoreMeter", "open_store", "register_store_scheme",
           "STORE_SCHEMES"]

#: URL scheme -> factory taking the part after ``scheme://``.
STORE_SCHEMES: dict[str, type | object] = {
    "file": FileStore.from_url,
    "mem": MemoryStore.from_url,
    "range": RangeStore.from_url,
    "http": HttpStore.from_url,
    "https": lambda rest: HttpStore.from_url(rest, secure=True),
}


def register_store_scheme(scheme: str, factory) -> None:
    """Register a third-party store: ``factory(rest)`` gets the URL part
    after ``{scheme}://`` and returns a :class:`Store`."""
    if not scheme or "://" in scheme:
        raise ValueError(f"invalid store scheme {scheme!r}")
    STORE_SCHEMES[str(scheme)] = factory


def open_store(root, *, instrument: bool = False,
               retries: int | None = None,
               timeout: float | None = None) -> Store:
    """Resolve a dataset root to a :class:`Store`.

    ``root`` is a :class:`Store` (returned as-is, possibly policy-wrapped),
    a URL (``file:///data/run42``, ``mem://myds``, ``http://host/ds``, any
    registered scheme), or a plain local path (the historical form —
    resolves to a :class:`FileStore`).

    ``instrument=True`` wraps the resolved backend in an
    :class:`InstrumentedStore` so every op is metered into the global
    ``cz_store_*`` registry series (already-instrumented stores pass
    through unwrapped).

    ``retries``/``timeout`` configure the :class:`RetryStore` policy layer:
    backends that declare ``remote = True`` (HttpStore) are wrapped by
    default with 2 retries; ``retries=N`` forces wrapping of any backend,
    ``retries=0`` opts out.  ``timeout`` sets the remote backend's socket
    timeout *and* the retry layer's per-op deadline.  The retry wrapper
    goes outermost (``Retry(Instrumented(inner))``) so each attempt is
    metered individually.
    """
    if isinstance(root, Store):
        store = root
    else:
        root = os.fspath(root)
        if "://" in root:
            scheme, rest = root.split("://", 1)
            try:
                factory = STORE_SCHEMES[scheme]
            except KeyError:
                raise ValueError(
                    f"unknown store scheme {scheme!r} in {root!r} (registered:"
                    f" {', '.join(sorted(STORE_SCHEMES))})") from None
            store = factory(rest)
        else:
            store = FileStore(root)
    if timeout is not None and isinstance(store, HttpStore):
        store.timeout = float(timeout)
    if instrument and not isinstance(store, (InstrumentedStore, RangeStore)):
        store = InstrumentedStore(store)
    if not isinstance(store, RetryStore):
        if retries is None:
            if store.remote:
                store = RetryStore(store, deadline=timeout)
        elif retries > 0:
            store = RetryStore(store, retries=retries, deadline=timeout)
    return store
