"""Batched serving driver: prefill a batch of prompts, then decode tokens.

CPU-runnable with ``--reduced``; demonstrates the serve path (KV cache /
SSM state decode) end-to-end with greedy sampling.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.models import (
    ModelSettings,
    cache_spec,
    decode_step,
    init_params,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    st = ModelSettings(q_chunk=16, kv_chunk=16, remat="none",
                       compute_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    B = args.batch
    S = args.prompt_len + args.max_new
    cache = cache_spec(cfg, B, S, dtype=jnp.float32, mode="zeros")
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)),
                         jnp.int32)

    step_fn = jax.jit(
        lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, st))

    # prefill by stepping the decoder over the prompt (cache fills in place)
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = step_fn(params, cache, prompt[:, i:i + 1], jnp.int32(i))
    generated = []
    for i in range(args.max_new):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        generated.append(nxt)
        logits, cache = step_fn(params, cache, nxt,
                                jnp.int32(args.prompt_len + i))
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks = B * (args.prompt_len + args.max_new)
    print(f"decoded {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s incl. prefill)")
    print("sample:", np.asarray(out[0])[:16].tolist())
    return np.asarray(out)


if __name__ == "__main__":
    main()
