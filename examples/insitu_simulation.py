"""In-situ compression during a running simulation (paper Fig. 12 analogue):
the mini Euler solver advances a bubble collapse; the I/O hook opens one
append-mode CZDataset and commits pressure + density snapshots as they are
produced — the manifest is patched atomically on every commit, so a reader
(or a crash) mid-run only ever sees whole timesteps.

Run:  PYTHONPATH=src python examples/insitu_simulation.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import CompressionSpec
from repro.fields import EulerConfig, init_bubble_cloud
from repro.fields.euler3d import cfl_dt, primitives, run
from repro.store import CZDataset

cfg = EulerConfig(n=48, n_bubbles=5)
U = init_bubble_cloud(cfg)
dt = cfl_dt(U)
spec = CompressionSpec(scheme="wavelet", eps=1e-2, block_size=16)

sim_t = io_t = 0.0
ds = CZDataset("artifacts/insitu_dataset", mode="a", spec=spec, workers=4)
for snap in range(5):
    t0 = time.time()
    U = run(U, 10, dt=dt)
    jnp.asarray(U).block_until_ready()
    sim_t += time.time() - t0

    rho, _, p = primitives(U)
    t0 = time.time()
    t = ds.append({"p": np.asarray(p, np.float32),
                   "rho": np.asarray(rho, np.float32)},
                  time=float(snap))
    io_t += time.time() - t0
    ts = ds.timestep_info("p", t)
    print(f"snapshot {snap} -> timestep {t}: p in "
          f"[{float(p.min()):.2f},{float(p.max()):.2f}] "
          f"CR {ts['raw_bytes']/ts['bytes']:6.1f}x (dataset v{ds.version})")
ds.close()
print(f"in-situ I/O overhead: {io_t/(sim_t+io_t)*100:.1f}% of wall time")

# reopen and pull one sub-box of the final snapshot — only the covering
# chunks are decoded, the 48^3 field is never inflated
with CZDataset("artifacts/insitu_dataset") as ds:
    t_last = ds.timesteps("p")[-1]
    box = ds.read_box("p", t_last, (8, 8, 8), (40, 40, 40))
    print(f"region read t={t_last}: box {box.shape}, "
          f"p_mean {box.mean():.3f}, stats {ds.stats()}")
