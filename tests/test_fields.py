"""Cavitation generator + mini Euler solver sanity tests."""
import numpy as np
import jax.numpy as jnp

from repro.fields import (
    CloudConfig,
    EulerConfig,
    cavitation_fields,
    init_bubble_cloud,
    primitives,
    run,
)
from repro.fields.euler3d import cfl_dt


def test_cavitation_fields_stats():
    cfg = CloudConfig(n=64, n_bubbles=20)
    for t in (4.7, 9.4):
        f = cavitation_fields(cfg, t)
        assert set(f) == {"p", "rho", "E", "a2"}
        for q, a in f.items():
            assert a.shape == (64, 64, 64)
            assert a.dtype == np.float32
            assert np.isfinite(a).all(), q
        assert f["a2"].min() >= 0.0 and f["a2"].max() <= 1.0
        assert f["p"].min() >= cfg.p_min - 1e-3
        assert f["rho"].max() <= cfg.rho_liquid * 1.6


def test_cavitation_collapse_dynamics():
    """Bubbles shrink toward collapse -> gas fraction decreases; shocks appear."""
    cfg = CloudConfig(n=64, n_bubbles=20)
    early = cavitation_fields(cfg, 1.0)
    late = cavitation_fields(cfg, 6.5)
    post = cavitation_fields(cfg, 9.4)
    assert late["a2"].mean() < early["a2"].mean()
    assert post["p"].max() > early["p"].max()  # emitted shocks raise peak p


def test_cavitation_deterministic():
    cfg = CloudConfig(n=32, n_bubbles=5)
    a = cavitation_fields(cfg, 4.7)["p"]
    b = cavitation_fields(cfg, 4.7)["p"]
    np.testing.assert_array_equal(a, b)


def test_euler_conservation_and_stability():
    cfg = EulerConfig(n=32, n_bubbles=3)
    U0 = init_bubble_cloud(cfg)
    dt = cfl_dt(U0)
    U = run(U0, steps=20, dt=dt)
    u = np.asarray(U)
    assert np.isfinite(u).all()
    # conservative scheme on a periodic box: totals preserved to fp rounding
    for comp in range(5):
        tot0 = float(jnp.sum(U0[comp]))
        tot1 = float(jnp.sum(U[comp]))
        scale = max(float(jnp.sum(jnp.abs(U0[comp]))), float(jnp.sum(jnp.abs(U[comp]))), 1.0)
        assert abs(tot1 - tot0) <= 1e-4 * scale, comp
    # pressure stays positive
    _, _, p = primitives(U)
    assert float(jnp.min(p)) > 0.0


def test_euler_waves_propagate():
    cfg = EulerConfig(n=32, n_bubbles=3)
    U0 = init_bubble_cloud(cfg)
    U = run(U0, steps=30)
    # collapse generates motion: kinetic energy becomes nonzero
    ke = float(jnp.sum(jnp.asarray(U)[1:4] ** 2))
    assert ke > 1e-8
