"""AdamW with global-norm clipping and cosine LR schedule (built from
scratch — no optax in this environment)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_step", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_step(params, grads, opt_state, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    lr = lr_at(cfg, step)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mh = m2 / (1 - b1 ** t)
        vh = v2 / (1 - b2 ** t)
        p2 = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
