"""Compressed-field region serving: ``(quantity, t, lo, hi)`` queries
against a CZDataset answered through a shared decode cache.

Deliberately free of jax/model imports — serving compressed fields must not
pull in the LLM decode stack (:mod:`repro.serve.step`).
"""
from __future__ import annotations

import threading
import time

__all__ = ["FieldRegionServer"]


class FieldRegionServer:
    """Serves ``(quantity, t, lo, hi)`` region queries from a CZDataset.

    Thin serving front over :meth:`repro.store.CZDataset.read_box`: all
    queries share the store's pooled FieldReaders and their LRU chunk
    caches, so a hot region costs one cache lookup instead of a decode —
    the paper's §2.3 decompressor, turned into a query server.  Safe for
    concurrent request threads.
    """

    def __init__(self, dataset, cache_readers: int = 16,
                 cache_chunks: int = 32):
        from repro.store import CZDataset

        if isinstance(dataset, str):
            dataset = CZDataset(dataset, mode="r",
                                cache_readers=cache_readers,
                                cache_chunks=cache_chunks)
        self.ds = dataset
        self._lock = threading.Lock()
        self.queries = 0
        self.query_s = 0.0

    def query(self, quantity: str, t: int, lo, hi):
        t0 = time.perf_counter()
        out = self.ds.read_box(quantity, t, lo, hi)
        with self._lock:
            self.queries += 1
            self.query_s += time.perf_counter() - t0
        return out

    def stats(self) -> dict:
        s = self.ds.stats()
        s.update({
            "queries": self.queries,
            "mean_latency_ms": 1e3 * self.query_s / max(1, self.queries),
        })
        return s

    def close(self):
        self.ds.close()
