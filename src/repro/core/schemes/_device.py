"""Device routing for scheme stage-1 transforms (the ``device=`` knob).

``CompressionSpec.device`` selects where a scheme's substage-1 transform
runs:

* ``"host"`` (default) — the pure ``jax.numpy`` reference math in
  ``repro.core`` (wavelets/zfpx/szx), exactly the pre-device code path;
* ``"jax"`` — the jit'd Pallas kernel wrappers in ``repro.kernels.ops``
  (real Pallas lowering on TPU, interpret mode elsewhere).  The whole block
  batch is transformed in one jitted call before chunking.

The knob is a *routing* choice, never a format choice: ``device`` is
recorded in container headers for provenance but is not required to decode.
A file written with ``device="jax"`` decodes bit-exact on host for schemes
whose kernels are integer-exact (zfpx, lorenzo) and within the scheme's
declared error bound otherwise (wavelet — fp rounding only).  When the
Pallas toolchain is unavailable, ``device="jax"`` falls back to host with a
:class:`DeviceFallbackWarning` instead of failing, so containers stay
readable everywhere.
"""
from __future__ import annotations

import warnings

from repro import obs
from repro.obs import events as _events

__all__ = ["DEVICES", "DeviceFallbackWarning", "check_device", "kernel_ops",
           "resolve_ops", "route", "resolved_device"]

_FALLBACKS = obs.counter(
    "cz_kernel_fallbacks_total",
    "device='jax' requests that fell back to the host path "
    "(Pallas toolchain unavailable).")

#: devices a spec may name (recorded in CZ2 headers, validated everywhere)
DEVICES = ("host", "jax")

_UNSET = object()
_OPS = _UNSET


class DeviceFallbackWarning(UserWarning):
    """``device="jax"`` was requested but the Pallas kernel wrappers could
    not be imported; stage 1 ran on the host reference path instead."""


def check_device(device: str) -> None:
    """Raise ValueError on a device name outside :data:`DEVICES`."""
    if device not in DEVICES:
        raise ValueError(
            f"unknown device {device!r}; one of {DEVICES}")


def kernel_ops():
    """``repro.kernels.ops`` if the Pallas toolchain imports, else ``None``
    (resolved once and cached — the fallback decision is per-process)."""
    global _OPS
    if _OPS is _UNSET:
        try:
            from repro.kernels import ops as _ops
            _OPS = _ops
        except Exception:  # missing/broken pallas: gate, don't crash
            _OPS = None
    return _OPS


def resolve_ops(spec):
    """Kernel-ops module when ``spec`` routes stage 1 to a device, else None.

    ``None`` means "use the host path" — either because the spec asked for
    it or because the kernels are unavailable (warned, not raised: decode of
    device-written containers must succeed on any host).
    """
    check_device(spec.device)
    if spec.device != "jax":
        return None
    ops = kernel_ops()
    if ops is None:
        _FALLBACKS.inc()
        _events.event("device.fallback", level="warn", requested="jax",
                      used="host")
        warnings.warn(
            "device='jax' requested but repro.kernels.ops is unavailable "
            "(no Pallas toolchain); stage 1 falling back to the host path",
            DeviceFallbackWarning, stacklevel=3)
    return ops


def route(spec, host_fn, ops_name: str):
    """The one device dispatch: the named ``kernels.ops`` wrapper when the
    spec routes to a device (and kernels are importable), else ``host_fn``.
    Kernel wrappers and host references share call signatures, so scheme
    code calls the result unconditionally."""
    ops = resolve_ops(spec)
    return host_fn if ops is None else getattr(ops, ops_name)


def resolved_device(spec, device_capable: bool) -> str:
    """Where stage 1 *actually* runs for this spec — what headers record.

    ``"jax"`` only when the scheme has a kernel path and the kernels import;
    a host-only scheme (or a fallback) truthfully reports ``"host"`` no
    matter what the knob asked for."""
    check_device(spec.device)
    if spec.device == "jax" and device_capable and kernel_ops() is not None:
        return "jax"
    return "host"
