"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]. Early-fusion frontend stubbed."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    shared_expert=True,
    notes="MoE top-1 routed + always-on shared expert (llama4 style)",
)
